"""Generation rollover without a stall: incremental snapshot builds,
the warm-handoff prefill cache, and the gateway ingestion fixes.

Load-bearing claims, matching the acceptance criteria:

  * the incremental ``SnapshotBuilder`` produces arrays **bit-for-bit**
    identical to the full ``run_snapshot`` oracle — including users
    whose only change is events *aging out* of the lookback window, and
    events appended (even with old timestamps) while the build was in
    flight;
  * the warm handoff rekeys exactly the unchanged rows, the rekeyed
    entries are bitwise what a fresh admission would build (identical
    history => identical prefill state), and served results across a
    rollover are bitwise identical with the handoff on or off;
  * the rekey **never** fires across a recomputed (evicted) generation
    — ``BatchFeatureStore.lookup`` on an evicted generation recomputes
    from the log *as of now*, which a late-arriving old-ts event can
    make diverge from the frozen arrays the cache keys assumed;
  * ``observe_many`` validates the whole event batch against BOTH
    stores before either absorbs anything, and ``queue_delay`` can
    never go negative under the legacy shim's non-monotonic clock
    rewind.
"""
import numpy as np
import jax
import pytest

from conftest import DAY, FEATURE_LEN, N_ITEMS, N_USERS, tiny_engine
from repro.core.feature_store import (BatchFeatureStore, FeatureStoreConfig,
                                      SnapshotBuilder)
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
from repro.serving.api import Request
from repro.serving.loop import InjectionServer
from repro.serving.scheduler import Gateway, ServerConfig

_ENGINE = tiny_engine()  # the conftest session-shared tiny platform


# ----------------------------------------------------------------------
# Incremental build vs the full-build oracle (feature-store level)
# ----------------------------------------------------------------------

def _seeded_stores(n=2, n_users=200, window=2 * DAY, retention=8, seed=0):
    cfg = FeatureStoreConfig(n_users=n_users, feature_len=8, window=window,
                             snapshot_retention=retention)
    stores = [BatchFeatureStore(cfg) for _ in range(n)]
    rng = np.random.RandomState(seed)
    # the last user gets no seed events — reserved for targeted
    # scenarios (e.g. the aging-out-only user)
    u = rng.randint(0, n_users - 1, 3000)
    it = rng.randint(0, 50, 3000)
    ts = rng.randint(0, 5 * DAY, 3000)
    for s in stores:
        s.extend(u, it, ts)
    return stores


def test_incremental_build_bitwise_equals_full_incl_aging_out():
    """The tentpole differential: delta-materialize + copy-forward ==
    one monolithic run_snapshot, bit for bit. A user whose ONLY change
    is events aging out of the lookback window (no new events at all —
    the case a naive "users with new events" delta misses) must be in
    the rematerialized set."""
    full, inc = _seeded_stores()
    g1, g2 = 5 * DAY, 6 * DAY
    # user 199: events only in [g1 - window, g2 - window) — inside g1's
    # window, aged out of g2's, and never active again
    for s in (full, inc):
        s.extend([199] * 3, [7, 8, 9],
                 [3 * DAY + 10, 3 * DAY + 20, 3 * DAY + 30])
        s.run_snapshot(g1)
    assert full._snapshots[g1][2][199].sum() > 0  # visible in g1
    rng = np.random.RandomState(7)
    u2 = rng.randint(0, 50, 100)
    it2 = rng.randint(0, 50, 100)
    for s in (full, inc):
        s.extend(u2, it2, np.full(100, g1 + 500))

    full.run_snapshot(g2)
    builder = inc.begin_snapshot(g2)
    assert not builder.full_build
    assert 0 < builder.n_changed < inc.cfg.n_users  # a real delta
    assert 199 in builder._todo                     # aging-out user found
    steps = 0
    while builder.step(13):                         # budget-bounded
        steps += 1
    assert steps > 1 and builder.done
    for a, b in zip(full._snapshots[g2], inc._snapshots[g2]):
        np.testing.assert_array_equal(a, b)
    assert inc._snapshots[g2][2][199].sum() == 0    # really aged out

    # the exact changed-row record (the warm-handoff authority) matches
    # a brute-force row compare, and includes the aging-out user
    ch = inc.changed_users_between(g1, g2)
    pi, pt, pv = inc._snapshots[g1]
    ni, nt, nv = inc._snapshots[g2]
    brute = np.flatnonzero(
        ((ni != pi) | (nt != pt) | (nv != pv)).any(axis=1))
    np.testing.assert_array_equal(np.sort(ch), brute)
    assert 199 in ch


def test_incremental_build_mid_build_appends_fixup():
    """Events appended while the build is in flight — including a LATE
    arrival with an old timestamp inside the new window — are picked up
    by the finish-time fixup: the installed arrays equal run_snapshot
    as of completion time."""
    full, inc = _seeded_stores()
    g1, g2 = 5 * DAY, 6 * DAY
    for s in (full, inc):
        s.run_snapshot(g1)
    builder = inc.begin_snapshot(g2)
    builder.step(5)  # build in flight
    for s in (full, inc):
        # one normal mid-build event, one late old-ts event in-window
        s.extend([3, 4], [41, 42], [g2 - 100, g1 - DAY])
    while builder.step(50):
        pass
    assert builder.late_fixups == 2
    full.run_snapshot(g2)  # oracle over the same final log
    for a, b in zip(full._snapshots[g2], inc._snapshots[g2]):
        np.testing.assert_array_equal(a, b)


def test_incremental_build_falls_back_to_full():
    """No previous frozen generation to delta against (first snapshot,
    or the predecessor was evicted/registered-only) => full build, still
    bitwise equal to the oracle."""
    (first,) = _seeded_stores(1)
    b = first.begin_snapshot(5 * DAY)
    assert b.full_build and b.n_changed == first.cfg.n_users
    b.run()
    (oracle,) = _seeded_stores(1)
    oracle.run_snapshot(5 * DAY)
    for a, c in zip(oracle._snapshots[5 * DAY], first._snapshots[5 * DAY]):
        np.testing.assert_array_equal(a, c)

    # predecessor evicted: retention=1 keeps only the newest generation
    (ev,) = _seeded_stores(1, retention=1)
    ev.run_snapshot(5 * DAY)
    ev.run_snapshot(6 * DAY)  # evicts 5*DAY
    assert 5 * DAY not in ev._snapshots
    b = ev.begin_snapshot(7 * DAY)
    assert not b.full_build  # 6*DAY is frozen — delta against it
    (ev2,) = _seeded_stores(1, retention=1)
    ev2.run_snapshot(6 * DAY)
    ev2._snapshots.pop(6 * DAY)  # simulate an evicted predecessor
    b2 = ev2.begin_snapshot(7 * DAY)
    assert b2.full_build


def test_changed_users_between_certification():
    """The record only certifies adjacent frozen generations: a
    generation gap or an evicted endpoint returns None (the handoff must
    purge, not rekey)."""
    (s,) = _seeded_stores(1, retention=2)
    g1, g2, g3 = 5 * DAY, 6 * DAY, 7 * DAY
    s.run_snapshot(g1)
    s.run_snapshot(g2)
    assert s.changed_users_between(g1, g2) is not None
    assert s.changed_users_between(g1, g3) is None     # unknown gen
    assert s.changed_users_between(g2, g1) is None     # wrong direction
    s.run_snapshot(g3)                                 # evicts g1
    assert s.changed_users_between(g2, g3) is not None
    assert s.changed_users_between(g1, g2) is None     # g1 recomputes now


def test_rerun_snapshot_uncertifies_successor_records():
    """Re-running an existing generation replaces its arrays, so any
    successor's delta record — computed against the OLD arrays — is no
    longer a valid rekey authority and must be dropped (a stale record
    would let the handoff rekey prefill states built against the
    re-materialized rows)."""
    (s,) = _seeded_stores(1)
    g1, g2 = 5 * DAY, 6 * DAY
    s.run_snapshot(g1)
    s.run_snapshot(g2)
    assert s.changed_users_between(g1, g2) is not None
    s.append(5, 123, g1 - 50)   # late old-ts event inside g1's window
    s.run_snapshot(g1)          # re-materialize g1 (the supported branch)
    assert s.changed_users_between(g1, g2) is None


def test_builder_rejects_registered_generation():
    (s,) = _seeded_stores(1)
    s.run_snapshot(5 * DAY)
    with pytest.raises(ValueError, match="already registered"):
        SnapshotBuilder(s, 5 * DAY)


# ----------------------------------------------------------------------
# Evicted-generation contract: recompute-vs-frozen divergence
# ----------------------------------------------------------------------

def test_evicted_generation_recompute_diverges_on_late_event():
    """Pinning the contract the warm-handoff guard depends on: lookup
    on an evicted generation recomputes from the log AS OF NOW, so a
    late-arriving old-ts event makes it diverge from the frozen arrays
    that PrefillStateCache keys assumed."""
    (s,) = _seeded_stores(1, retention=2)
    g1, g2, g3 = 5 * DAY, 6 * DAY, 7 * DAY
    s.run_snapshot(g1)
    users = np.arange(s.cfg.n_users)
    frozen = [a.copy() for a in s.lookup(users, g1 + 100)]
    s.run_snapshot(g2)
    s.run_snapshot(g3)                       # evicts g1
    assert g1 not in s._snapshots and g1 in s._snapshot_times
    # late event: old ts inside g1's window, appended after eviction
    s.append(5, 123, g1 - 50)
    recomputed = s.lookup(users, g1 + 100)   # time-travel read of g1
    same = all((a == b).all() for a, b in zip(frozen, recomputed))
    assert not same                          # the frozen arrays lied
    assert (frozen[0][:5] == recomputed[0][:5]).all()  # only user 5 moved


def test_rekey_never_fires_across_recomputed_generation():
    """If installing the new generation evicts the old one (retention
    pressure), the old generation recomputes on lookup — its cache
    entries can no longer be certified against frozen rows, so the
    handoff must purge instead of rekey even with NO changed users."""
    gw = _gateway(retention=1)
    now = 5 * DAY + 100
    gw.submit_many([Request(user=u, now=now) for u in range(4)])
    gw.flush(now)
    assert len(gw.cache) == 4
    gw.tick(now + DAY)   # installs 6*DAY, evicting 5*DAY
    assert gw.injector.batch.changed_users_between(5 * DAY, 6 * DAY) is None
    assert gw.cache.rekeys == 0 and gw.cache.invalidations == 4
    assert len(gw.cache) == 0
    assert gw.stats()["rollover"]["rekeyed"] == 0


# ----------------------------------------------------------------------
# Warm handoff (gateway level)
# ----------------------------------------------------------------------

def _injector(policy="inject", retention=8, n_users=N_USERS):
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=FEATURE_LEN,
        snapshot_retention=retention))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=n_users, buffer_len=8, ingest_latency=0))
    rng = np.random.RandomState(0)
    us, its, tss = (rng.randint(0, min(n_users, N_USERS), 1500),
                    rng.randint(0, N_ITEMS, 1500),
                    rng.randint(0, 5 * DAY, 1500))
    store.extend(us, its, tss)
    rts.extend(us, its, tss)
    return FeatureInjector(
        InjectionConfig(policy=policy, feature_len=FEATURE_LEN), store, rts)


def _gateway(policy="inject", retention=8, injector=None, **cfg_kw):
    cfg_kw.setdefault("slate_len", 3)
    cfg_kw.setdefault("cache_entries", 64)
    return Gateway(_ENGINE, injector or _injector(policy, retention),
                   ServerConfig(**cfg_kw))


def test_rollover_rekeys_unchanged_invalidates_changed():
    """Across a generation roll: users with events in the rolled period
    lose their entry under the new generation (their snapshot rows
    changed — the old-generation entry is retained for the handoff
    window, marked first-victim); everyone else keeps their cached
    state under the new generation. The rekeyed entry must be BITWISE
    the entry a fresh admission under the new generation builds —
    identical history => identical prefill state."""
    gw = _gateway()
    now = 5 * DAY + 100
    users = list(range(8))
    gw.submit_many([Request(user=u, now=now) for u in users])
    gw.flush(now)
    assert len(gw.cache) == 8
    changed_users = [0, 1, 2]
    gw.observe_many(changed_users, [11, 12, 13], [now + 500] * 3)
    gw.tick(now + DAY)
    gen_b = gw.injector.generation(now + DAY)
    st = gw.stats()["rollover"]
    assert st["rekeyed"] == 5 and st["invalidated"] == 0
    assert st["retained"] == 3  # changed users' old-gen entries live on
    for u in users:
        assert ((u, (gen_b, 0)) in gw.cache) == (u not in changed_users)

    # the rekey invariant: rekeyed state == fresh admission, bitwise
    fresh = _gateway()
    fresh.observe_many(changed_users, [11, 12, 13], [now + 500] * 3)
    fresh.warm(users, now + DAY)
    for u in (3, 4, 7):
        a = gw.cache._entries[(u, (gen_b, 0))][0]
        b = fresh.cache._entries[(u, (gen_b, 0))][0]
        jax.tree.map(np.testing.assert_array_equal, a, b)

    # and serving after the roll: unchanged users hit, changed users miss
    h0, m0 = gw.cache.hits, gw.cache.misses
    gw.submit_many([Request(user=u, now=now + DAY) for u in users])
    gw.flush(now + DAY)
    assert gw.cache.hits - h0 == 5 and gw.cache.misses - m0 == 3


@pytest.mark.parametrize("legacy_serve", [False, True],
                         ids=["gateway", "legacy_serve"])
def test_warm_handoff_results_bitwise_equal_purge(legacy_serve):
    """The handoff is an optimization only: the same trace spanning a
    rollover serves bitwise-identical scores/slates with the handoff on
    or off — including through the deprecated legacy serve() wrapper
    (the fingerprint criterion). Hit counters differ, proving the rekey
    actually fired."""
    outs = []
    for handoff in (True, False):
        if legacy_serve:
            srv = InjectionServer(_ENGINE, _injector(), ServerConfig(
                slate_len=3, cache_entries=64, warm_handoff=handoff))
            gw = srv.gateway
        else:
            gw = _gateway(warm_handoff=handoff)
        rng = np.random.RandomState(3)
        now = 5 * DAY + 100
        scores, slates = [], []
        hits = 0
        for wave in range(3):
            u = rng.randint(0, N_USERS, 6)
            gw.observe_many(u, (u + 3) % N_ITEMS, np.full(6, now - 30))
            q = rng.randint(0, N_USERS, 10)
            if legacy_serve:
                with pytest.warns(DeprecationWarning):
                    r = srv.serve(q, now)
                scores.append(r.scores)
                slates.append(r.slate)
                hits += r.cache_hits
            else:
                tk = gw.submit_many([Request(user=int(x), now=now)
                                     for x in q])
                gw.flush(now)
                scores.append(np.stack([t.response.scores for t in tk]))
                slates.append(np.stack([t.response.slate for t in tk]))
                hits += sum(t.response.telemetry.cache_hit for t in tk)
            now += DAY  # every wave crosses a generation boundary
        outs.append((np.concatenate(scores), np.concatenate(slates),
                     hits, gw.cache.rekeys))
    (s_on, l_on, h_on, rk_on), (s_off, l_off, h_off, rk_off) = outs
    np.testing.assert_array_equal(l_on, l_off)   # slates: bitwise
    np.testing.assert_array_equal(s_on, s_off)   # scores: bitwise
    assert rk_on > 0 and rk_off == 0
    assert h_on > h_off  # the handoff converted misses into hits


def test_warm_step_stops_when_cache_budget_refills():
    """If live traffic refills the cache between ticks, warm_step must
    not thrash: the first re-warm pane that triggers an eviction stops
    the pass and drops the queue (further prefills would only evict
    resident states, repeating every tick)."""
    gw = _gateway(cache_entries=8, rewarm_budget=4)
    now = 5 * DAY + 100
    users = list(range(8))
    gw.submit_many([Request(user=u, now=now) for u in users])
    gw.flush(now)
    gw.observe_many(users, np.arange(8) + 20, np.full(8, now + 500))
    gw.tick(now + DAY)  # roll: all 8 invalidated, 4 rewarmed (budget)
    assert gw.stats()["rollover"]["pending_rewarm"] == 4
    # live traffic refills the cache to its 8-entry budget
    gw.submit_many([Request(user=u, now=now + DAY + 10)
                    for u in (20, 21, 22, 23)])
    gw.flush(now + DAY + 10)
    assert len(gw.cache) == 8
    ev0 = gw.cache.evictions
    gw.tick(now + DAY + 20)  # warm_step hits a full cache
    assert gw.cache.evictions <= ev0 + gw.engine.scfg.max_batch
    assert gw.stats()["rollover"]["pending_rewarm"] == 0  # queue dropped
    gw.tick(now + DAY + 30)  # and subsequent ticks do not churn
    assert gw.cache.evictions <= ev0 + gw.engine.scfg.max_batch


def test_amortized_catchup_builds_every_retained_boundary():
    """A multi-boundary gap in budget mode matches the synchronous
    contract: every missed boundary inside retention is built in order
    (frozen arrays and all), so time-travel reads do not silently take
    the recompute path only because the build was amortized."""
    inc = _gateway(snapshot_build_budget=50)
    sync = _gateway()
    now = 5 * DAY + 100
    for gw in (inc, sync):
        gw.submit_many([Request(user=u, now=now) for u in range(4)])
        gw.flush(now)
    t = now + 3 * DAY  # offline across three boundaries
    sync.tick(t)
    for _ in range(60):
        inc.tick(t)
        if inc.injector.generation(t) == 8 * DAY \
                and inc.stats()["rollover"]["pending_build_users"] == 0:
            break
    a, b = inc.injector.batch, sync.injector.batch
    assert a._snapshot_times == b._snapshot_times
    assert sorted(a._snapshots) == sorted(b._snapshots)  # same frozen set
    for g in (6 * DAY, 7 * DAY, 8 * DAY):
        for x, y in zip(a._snapshots[g], b._snapshots[g]):
            np.testing.assert_array_equal(x, y)
    assert inc.stats()["rollover"]["rollovers"] == 3  # gen by gen


def test_amortized_catchup_never_serves_register_only_generation():
    """Gap longer than retention: boundaries past retention register
    WITHOUT arrays, but only once the first real build installs — if
    they registered up front, the serving generation would resolve to a
    register-only (recompute-on-read) boundary for the whole build
    window and cached states would key to a non-frozen generation,
    violating the cache-key invariant."""
    inj = _injector(retention=2)
    gw = Gateway(_ENGINE, inj, ServerConfig(
        slate_len=3, cache_entries=64, snapshot_build_budget=3))
    now = 5 * DAY + 100
    gw.submit_many([Request(user=u, now=now) for u in range(4)])
    gw.flush(now)
    gen_a = gw.injector.generation(now)
    assert gen_a == 5 * DAY
    gw.observe_many([0, 1], [7, 8], [now + 500] * 2)
    t = now + 5 * DAY  # five boundaries behind (latest due: day 10),
    #                    retention 2 -> days 6..8 are skip targets
    st = gw.injector.batch
    latest_due = st.latest_due_boundary(t)
    assert latest_due == 10 * DAY
    ticks = 0
    # mid-build: serving always reads a FROZEN generation — never a
    # register-only one — while the catch-up builds 9 then 10
    while gw.injector.generation(t) != latest_due \
            or gw.stats()["rollover"]["pending_build_users"] > 0:
        gw.tick(t)
        g = gw.injector.generation(t)
        assert g == gen_a or g in st._snapshots, \
            f"serving a register-only gen {g}"
        ticks += 1
        assert ticks < 200
    assert gw.injector.generation(t) == 10 * DAY
    # the skipped boundaries registered (array-less) once the build
    # landed, so the time-travel grid matches the synchronous job's
    sync = Gateway(_ENGINE, _injector(retention=2), ServerConfig(
        slate_len=3, cache_entries=64))
    sync.observe_many([0, 1], [7, 8], [now + 500] * 2)
    sync.tick(t)
    assert st._snapshot_times == sync.injector.batch._snapshot_times
    assert sorted(st._snapshots) == sorted(sync.injector.batch._snapshots)


def test_warm_step_rebuilds_invalidated_users():
    """rewarm_budget: after a rollover, tick() re-prefills invalidated
    users between panes (MRU-first), so the first post-rollover requests
    for them are hits again."""
    gw = _gateway(rewarm_budget=2)
    now = 5 * DAY + 100
    users = list(range(8))
    gw.submit_many([Request(user=u, now=now) for u in users])
    gw.flush(now)
    its = np.arange(8) + 20
    gw.observe_many(users, its, np.full(8, now + 500))  # everyone changes
    gw.tick(now + DAY)          # roll: all stale-retained; rewarm 2
    gen_b = gw.injector.generation(now + DAY)
    # changed users' old-gen entries are retained (first-victim), not
    # purged — so 8 retained + 2 rewarmed new-gen entries are resident
    assert gw.stats()["rollover"]["retained"] == 8
    assert gw.stats()["rollover"]["invalidated"] == 0
    assert gw.stats()["rollover"]["rebuilt"] == 2
    assert gw.stats()["rollover"]["pending_rewarm"] == 6
    assert len(gw.cache) == 10
    # MRU-first: users 7 and 6 were the most recently used entries
    assert (7, (gen_b, 0)) in gw.cache and (6, (gen_b, 0)) in gw.cache
    for _ in range(3):
        gw.tick(now + DAY + 60)
    assert len(gw.cache) == 16
    assert gw.stats()["rollover"]["pending_rewarm"] == 0
    h0 = gw.cache.hits
    gw.submit_many([Request(user=u, now=now + DAY + 120) for u in users])
    gw.flush(now + DAY + 120)
    assert gw.cache.hits - h0 == 8  # the miss storm was pre-drained

    # rewarmed states are real: results match a never-rolled oracle
    oracle = _gateway()
    oracle.observe_many(users, its, np.full(8, now + 500))
    tk = oracle.submit_many(
        [Request(user=u, now=now + DAY + 120) for u in users])
    oracle.flush(now + DAY + 120)
    tk2 = gw.submit_many(
        [Request(user=u, now=now + DAY + 120) for u in users])
    gw.flush(now + DAY + 120)
    for a, b in zip(tk, tk2):
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)


def test_amortized_build_rolls_without_stalling_ticks():
    """snapshot_build_budget: a due boundary no longer materializes the
    full plane inside one clock call — the build advances budget-bounded
    across ticks while serving keeps reading the previous generation,
    and the results after the (delayed) roll are bitwise what the
    synchronous build serves."""
    inc = _gateway(snapshot_build_budget=3)
    sync = _gateway()
    now = 5 * DAY + 100
    users = list(range(8))
    for gw in (inc, sync):
        gw.submit_many([Request(user=u, now=now) for u in users])
        gw.flush(now)
        gw.observe_many(users, np.arange(8) + 20, np.full(8, now + 500))
    gen_a = inc.injector.generation(now)

    t = now + DAY  # past the 6*DAY boundary
    inc.tick(t)    # starts the builder, one 3-user slice
    st = inc.stats()["rollover"]
    # the 8 observed users changed > one 3-user slice: the generation
    # must NOT have rolled yet — the build is in flight and serving
    # continues on generation A
    assert st["pending_build_users"] > 0
    assert inc.injector.generation(t) == gen_a
    tk = inc.submit_many([Request(user=0, now=t)])
    inc.flush(t)
    assert tk[0].response.telemetry.generation == gen_a
    ticks = 0
    while inc.stats()["rollover"]["pending_build_users"] > 0 \
            or inc.injector.generation(t) == gen_a:
        inc.tick(t)
        ticks += 1
        assert ticks < 100
    assert inc.injector.generation(t) == 6 * DAY
    assert inc.stats()["rollover"]["build_steps"] >= 2

    sync.tick(t)   # the synchronous oracle rolls in one call
    for gw in (inc, sync):
        gw._served = gw.submit_many(
            [Request(user=u, now=t + 10) for u in users])
        gw.flush(t + 10)
    for a, b in zip(inc._served, sync._served):
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)
    # and the installed generation is bitwise the oracle's
    for a, b in zip(inc.injector.batch._snapshots[6 * DAY],
                    sync.injector.batch._snapshots[6 * DAY]):
        np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# Gateway ingestion fixes
# ----------------------------------------------------------------------

def test_observe_many_validates_before_any_write():
    """A rejected event batch must mutate NEITHER store. The regression:
    batch.extend ran (and validated, and wrote) before realtime.extend's
    range check fired, leaving the log and the ring silently diverged
    when the realtime store is the stricter one."""
    # realtime store covers fewer users than the batch log — the exact
    # shape of the original bug
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=40, feature_len=FEATURE_LEN))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=20, buffer_len=8, ingest_latency=0))
    inj = FeatureInjector(
        InjectionConfig(policy="inject", feature_len=FEATURE_LEN),
        store, rts)
    gw = Gateway(_ENGINE, inj, ServerConfig(slate_len=3, cache_entries=64))

    n_log = len(store._log)
    n_rt = rts.events_ingested
    with pytest.raises(IndexError, match="out of range"):
        gw.observe_many([1, 30, 2], [5, 6, 7], [100, 100, 100])
    assert len(store._log) == n_log          # the log absorbed nothing
    assert rts.events_ingested == n_rt       # the ring absorbed nothing

    with pytest.raises(IndexError, match="out of range"):
        gw.observe((30, 5, 100))             # single-event path too
    assert len(store._log) == n_log and rts.events_ingested == n_rt

    with pytest.raises(ValueError, match="parallel arrays"):
        gw.observe_many([1, 2], [5], [100, 100])
    assert len(store._log) == n_log and rts.events_ingested == n_rt

    gw.observe_many([1, 19], [5, 6], [100, 100])  # in range for both
    assert len(store._log) == n_log + 2
    assert rts.events_ingested == n_rt + 2


def test_observe_many_out_of_range_rejected_cleanly():
    """Same-n_users stores: an out-of-range user is rejected by the
    gateway before either store sees the batch."""
    gw = _gateway()
    n_log = len(gw.injector.batch._log)
    with pytest.raises(IndexError, match="out of range"):
        gw.observe_many([1, N_USERS], [5, 6], [100, 100])
    assert len(gw.injector.batch._log) == n_log
    assert gw.injector.realtime.events_ingested == 1500  # seed only


def test_queue_delay_clamped_under_legacy_rewind():
    """The deprecated serve() shim rewinds the gateway clock for
    non-monotonic replays; a request left pending from a later wave
    would record served_at < now. queue_delay clamps at 0 instead of
    polluting the stats() percentiles with negative delays."""
    srv = InjectionServer(_ENGINE, _injector(), ServerConfig(
        slate_len=3, cache_entries=64))
    gw = srv.gateway
    t0, t1 = 5 * DAY + 100, 5 * DAY + 900
    # a request arrives at t1 and queues (pane of 4 not full)
    pending = gw.submit(Request(user=7, now=t1))
    assert not pending.done
    # ...then a legacy replay serves an older wave: the shim rewinds the
    # clock to t0 and its flush drains the pending t1 request too
    with pytest.warns(DeprecationWarning):
        srv.serve(np.array([1, 2]), t0)
    assert pending.done
    assert pending.response.telemetry.queue_delay == 0   # not -800
    st = gw.stats()["queue_delay"]
    assert st["p50"] >= 0.0 and st["max"] >= 0
    assert min(gw._queue_delays) >= 0
