"""Two-stage pipeline behaviour + sharding-rule structure checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.pipeline import (PipelineConfig, RecommenderPlatform,
                                 _serve_core, items_to_tokens)
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
from repro.core.ab import default_sim_model
from repro.models.model import init_params

N_ITEMS = 200


@pytest.fixture(scope="module")
def setup():
    cfg = default_sim_model(N_ITEMS)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    pcfg = PipelineConfig(n_items=N_ITEMS, slate_size=5, n_candidates=32,
                          recall_primary=24, recall_popular=8, serve_batch=4)
    return cfg, params, pcfg


def test_slate_shape_and_range(setup):
    cfg, params, pcfg = setup
    toks = jnp.asarray(np.random.RandomState(0).randint(1, N_ITEMS + 1, (4, 16)))
    valid = jnp.ones((4, 16), jnp.int32)
    pop = jnp.zeros((N_ITEMS,), jnp.float32)
    slate, cand = _serve_core(params, toks, valid, pop, cfg=cfg, pcfg=pcfg)
    assert slate.shape == (4, 5)
    assert (np.asarray(slate) >= 0).all() and (np.asarray(slate) < N_ITEMS).all()
    # slate has no duplicate items per row
    for row in np.asarray(slate):
        assert len(set(row.tolist())) == len(row)


def test_watched_items_excluded(setup):
    cfg, params, pcfg = setup
    watched = [3, 7, 11, 19]
    toks = jnp.asarray([[i + 1 for i in watched] * 4])  # (1,16)
    valid = jnp.ones((1, 16), jnp.int32)
    pop = jnp.zeros((N_ITEMS,), jnp.float32)
    slate, _ = _serve_core(params, toks, valid, pop, cfg=cfg, pcfg=pcfg)
    assert not set(np.asarray(slate)[0].tolist()) & set(watched)


def test_popularity_recaller_contributes(setup):
    cfg, params, pcfg = setup
    toks = jnp.zeros((1, 16), jnp.int32)
    valid = jnp.zeros((1, 16), jnp.int32)  # cold user: no history signal
    pop = jnp.zeros((N_ITEMS,), jnp.float32).at[42].set(100.0)
    slate, cand = _serve_core(params, toks, valid, pop, cfg=cfg, pcfg=pcfg)
    assert 42 in np.asarray(cand)[0].tolist()


def test_platform_end_to_end_arms_differ():
    """Same request, fresh events present: inject-arm slate may differ from
    control — and MUST use the realtime buffer to do so."""
    cfg = default_sim_model(N_ITEMS)
    params = init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    pcfg = PipelineConfig(n_items=N_ITEMS, slate_size=5, recall_primary=24,
                          recall_popular=8, serve_batch=4)
    pop = np.full((N_ITEMS,), 1.0 / N_ITEMS)

    plats = {}
    for policy in ("batch", "inject"):
        store = BatchFeatureStore(FeatureStoreConfig(n_users=4, feature_len=16))
        rt = RealtimeFeatureService(RealtimeConfig(n_users=4, buffer_len=8,
                                                   ingest_latency=0))
        inj = FeatureInjector(InjectionConfig(policy=policy, feature_len=16),
                              store, rt)
        plat = RecommenderPlatform(pcfg, cfg, params, inj, pop,
                                   run_batch_jobs=False)
        for t, it in [(100, 1), (200, 2)]:
            store.append(0, it, t)
        store.run_snapshot(86400)
        rt.ingest(0, 50, ts=86400 + 10)
        plats[policy] = plat

    users, tss = np.array([0]), np.array([86400 + 100])
    s_ctrl = plats["batch"].serve(users, tss)
    s_inj = plats["inject"].serve(users, tss)
    assert s_ctrl.shape == s_inj.shape == (1, 5)
    assert plats["inject"].injector.merge_calls == 1
    assert plats["batch"].injector.merge_calls == 0
    # the injected arm must exclude the just-watched item 50
    assert 50 not in s_inj[0].tolist()


def test_items_to_tokens():
    items = np.array([[4, 0, 9]])
    valid = np.array([[1, 0, 1]])
    np.testing.assert_array_equal(items_to_tokens(items, valid),
                                  [[5, 0, 10]])
