"""Paged device-resident state pool: bitwise exactness + scheduling.

Three layers of guarantees, each tested here:

1. **Pool mechanics** — one-hot gather/scatter round-trips every state
   leaf (float, int, bool) bit-for-bit, untouched slots stay untouched,
   and short panes are assembled exactly.
2. **Slot table** — LRU + free-list allocation, pin-aware eviction, and
   the PR 5 warm-handoff ``rekey_generation`` renaming table keys
   without a single device-array operation.
3. **Serving equivalence** — the pooled Gateway serves slates/scores
   bitwise equal to the host-LRU Gateway (including under slot-pressure
   eviction and generation rollover), and the continuous scheduler
   (``max_wait=0``, one submit per arrival) is bitwise equal to the
   wave path for every policy, on a plain engine and a 1x1 mesh engine.

The zero-collective claim for the compiled gather/scatter is asserted
from HLO by ``tools/slot_pool_check.py`` (subprocess, forced 8-device
CPU topology) — not here.
"""
import dataclasses
import json

import numpy as np
import jax
import pytest

from conftest import DAY, N_ITEMS, N_USERS
from conftest import ingest as _ingest
from conftest import make_gateway, seeded_injector, tiny_engine
from repro.serving.api import GatewayStats, Request, RolloverStats
from repro.serving.pool import DeviceStatePool, PagedStateCache

_ENGINES = {  # the conftest session-shared tiny platform, both paths
    "plain": tiny_engine(),
    "mesh1x1": tiny_engine(mesh1x1=True),
}


def _injector(policy="inject", seed=0):
    return seeded_injector(policy, seed=seed)


def _gateway(engine, pool_slots=None, max_wait=None, cache_entries=64,
             injector=None):
    return make_gateway(engine=engine, injector=injector,
                        cache_entries=cache_entries,
                        pool_slots=pool_slots, max_wait=max_wait)


def _prefill_pane(engine, seed=0):
    """A real prefill state for max_batch rows of random histories."""
    rng = np.random.RandomState(seed)
    seqs = [rng.randint(1, N_ITEMS, rng.randint(4, 20)).tolist()
            for _ in range(engine.scfg.max_batch)]
    toks, valid = engine.pad_tokens(seqs, engine.scfg.prefill_len)
    return engine.prefill(toks, valid)


def _assert_state_rows_equal(gathered, last, state, rows):
    """Row ``i`` of the gathered pane == row ``rows[i]`` of ``state``,
    bitwise, for every leaf (including bool valid and int32 next_pos)."""
    idx = np.asarray(rows)
    jax.tree.map(
        lambda g, s: np.testing.assert_array_equal(
            np.asarray(g), np.asarray(s)[:, idx]),
        gathered["caches"], state["caches"])
    np.testing.assert_array_equal(np.asarray(gathered["valid"]),
                                  np.asarray(state["valid"])[idx])
    np.testing.assert_array_equal(np.asarray(gathered["next_pos"]),
                                  np.asarray(state["next_pos"])[idx])
    assert gathered["logits"] is None
    np.testing.assert_array_equal(np.asarray(last),
                                  np.asarray(state["logits"])[idx, -1, :])


# ----------------------------------------------------------------------
# 1. Pool mechanics
# ----------------------------------------------------------------------

@pytest.mark.parametrize("engine_key", sorted(_ENGINES))
def test_pool_roundtrip_bitwise(engine_key):
    eng = _ENGINES[engine_key]
    pool = DeviceStatePool(eng, 8)
    state = _prefill_pane(eng)
    pool.scatter(state, [5, 0, 7, 2])
    gathered, last = pool.gather([5, 0, 7, 2])
    _assert_state_rows_equal(gathered, last, state, [0, 1, 2, 3])
    # dtype preservation through the int32 contraction path
    assert np.asarray(gathered["valid"]).dtype == np.bool_
    assert np.asarray(gathered["next_pos"]).dtype == np.int32
    assert pool.gathers == 1 and pool.scatters == 1


@pytest.mark.parametrize("engine_key", sorted(_ENGINES))
def test_pool_scatter_leaves_other_slots_untouched(engine_key):
    eng = _ENGINES[engine_key]
    pool = DeviceStatePool(eng, 8)
    a, b = _prefill_pane(eng, seed=1), _prefill_pane(eng, seed=2)
    pool.scatter(a, [0, 1, 2, 3])
    pool.scatter(b, [4, 5])          # short writeback: pane rows 0,1 only
    ga, la = pool.gather([0, 1, 2, 3])
    _assert_state_rows_equal(ga, la, a, [0, 1, 2, 3])   # a intact
    gb, lb = pool.gather([4, 5, 4, 5])                  # padded assembly
    _assert_state_rows_equal(gb, lb, b, [0, 1, 0, 1])


def test_pool_overwrite_slot():
    eng = _ENGINES["plain"]
    pool = DeviceStatePool(eng, 4)
    a, b = _prefill_pane(eng, seed=1), _prefill_pane(eng, seed=2)
    pool.scatter(a, [0, 1, 2, 3])
    pool.scatter(b, [1])             # overwrite one slot in place
    g, last = pool.gather([0, 1, 2, 3])
    _assert_state_rows_equal(
        {"caches": jax.tree.map(lambda x: np.asarray(x)[:, [0]], g["caches"]),
         "valid": np.asarray(g["valid"])[[0]],
         "next_pos": np.asarray(g["next_pos"])[[0]], "logits": None},
        np.asarray(last)[[0]], a, [0])
    _assert_state_rows_equal(
        {"caches": jax.tree.map(lambda x: np.asarray(x)[:, [1]], g["caches"]),
         "valid": np.asarray(g["valid"])[[1]],
         "next_pos": np.asarray(g["next_pos"])[[1]], "logits": None},
        np.asarray(last)[[1]], b, [0])


def test_pool_rejects_fewer_slots_than_max_batch():
    with pytest.raises(ValueError, match="pool_slots"):
        DeviceStatePool(_ENGINES["plain"], 2)
    with pytest.raises(ValueError, match="pool_slots"):
        _gateway(_ENGINES["plain"], pool_slots=2)


def test_pool_rejects_oversized_pane():
    pool = DeviceStatePool(_ENGINES["plain"], 4)
    with pytest.raises(ValueError, match="max_batch"):
        pool.gather([0, 1, 2, 3, 0])


# ----------------------------------------------------------------------
# 2. Slot table (PagedStateCache)
# ----------------------------------------------------------------------

def _table(n_slots=4):
    return PagedStateCache(DeviceStatePool(_ENGINES["plain"], n_slots))


def test_slot_table_allocates_then_evicts_lru():
    c = _table(4)
    slots = [c.admit(u, 100, pinned=set()) for u in range(4)]
    assert sorted(slots) == [0, 1, 2, 3] and len(c._free) == 0
    assert c.lookup(1, 100) == slots[1]          # touch 1 -> MRU
    s4 = c.admit(9, 100, pinned=set())           # evicts user 0 (LRU)
    assert s4 == slots[0] and c.evictions == 1
    assert c.lookup(0, 100) is None
    assert c.lookup(1, 100) == slots[1]


def test_slot_table_pinned_slots_never_evicted():
    c = _table(4)
    slots = {u: c.admit(u, 100, pinned=set()) for u in range(4)}
    pinned = {slots[0], slots[1]}
    s = c.admit(7, 100, pinned=pinned)           # LRU would be user 0
    assert s == slots[2]                         # first UNPINNED LRU
    with pytest.raises(RuntimeError, match="pinned"):
        c.admit(8, 100, pinned={0, 1, 2, 3})


def test_slot_table_scratch_returns_to_free_list():
    c = _table(4)
    s = c.alloc_scratch(pinned=set())
    assert len(c._free) == 3 and len(c) == 0     # scratch is never an entry
    c.free_scratch(s)
    assert len(c._free) == 4


def test_slot_table_invalidate_frees_slots():
    c = _table(4)
    for u in range(3):
        c.admit(u, 100, pinned=set())
    c.admit(5, 200, pinned=set())
    assert c.invalidate_except(200) == 3
    assert len(c) == 1 and len(c._free) == 3 and c.invalidations == 3


def test_slot_table_rekey_renames_without_touching_device_state():
    """PR 5 warm handoff on the pool: rekey is pure slot-table surgery —
    unchanged users keep their slot under the new generation, changed
    users' slots go back on the free list, and the device pool sees
    zero gather/scatter traffic."""
    c = _table(4)
    pool = c.pool
    slots = {u: c.admit(u, 100, pinned=set()) for u in range(4)}
    g0, s0 = pool.gathers, pool.scatters
    buf_ids = [id(x) for x in jax.tree.leaves(pool.caches)]
    kept, dropped = c.rekey_generation(100, 200, changed=[1, 3])
    assert (kept, dropped) == (2, 2) and c.rekeys == 2
    assert c.lookup(0, 200) == slots[0] and c.lookup(2, 200) == slots[2]
    assert c.lookup(1, 200) is None and (1, 100) not in c
    assert sorted(c._free) == sorted([slots[1], slots[3]])
    assert (pool.gathers, pool.scatters) == (g0, s0)
    assert [id(x) for x in jax.tree.leaves(pool.caches)] == buf_ids


def test_slot_table_byte_accounting_is_structural():
    """Fixed slots = fixed bytes: the pool's byte accounting can't drift
    by construction — always exactly entries * slot_nbytes."""
    c = _table(4)
    for u in range(3):
        c.admit(u, 100, pinned=set())
        assert c.bytes_per_shard == len(c) * c.pool.slot_nbytes
    assert c.byte_budget == 4 * c.pool.slot_nbytes
    st = c.stats()
    assert st["slots"] == 4 and st["free_slots"] == 1
    assert st["slot_bytes"] == c.pool.slot_nbytes


# ----------------------------------------------------------------------
# 3. Serving equivalence
# ----------------------------------------------------------------------

def _wave(gw, reqs, now):
    tickets = gw.submit_many(list(reqs))
    gw.flush(now)
    assert all(t.done for t in tickets)
    return tickets


def test_pooled_gateway_bitwise_equals_host_lru():
    """Same traffic through the pooled and host-LRU gateways — slates and
    scores bitwise equal, identical hit/miss/eviction/rekey telemetry —
    across slot-pressure eviction AND a generation rollover."""
    eng = _ENGINES["plain"]
    pooled = _gateway(eng, pool_slots=6, injector=_injector())
    host = _gateway(eng, cache_entries=6, injector=_injector())
    rng = np.random.RandomState(1)
    now = 5 * DAY + 100
    waves = [rng.randint(0, N_USERS, 9) for _ in range(3)]
    waves.append(waves[0])                       # revisit evicted users
    for users in waves:
        # fresh events for only HALF the wave: the quiet half stays
        # certifiably unchanged, so the rollover exercises rekey (warm
        # handoff) and invalidation side by side
        ev_users = users[: len(users) // 2]
        it = rng.randint(0, N_ITEMS, len(ev_users))
        _ingest(pooled, ev_users, it, np.full(len(ev_users), now - 30))
        _ingest(host, ev_users, it, np.full(len(ev_users), now - 30))
        tp = _wave(pooled, [Request(user=int(u), now=now) for u in users],
                   now)
        th = _wave(host, [Request(user=int(u), now=now) for u in users],
                   now)
        for a, b in zip(tp, th):
            np.testing.assert_array_equal(a.response.slate, b.response.slate)
            np.testing.assert_array_equal(a.response.scores,
                                          b.response.scores)
        now += 300
    # rollover wave: warm-handoff rekey must fire on both cache kinds
    now = 6 * DAY + 100
    users = waves[0]
    tp = _wave(pooled, [Request(user=int(u), now=now) for u in users], now)
    th = _wave(host, [Request(user=int(u), now=now) for u in users], now)
    for a, b in zip(tp, th):
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)
    for k in ("hits", "misses", "evictions", "rekeys", "invalidations"):
        assert getattr(pooled.cache, k) == getattr(host.cache, k), k
    assert pooled.cache.evictions > 0 and pooled.cache.rekeys > 0
    assert pooled.pool.gathers > 0 and pooled.pool.scatters > 0


_POLICY_WAVES = [
    [None, "batch", "inject", "fresh"],
    ["inject", "inject", "inject", "inject"],
    ["fresh", None, "batch", None],
]


@pytest.mark.parametrize("engine_key", sorted(_ENGINES))
@pytest.mark.parametrize("pooled", [False, True],
                         ids=["host-lru", "paged-pool"])
def test_continuous_trickle_bitwise_equals_wave(engine_key, pooled):
    """Mid-pane admission property: a trickle of single submits through
    the continuous scheduler (max_wait=0 — every arrival served
    immediately in a padded partial pane) produces responses bitwise
    equal to the same requests batched through the wave path, for every
    policy, with and without the pool, on plain and 1x1-mesh engines."""
    eng = _ENGINES[engine_key]
    slots = 16 if pooled else None
    wave = _gateway(eng, pool_slots=slots, injector=_injector())
    trickle = _gateway(eng, pool_slots=slots, max_wait=0,
                       injector=_injector())
    rng = np.random.RandomState(2)
    now = 5 * DAY + 100
    for pols in _POLICY_WAVES:
        users = rng.randint(0, N_USERS, len(pols))
        it = rng.randint(0, N_ITEMS, len(pols))
        _ingest(wave, users, it, np.full(len(pols), now - 30))
        _ingest(trickle, users, it, np.full(len(pols), now - 30))
        wt = _wave(wave, [Request(user=int(u), now=now, policy=p)
                          for u, p in zip(users, pols)], now)
        tt = []
        for u, p in zip(users, pols):
            t = trickle.submit(Request(user=int(u), now=now, policy=p))
            assert t.done                        # served on arrival
            tt.append(t)
        assert len(trickle.poll()) == len(pols)  # streamed out exactly once
        for a, b in zip(wt, tt):
            assert a.response.telemetry.policy == b.response.telemetry.policy
            np.testing.assert_array_equal(a.response.slate, b.response.slate)
            np.testing.assert_array_equal(a.response.scores,
                                          b.response.scores)
        now += 300
    # the trickle side really ran one pane per request
    assert trickle.stats()["panes"] == sum(map(len, _POLICY_WAVES))


def test_poll_claims_once_and_drain_flushes():
    gw = _gateway(_ENGINES["plain"])
    now = 5 * DAY + 100
    tickets = [gw.submit(Request(user=u, now=now)) for u in range(3)]
    assert gw.poll() == []                       # nothing served yet
    assert not tickets[0].done and gw.pending == 3
    done = gw.drain(now)                         # flush + claim
    assert {t.request_id for t in done} == {t.request_id for t in tickets}
    assert gw.poll() == [] and gw.pending == 0   # claimed exactly once
    t = gw.submit(Request(user=9, now=now + 10))
    assert [x.request_id for x in gw.drain(now + 10)] == [t.request_id]


def test_gateway_stats_typed_surface():
    gw = _gateway(_ENGINES["plain"], pool_slots=8)
    now = 5 * DAY + 100
    _wave(gw, [Request(user=u, now=now) for u in range(4)], now)
    st = gw.stats()
    assert isinstance(st, GatewayStats)
    assert isinstance(st.rollover, RolloverStats)
    with pytest.raises(dataclasses.FrozenInstanceError):
        st.requests = 0
    # dict-era compat: subscript access keeps old call sites working
    assert st["requests"] == st.requests == 4
    assert st["rollover"]["rollovers"] == st.rollover.rollovers
    assert st["paths"]["inject"] >= 0 and "window" in st["queue_delay"]
    # as_dict() recurses and is JSON-serializable (benchmarks dump it)
    d = st.as_dict()
    assert d["rollover"] == dataclasses.asdict(st.rollover)
    assert json.loads(json.dumps(d))["cache"]["slots"] == 8
