"""Core ITFI behaviour: batch staleness, realtime visibility, injection
semantics (paper §III)."""
import numpy as np

from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService

DAY = 86400


def _store(n_users=4, k=8):
    return BatchFeatureStore(FeatureStoreConfig(n_users=n_users,
                                                feature_len=k))


def test_batch_features_are_stale_until_snapshot():
    st = _store()
    st.append(0, 11, ts=100)
    st.run_snapshot(DAY)          # midnight job
    st.append(0, 22, ts=DAY + 50)  # today's watch — invisible until tomorrow
    items, ts, valid = st.lookup(np.array([0]), now=DAY + 100)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [11], "daily snapshot must not see same-day events"
    st.run_snapshot(2 * DAY)
    items, ts, valid = st.lookup(np.array([0]), now=2 * DAY + 1)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [11, 22]


def test_snapshot_scheduler_idempotent():
    st = _store()
    st.append(1, 5, ts=10)
    st.maybe_run_due_snapshots(DAY + 5)
    st.maybe_run_due_snapshots(DAY + 9)
    assert len(st._snapshot_times) == 1
    st.maybe_run_due_snapshots(3 * DAY + 1)  # catches up day 2 and 3
    assert st._snapshot_times == [DAY, 2 * DAY, 3 * DAY]


def test_scheduler_catchup_no_prior_snapshot():
    """With no snapshot yet, catch-up starts at the first period boundary
    after the earliest event — not just the single most recent due one."""
    st = _store()
    st.append(0, 5, ts=10)
    st.maybe_run_due_snapshots(3 * DAY + 1)
    assert st._snapshot_times == [DAY, 2 * DAY, 3 * DAY]


def test_scheduler_catchup_multiple_missed_periods():
    st = _store()
    st.append(0, 5, ts=10)
    st.maybe_run_due_snapshots(DAY + 5)
    assert st._snapshot_times == [DAY]
    st.append(0, 7, ts=DAY + 50)
    st.maybe_run_due_snapshots(4 * DAY + 5)  # three missed periods
    assert st._snapshot_times == [DAY, 2 * DAY, 3 * DAY, 4 * DAY]
    # every intermediate generation is materialized, not just the last:
    # the 2*DAY generation must already contain the DAY+50 event
    items, _, valid = st.lookup(np.array([0]), now=2 * DAY + 1)
    assert [int(i) for i, v in zip(items[0], valid[0]) if v] == [5, 7]


def test_scheduler_nonzero_offset():
    st = BatchFeatureStore(FeatureStoreConfig(
        n_users=2, feature_len=8, snapshot_offset=3600))
    st.append(0, 1, ts=100)
    st.maybe_run_due_snapshots(2 * DAY + 4000)
    assert st._snapshot_times == [3600, DAY + 3600, 2 * DAY + 3600]


def test_scheduler_empty_log_registers_latest_boundary():
    st = _store()
    st.maybe_run_due_snapshots(2 * DAY + 7)
    assert st._snapshot_times == [2 * DAY]
    _, _, valid = st.lookup(np.array([0]), now=2 * DAY + 8)
    assert valid.sum() == 0


def test_scheduler_not_due_yet_runs_nothing():
    st = _store()
    st.append(0, 1, ts=10)
    st.maybe_run_due_snapshots(DAY - 1)  # first boundary not reached
    assert st._snapshot_times == []


def test_lookup_at_cutoff_matches_snapshot():
    st = _store()
    for t, it in [(10, 1), (20, 2), (DAY + 5, 3)]:
        st.append(0, it, t)
    st.run_snapshot(DAY)
    a = st.lookup(np.array([0]), now=DAY + 50)
    b = st.lookup_at_cutoff(np.array([0]), cutoff=DAY)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_realtime_ingest_latency_and_retention():
    rt = RealtimeFeatureService(RealtimeConfig(
        n_users=2, buffer_len=8, ingest_latency=30, retention=3600))
    rt.ingest(0, 7, ts=1000)
    # not yet visible (stream delay)
    items, _, valid = rt.lookup(np.array([0]), now=1010)
    assert valid.sum() == 0
    items, _, valid = rt.lookup(np.array([0]), now=1030)
    assert valid.sum() == 1 and items[0, -1] == 7
    # falls out of the short retention window
    _, _, valid = rt.lookup(np.array([0]), now=1000 + 3601)
    assert valid.sum() == 0


def test_realtime_bounded_buffer():
    rt = RealtimeFeatureService(RealtimeConfig(n_users=1, buffer_len=4,
                                               ingest_latency=0))
    for i in range(10):
        rt.ingest(0, i, ts=100 + i)
    items, _, valid = rt.lookup(np.array([0]), now=1000)
    got = [int(x) for x, v in zip(items[0], valid[0]) if v]
    assert got == [6, 7, 8, 9]  # only the freshest buffer_len


def _wired(policy, k=8):
    st = _store(k=k)
    rt = RealtimeFeatureService(RealtimeConfig(n_users=4, buffer_len=4,
                                               ingest_latency=30))
    inj = FeatureInjector(InjectionConfig(policy=policy, feature_len=k), st, rt)
    return st, rt, inj


def test_injection_merges_batch_and_fresh():
    st, rt, inj = _wired("inject")
    st.append(0, 1, ts=100)
    st.append(0, 2, ts=200)
    st.run_snapshot(DAY)
    rt.ingest(0, 3, ts=DAY + 100)
    items, ts, valid = inj.features(np.array([0]), now=DAY + 200)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [1, 2, 3], "fresh event must be appended after batch"


def test_injection_dedups_rewatch():
    """Re-watching a batch-history item keeps only the fresh occurrence."""
    st, rt, inj = _wired("inject")
    for t, it in [(100, 1), (200, 2), (300, 3)]:
        st.append(0, it, t)
    st.run_snapshot(DAY)
    rt.ingest(0, 2, ts=DAY + 10)  # re-watch item 2
    items, ts, valid = inj.features(np.array([0]), now=DAY + 100)
    got = [(int(i), int(t)) for i, t, v in
           zip(items[0], ts[0], valid[0]) if v]
    assert got == [(1, 100), (3, 300), (2, DAY + 10)]


def test_control_policy_ignores_fresh():
    st, rt, inj = _wired("batch")
    st.append(0, 1, ts=100)
    st.run_snapshot(DAY)
    rt.ingest(0, 9, ts=DAY + 10)
    items, _, valid = inj.features(np.array([0]), now=DAY + 100)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [1]


def test_staleness_override_for_latency_ablation():
    st, rt, inj = _wired("batch")
    inj = FeatureInjector(InjectionConfig(policy="batch", feature_len=8,
                                          staleness=3600), st, rt)
    st.append(0, 1, ts=100)
    st.append(0, 2, ts=DAY + 100)  # 2h before the request below
    items, _, valid = inj.features(np.array([0]), now=DAY + 100 + 7200)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [1, 2], "1h-stale pipeline must see the 2h-old event"


def test_at_least_once_redelivery_harmless():
    """Stream redelivery (at-least-once) must not duplicate history items."""
    st, rt, inj = _wired("inject")
    st.append(0, 1, ts=100)
    st.run_snapshot(DAY)
    for _ in range(3):  # redelivered 3x
        rt.ingest(0, 5, ts=DAY + 10)
    items, _, valid = inj.features(np.array([0]), now=DAY + 100)
    got = [int(i) for i, v in zip(items[0], valid[0]) if v]
    assert got == [1, 5]
