"""Training substrate: loss descends, checkpoints round-trip, optimizer
semantics."""
import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.losses import cross_entropy
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state)
from repro.training.train_loop import TrainConfig, make_train_step, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                   tie_embeddings=True)


def _copy_batch(rng, b=16, s=12):
    """Learnable toy task: predict the previous token."""
    toks = rng.randint(1, TINY.vocab_size, (b, s)).astype(np.int32)
    labels = np.concatenate([toks[:, :1], toks[:, :-1]], axis=1)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def test_loss_decreases():
    rng = np.random.RandomState(0)
    params = init_params(TINY, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=150),
                       remat=False, param_dtype=jnp.float32)
    out = train(TINY, tcfg, params, opt,
                (_copy_batch(rng) for _ in range(150)), log=None)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first * 0.65, (first, last)


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation must match the single-batch gradient."""
    rng = np.random.RandomState(1)
    params = init_params(TINY, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _copy_batch(rng, b=8)
    outs = {}
    for nm in (1, 4):
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3), microbatches=nm,
                           remat=False, param_dtype=jnp.float32)
        step = jax.jit(make_train_step(TINY, tcfg))
        p2, _, m = step(params, init_opt_state(params), batch)
        outs[nm] = (p2, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     outs[1][0], outs[4][0])
    assert max(jax.tree.leaves(d)) < 1e-4


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = {"w": jnp.full((4,), 1e6, jnp.float32)}
    p2, opt2, stats = adamw_update(cfg, grads, opt, jnp.float32)
    assert float(stats["grad_norm"]) > 1e5
    # clipped: the effective step is bounded by lr regardless of grad scale
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) <= 1.5


def test_weight_decay_skips_norm_scales():
    cfg = AdamWConfig(lr=1e-2, weight_decay=1e3)
    params = {"scale": jnp.ones((4,), jnp.float32),
              "w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, grads, opt, jnp.float32)
    np.testing.assert_allclose(np.asarray(p2["scale"]), 1.0)  # no decay
    assert float(p2["w"][0]) < 1.0  # decayed


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 3, 5), jnp.float32).at[0, 1, 2].set(10.0)
    labels = jnp.array([[0, 2, 0]], jnp.int32)
    mask = jnp.array([[False, True, False]])
    loss, acc = cross_entropy(logits, labels, mask)
    assert float(acc) == 1.0 and float(loss) < 0.01


def test_adamw_matches_numpy_reference():
    """Three chained AdamW updates vs an independent pure-numpy
    implementation of the same math (clip -> schedule -> bias-corrected
    moments -> selective decay): the jit'd optimizer must agree leaf for
    leaf, including the warmup->cosine lr transition."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                      grad_clip=0.5, warmup_steps=2, total_steps=10,
                      min_lr_ratio=0.1)
    rng = np.random.RandomState(3)
    params = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32),
              "scale": jnp.asarray(rng.rand(3), jnp.float32)}
    opt = init_opt_state(params)
    ref_p = {k: np.asarray(v, np.float32).copy() for k, v in params.items()}
    ref_m = {k: np.zeros_like(v) for k, v in ref_p.items()}
    ref_v = {k: np.zeros_like(v) for k, v in ref_p.items()}
    p = params
    for t in range(1, 4):
        grads = {k: jnp.asarray(rng.randn(*np.shape(v)) * (3.0 if t == 1
                                else 0.1), jnp.float32)
                 for k, v in params.items()}  # t=1 triggers the clip
        p, opt, stats = adamw_update(cfg, grads, opt, jnp.float32)
        g = {k: np.asarray(v, np.float32) for k, v in grads.items()}
        gnorm = np.sqrt(sum(np.sum(np.square(x)) for x in g.values()))
        clip = min(1.0, cfg.grad_clip / (gnorm + 1e-9))
        warm = t / max(cfg.warmup_steps, 1)
        prog = np.clip((t - cfg.warmup_steps)
                       / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + np.cos(np.pi * prog))
        lr = cfg.lr * (warm if t < cfg.warmup_steps else cos)
        b1c, b2c = 1 - cfg.b1 ** t, 1 - cfg.b2 ** t
        for k in ref_p:
            gk = g[k] * clip
            ref_m[k] = cfg.b1 * ref_m[k] + (1 - cfg.b1) * gk
            ref_v[k] = cfg.b2 * ref_v[k] + (1 - cfg.b2) * gk * gk
            delta = (ref_m[k] / b1c) / (np.sqrt(ref_v[k] / b2c) + cfg.eps)
            if k == "w":  # matrices decay; norm scales never do
                delta = delta + cfg.weight_decay * ref_p[k]
            ref_p[k] = (ref_p[k] - lr * delta).astype(np.float32)
        np.testing.assert_allclose(float(stats["lr"]), lr, rtol=1e-6)
        assert int(opt.step) == t
        for k in ref_p:
            np.testing.assert_allclose(np.asarray(p[k]), ref_p[k],
                                       rtol=2e-5, atol=2e-6)


def test_checkpoint_roundtrip(tmp_path):
    params = init_params(TINY, jax.random.PRNGKey(0))  # bf16 leaves
    tree = {"params": params, "meta": {"arch": "tiny", "step": 7},
            "none": None, "tup": (1, 2.5)}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_checkpoint(path, tree, step=7, metadata={"note": "x"})
    loaded, step, meta = load_checkpoint(path)
    assert step == 7 and meta["note"] == "x"
    assert loaded["meta"]["arch"] == "tiny" and loaded["tup"] == (1, 2.5)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded["params"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_bf16_bitwise(tmp_path):
    """bfloat16 leaves round-trip through the uint16 view BITWISE — not
    through a float cast that could renormalize subnormals/NaNs."""
    x = (jnp.arange(31, dtype=jnp.float32) * 0.1007).astype(jnp.bfloat16)
    path = os.path.join(tmp_path, "c.msgpack")
    save_checkpoint(path, {"x": x})
    loaded, _, _ = load_checkpoint(path)
    assert loaded["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(x)).view(np.uint16),
        np.asarray(jax.device_get(loaded["x"])).view(np.uint16))


def test_checkpoint_atomic_on_failure(tmp_path, monkeypatch):
    """Write-to-temp + rename: a save that dies before the rename must
    leave the existing checkpoint intact and no temp litter behind."""
    import repro.training.checkpoint as ckpt
    path = os.path.join(tmp_path, "c.msgpack")
    save_checkpoint(path, {"v": 1}, step=1)

    def boom(src, dst):
        raise OSError("disk full")
    monkeypatch.setattr(ckpt.os, "replace", boom)
    with pytest.raises(OSError):
        save_checkpoint(path, {"v": 2}, step=2)
    monkeypatch.undo()
    tree, step, _ = load_checkpoint(path)
    assert tree["v"] == 1 and step == 1          # old checkpoint survives
    assert os.listdir(tmp_path) == ["c.msgpack"]  # temp file cleaned up


def test_checkpoint_truncated_and_corrupt(tmp_path):
    """A half-written or garbage file must fail loudly at load, and a
    future format version must be rejected, not misparsed."""
    path = os.path.join(tmp_path, "c.msgpack")
    save_checkpoint(path, {"x": jnp.ones((3,), jnp.float32)}, step=3)
    blob = open(path, "rb").read()
    trunc = os.path.join(tmp_path, "t.msgpack")
    with open(trunc, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(Exception):
        load_checkpoint(trunc)
    garbage = os.path.join(tmp_path, "g.msgpack")
    with open(garbage, "wb") as f:
        f.write(b"\x00garbage" * 7)
    with pytest.raises(Exception):
        load_checkpoint(garbage)
    doc = msgpack.unpackb(blob, raw=False, strict_map_key=False)
    doc["version"] = 2
    vers = os.path.join(tmp_path, "v.msgpack")
    with open(vers, "wb") as f:
        f.write(msgpack.packb(doc, use_bin_type=True))
    with pytest.raises(AssertionError):
        load_checkpoint(vers)
