"""Training substrate: loss descends, checkpoints round-trip, optimizer
semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.losses import cross_entropy
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state)
from repro.training.train_loop import TrainConfig, make_train_step, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                   tie_embeddings=True)


def _copy_batch(rng, b=16, s=12):
    """Learnable toy task: predict the previous token."""
    toks = rng.randint(1, TINY.vocab_size, (b, s)).astype(np.int32)
    labels = np.concatenate([toks[:, :1], toks[:, :-1]], axis=1)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def test_loss_decreases():
    rng = np.random.RandomState(0)
    params = init_params(TINY, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=150),
                       remat=False, param_dtype=jnp.float32)
    out = train(TINY, tcfg, params, opt,
                (_copy_batch(rng) for _ in range(150)), log=None)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first * 0.65, (first, last)


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation must match the single-batch gradient."""
    rng = np.random.RandomState(1)
    params = init_params(TINY, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _copy_batch(rng, b=8)
    outs = {}
    for nm in (1, 4):
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3), microbatches=nm,
                           remat=False, param_dtype=jnp.float32)
        step = jax.jit(make_train_step(TINY, tcfg))
        p2, _, m = step(params, init_opt_state(params), batch)
        outs[nm] = (p2, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     outs[1][0], outs[4][0])
    assert max(jax.tree.leaves(d)) < 1e-4


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = {"w": jnp.full((4,), 1e6, jnp.float32)}
    p2, opt2, stats = adamw_update(cfg, grads, opt, jnp.float32)
    assert float(stats["grad_norm"]) > 1e5
    # clipped: the effective step is bounded by lr regardless of grad scale
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) <= 1.5


def test_weight_decay_skips_norm_scales():
    cfg = AdamWConfig(lr=1e-2, weight_decay=1e3)
    params = {"scale": jnp.ones((4,), jnp.float32),
              "w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, grads, opt, jnp.float32)
    np.testing.assert_allclose(np.asarray(p2["scale"]), 1.0)  # no decay
    assert float(p2["w"][0]) < 1.0  # decayed


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 3, 5), jnp.float32).at[0, 1, 2].set(10.0)
    labels = jnp.array([[0, 2, 0]], jnp.int32)
    mask = jnp.array([[False, True, False]])
    loss, acc = cross_entropy(logits, labels, mask)
    assert float(acc) == 1.0 and float(loss) < 0.01


def test_checkpoint_roundtrip(tmp_path):
    params = init_params(TINY, jax.random.PRNGKey(0))  # bf16 leaves
    tree = {"params": params, "meta": {"arch": "tiny", "step": 7},
            "none": None, "tup": (1, 2.5)}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_checkpoint(path, tree, step=7, metadata={"note": "x"})
    loaded, step, meta = load_checkpoint(path)
    assert step == 7 and meta["note"] == "x"
    assert loaded["meta"]["arch"] == "tiny" and loaded["tup"] == (1, 2.5)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded["params"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
