"""Config registry: the 10 assigned architectures + shapes."""
import pytest

from repro.configs.archs import ASSIGNED
from repro.configs.base import get_config, list_configs, pad_vocab, reduced
from repro.configs.shapes import SHAPES, get_shape

EXPECTED = {
    "mamba2-780m": dict(n_layers=48, d_model=1536, vocab_size=50280),
    "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                 n_kv_heads=8, d_ff=512, vocab_size=49155),
    "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
                        d_ff=8192, vocab_size=128256),
    "mixtral-8x22b": dict(n_layers=56, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=16384, vocab_size=32768),
    "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                           n_kv_heads=32, d_ff=8192, vocab_size=2048),
    "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=32, d_ff=13440, vocab_size=92416),
    "command-r-plus-104b": dict(n_layers=64, d_model=12288, n_heads=96,
                                n_kv_heads=8, d_ff=33792, vocab_size=256000),
    "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56,
                           n_kv_heads=8, d_ff=20480, vocab_size=64000),
    "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab_size=65536),
    "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64,
                         n_kv_heads=8, d_ff=22016, vocab_size=102400),
}

# hyperparameters straight from the assignment
MOE = {"granite-moe-3b-a800m": (40, 8), "mixtral-8x22b": (8, 2),
       "jamba-v0.1-52b": (16, 2)}


def test_all_assigned_registered():
    for a in ASSIGNED:
        assert a in list_configs()
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("arch", ASSIGNED)
def test_exact_assigned_hparams(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)
    if arch in MOE:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == MOE[arch]
    cfg.validate()


def test_families_span_six_types():
    fams = {get_config(a).family for a in ASSIGNED}
    assert fams == {"ssm", "moe", "dense", "audio", "vlm", "hybrid"}


@pytest.mark.parametrize("arch,approx_b", [
    ("mamba2-780m", 0.78), ("llama3.2-1b", 1.24), ("mixtral-8x22b", 141.0),
    ("deepseek-67b", 67.0), ("command-r-plus-104b", 104.0),
    ("jamba-v0.1-52b", 52.0),
])
def test_param_counts_match_names(arch, approx_b):
    n = get_config(arch).param_count() / 1e9
    assert approx_b * 0.7 < n < approx_b * 1.4, f"{arch}: {n:.1f}B"


def test_active_params_moe():
    cfg = get_config("mixtral-8x22b")
    # 8x22b: ~39B active of ~141B total
    assert cfg.active_param_count() < 0.35 * cfg.param_count()


def test_reduced_configs_are_small():
    for a in ASSIGNED:
        r = reduced(get_config(a))
        assert r.n_layers == 2 and r.d_model <= 512
        if r.moe:
            assert r.moe.n_experts <= 4
        r.validate()


def test_shapes():
    assert get_shape("train_4k").tokens == 4096 * 256
    assert get_shape("long_500k").seq_len == 524288
    assert {s.kind for s in SHAPES.values()} == {"train", "prefill", "decode"}


def test_pad_vocab():
    assert pad_vocab(49155) == 49408
    assert pad_vocab(256) == 256
