"""Scenario harness: trace determinism, SLO gates, load shedding.

Three claims this file owns:

  * **Determinism** — the same ScenarioSpec produces the bitwise-same
    op stream (trace fingerprint) and, replayed against the same
    engine, the bitwise-same served slates (slate fingerprint). This is
    what makes a committed BENCH_scenarios.json a reproducible record
    rather than a one-off observation.
  * **SLO gates** — ``evaluate_slo`` is pure bookkeeping, so it is
    tested synthetically: for every gate in the contract, one metrics
    dict that must pass and one that must fail, plus the vacuous-pass
    rule for wall budgets over empty path groups.
  * **Shedding** — the deadline shed policy must never fire under a
    steady trickle the server can absorb, must fire (and be counted)
    under a spike it cannot, and a shed ticket must resolve immediately
    with the typed marker — never blocking ``drain``.

Uses the conftest tiny engine (max_batch=4) with matching small specs
so nothing here recompiles pane shapes.
"""
import dataclasses

import numpy as np
import pytest

from conftest import tiny_engine
from repro.serving.api import Request
from repro.serving.loadgen import (DAY, PATH_GROUPS, SCENARIO_NAMES,
                                   ScenarioSpec, SLOContract, build_gateway,
                                   collect_metrics, evaluate_slo,
                                   get_scenario, make_trace, replay,
                                   run_scenario, slate_fingerprint)

# a spec shaped to the conftest engine (max_batch=4) so replays reuse
# its jit caches; short horizon keeps this file inside tier-1 budget
_TINY = ScenarioSpec(
    name="tiny-steady", kind="steady", horizon=50, n_users=40,
    n_items=300, seed=3, base_rate=0.6, event_rate=0.4,
    prelude_events=400, max_batch=4, prefill_len=32, inject_len=8,
    slo=SLOContract())


def _tiny(**kw):
    return dataclasses.replace(_TINY, **kw)


def _run(spec):
    trace = make_trace(spec)
    gw = build_gateway(spec, engine=tiny_engine())
    gw.warm(np.arange(spec.seen_users or spec.n_users), spec.start)
    return gw, trace, replay(gw, trace, spec)


# ----------------------------------------------------------------------
# Determinism
# ----------------------------------------------------------------------

def test_trace_fingerprint_deterministic_per_scenario():
    """Every named scenario's generator is a pure function of its spec:
    regenerating gives the identical op stream; a different seed gives
    a different one (the fingerprint actually discriminates)."""
    for name in SCENARIO_NAMES:
        spec = get_scenario(name, smoke=True)
        a, b = make_trace(spec), make_trace(spec)
        assert a.ops == b.ops
        assert a.fingerprint == b.fingerprint
        reseeded = make_trace(dataclasses.replace(spec, seed=spec.seed + 1))
        assert reseeded.fingerprint != a.fingerprint


def test_replay_slates_bitwise_deterministic():
    """Same seed => same served bytes: two independent platforms fed
    the same trace serve identical slates/scores in identical order."""
    gw1, tr1, t1 = _run(_TINY)
    gw2, tr2, t2 = _run(_TINY)
    assert tr1.fingerprint == tr2.fingerprint
    assert slate_fingerprint(t1) == slate_fingerprint(t2)
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)


def test_get_scenario_unknown_name():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("black_friday")


def test_scenario_traces_have_declared_shape():
    """Structural invariants the scenario factories promise: diurnal's
    two rollovers land inside the trace at peak and trough; cold users
    never repeat and are all unseen; churn events stay inside the
    churned slice."""
    d = get_scenario("diurnal", smoke=True)
    first = (d.start // d.snapshot_period) * d.snapshot_period \
        + d.snapshot_offset
    if first <= d.start:
        first += d.snapshot_period
    boundaries = [b for b in (first, first + d.snapshot_period)
                  if b < d.start + d.horizon]
    assert len(boundaries) == 2  # one at peak (h/4), one at trough (3h/4)
    assert boundaries[0] - d.start == d.horizon // 4
    assert boundaries[1] - d.start == 3 * d.horizon // 4

    c = get_scenario("cold_start_storm", smoke=True)
    tr = make_trace(c)
    cold_users = [op[1] for op in tr.ops if op[0] == "a"]
    assert len(set(cold_users)) == len(cold_users)       # never repeats
    assert min(cold_users) >= c.seen_users               # never seen

    ch = get_scenario("churn_heavy", smoke=True)
    tr = make_trace(ch)
    ev_users = {op[1] for op in tr.ops if op[0] == "e"}
    assert max(ev_users) < int(ch.n_users * ch.churn_frac)


# ----------------------------------------------------------------------
# SLO gates (synthetic telemetry — evaluate_slo is pure)
# ----------------------------------------------------------------------

def _metrics(**over):
    m = {"requests": 100, "served": 100, "shed": 0, "shed_rate": 0.0,
         "deadline_misses": 0, "deadline_miss_rate": 0.0, "hit_rate": 0.9,
         "queue_delay": {"p50": 2.0, "p99": 5.0, "max": 6},
         "wall_ms_p99": {"hit": 10.0, "fresh": 20.0, "miss": None},
         "boundary_slice_max_ms": 1.0,
         "paths": {"prefill": 10, "inject": 40, "cached": 50}}
    m.update(over)
    return m


@pytest.mark.parametrize("contract,bad", [
    (SLOContract(queue_delay_p50=3),
     _metrics(queue_delay={"p50": 4.0, "p99": 5.0, "max": 6})),
    (SLOContract(queue_delay_p99=6),
     _metrics(queue_delay={"p50": 2.0, "p99": 9.0, "max": 9})),
    (SLOContract(max_deadline_miss_rate=0.0),
     _metrics(deadline_miss_rate=0.01)),
    (SLOContract(max_shed_rate=0.0), _metrics(shed=1, shed_rate=0.01)),
    (SLOContract(min_shed=1, max_shed_rate=0.1), _metrics()),
    (SLOContract(min_hit_rate=0.85), _metrics(hit_rate=0.8)),
    (SLOContract(max_hit_rate=0.9), _metrics(hit_rate=0.95)),
    (SLOContract(wall_ms_p99={"hit": 15.0}),
     _metrics(wall_ms_p99={"hit": 20.0, "fresh": 20.0, "miss": None})),
    (SLOContract(max_boundary_slice_ms=5.0),
     _metrics(boundary_slice_max_ms=10.0)),
])
def test_each_gate_fails_on_violation_and_passes_in_budget(contract, bad):
    ok, gates = evaluate_slo(contract, _metrics())
    # the default metrics satisfy every contract above except min_shed
    if contract.min_shed:
        ok2, _ = evaluate_slo(contract, _metrics(shed=3, shed_rate=0.03))
        assert ok2
    else:
        assert ok, gates
    failed, gates = evaluate_slo(contract, bad)
    assert not failed
    assert any(not g["pass"] for g in gates)


def test_wall_budget_vacuous_pass_on_empty_path_group():
    """A path group nothing traveled ("miss" on an all-hit run) must
    pass its wall budget vacuously, not crash on None."""
    ok, gates = evaluate_slo(
        SLOContract(max_deadline_miss_rate=None, max_shed_rate=None,
                    wall_ms_p99={"miss": 1.0}),  # impossible budget...
        _metrics())                              # ...but no miss rows
    assert ok
    (g,) = gates
    assert g["actual"] is None and g["pass"]


def test_empty_contract_always_passes():
    ok, gates = evaluate_slo(
        SLOContract(max_deadline_miss_rate=None, max_shed_rate=None),
        _metrics(shed=50, shed_rate=0.5, deadline_miss_rate=1.0))
    assert ok and gates == []


# ----------------------------------------------------------------------
# Load shedding
# ----------------------------------------------------------------------

def test_no_shed_under_steady_trickle():
    """The absorbing regime: arrivals below service capacity with
    generous deadlines. The shed policy must be invisible — zero sheds,
    zero deadline misses, every request served."""
    gw, _, tickets = _run(_tiny(deadline_offset=60))
    st = gw.stats()
    assert st.shed == 0
    assert st.deadline_misses == 0
    assert all(not t.response.shed for t in tickets)
    assert len(tickets) > 0 and all(t.done for t in tickets)


def test_spike_sheds_and_is_counted():
    """A 50x one-second spike with tight deadlines: the projected drain
    time exceeds late arrivals' deadlines, so the shedder must engage,
    every shed must be counted, and served p99 queue delay stays inside
    the deadline budget (the whole point of shedding)."""
    spec = _tiny(name="tiny-spike", kind="spike", horizon=40,
                 base_rate=0.4, peak_mult=50.0, spike_start=10,
                 spike_len=4, deadline_offset=10)
    gw, _, tickets = _run(spec)
    st = gw.stats()
    shed = [t for t in tickets if t.response.shed]
    served = [t for t in tickets if not t.response.shed]
    assert st.shed == len(shed) > 0
    # every served request landed inside (or at) its deadline budget
    for t in served:
        tel = t.response.telemetry
        assert tel.served_at <= t.request.deadline + 0, \
            (tel.served_at, t.request.deadline)
    assert st.deadline_misses == 0


def test_shed_ticket_resolves_immediately_and_never_blocks_drain():
    """A shed ticket is done the moment submit returns: typed marker,
    empty slate, path="shed", pane_id=-1 — and drain still returns it
    exactly once without waiting on anything."""
    spec = _tiny(deadline_offset=60)
    gw = build_gateway(spec, engine=tiny_engine())
    now = spec.start
    gw.tick(now)
    # deadline == now with a service model: projected completion is
    # now + pane_service_time > now, so this must shed at submit
    t = gw.submit(Request(user=1, now=now, deadline=now))
    assert t.done and t.response.shed
    tel = t.response.telemetry
    assert tel.path == "shed" and tel.pane_id == -1
    assert t.response.slate.size == 0 and t.response.scores.size == 0
    assert t.completed_wall >= t.submitted_wall
    assert gw.stats().shed == 1
    # shed rows never enter the served-path telemetry
    assert sum(gw.stats().paths.values()) == 0
    out = gw.drain(now + 1)
    assert t in out              # claimable exactly once...
    assert gw.poll() == []       # ...and not twice


def test_shed_requires_service_model():
    from repro.serving.scheduler import ServerConfig
    with pytest.raises(ValueError, match="needs pane_service_time"):
        ServerConfig(shed_policy="deadline")
    with pytest.raises(ValueError, match="shed_policy"):
        ServerConfig(shed_policy="random", pane_service_time=1)


# ----------------------------------------------------------------------
# Background builds under load: zero boundary stall
# ----------------------------------------------------------------------

def _settle(gw, now, timeout=60.0):
    """Tick until the in-flight background build installs (ticks are
    cheap polls; the worker runs off-thread in wall time)."""
    import time
    t0 = time.monotonic()
    while gw._builder is not None:
        assert time.monotonic() - t0 < timeout, "background build stuck"
        time.sleep(0.001)
        gw.tick(now)


def _run_bg(spec):
    """Replay with a settle pass appended so an install racing the end
    of the trace still lands before metrics are read."""
    trace = make_trace(spec)
    gw = build_gateway(spec, engine=tiny_engine())
    gw.warm(np.arange(spec.seen_users or spec.n_users), spec.start)
    tickets = replay(gw, trace, spec)
    _settle(gw, spec.start + spec.horizon)
    return gw, trace, tickets


def test_flash_crowd_background_build_boundary_mid_spike():
    """A generation boundary landing INSIDE a 25x arrival spike, built
    off-thread: the SLO gates (including the boundary-stall gate) must
    pass — no tick during the spike paid a build slice — and the
    rollover must actually complete with the changed users retained
    through the handoff window."""
    h, start = 60, _TINY.start
    spec = _tiny(
        name="tiny-flash-bg", kind="spike", horizon=h,
        base_rate=0.4, peak_mult=25.0, spike_start=h // 3,
        spike_len=12, event_rate=0.5, event_burst_mult=8.0,
        deadline_offset=60, background_build=True,
        # one boundary mid-trace, 6s into the spike window
        snapshot_period=h, snapshot_offset=(start + h // 3 + 6) % h,
        prelude_ts=(start - h, start),
        slo=SLOContract(max_deadline_miss_rate=0.05, max_shed_rate=0.9,
                        max_boundary_slice_ms=50.0))
    gw, _, tickets = _run_bg(spec)
    assert all(t.done for t in tickets)
    m = collect_metrics(tickets, gw.stats())
    ok, gates = evaluate_slo(spec.slo, m)
    assert ok, gates
    assert any(g["gate"] == "boundary_slice_max_ms" for g in gates)
    st = gw.stats()["rollover"]
    assert st["rollovers"] >= 1
    assert st["build_steps"] > 0 and st["build_time_s"] > 0
    assert st["rekeyed"] + st["retained"] > 0


def test_churn_heavy_background_build_slo():
    """churn_heavy's regime — 80% of users receive events before the
    boundary — with the off-thread builder: every gate passes, the
    boundary stall stays bounded, and the budgeted re-warm drains the
    retained-stale population after the roll."""
    h, start = 60, _TINY.start
    spec = _tiny(
        name="tiny-churn-bg", kind="steady", horizon=h,
        base_rate=0.5, event_rate=1.5, churn_frac=0.8,
        rewarm_budget=4, deadline_offset=60, background_build=True,
        snapshot_period=h, snapshot_offset=(start + h // 2) % h,
        prelude_ts=(start - h, start - h // 2),
        slo=SLOContract(max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                        max_boundary_slice_ms=50.0))
    gw, _, tickets = _run_bg(spec)
    m = collect_metrics(tickets, gw.stats())
    ok, gates = evaluate_slo(spec.slo, m)
    assert ok, gates
    assert m["boundary_slice_max_ms"] <= 50.0
    st = gw.stats()["rollover"]
    assert st["rollovers"] >= 1
    # the churned majority changed: the handoff retained them as stale
    assert st["retained"] > 0


# ----------------------------------------------------------------------
# Metrics plumbing
# ----------------------------------------------------------------------

def test_collect_metrics_groups_paths_and_excludes_shed():
    """Shed rows count in shed/shed_rate but never in the queue-delay
    population or the per-path wall groups."""
    gw, _, tickets = _run(_tiny(horizon=30))
    m = collect_metrics(tickets, gw.stats())
    assert m["requests"] == len(tickets)
    assert m["served"] + m["shed"] == m["requests"]
    assert set(m["wall_ms_p99"]) == set(PATH_GROUPS.values())
    assert sum(gw.stats().paths.values()) == m["served"]


def test_run_scenario_smoke_end_to_end():
    """One full run_scenario pass on a tiny steady spec: SLO evaluated,
    fingerprints stamped, every ticket resolved."""
    spec = _tiny(slo=SLOContract(queue_delay_p99=10, max_shed_rate=0.0))
    (res,) = run_scenario(spec, warmup=False)
    assert res.slo_pass, res.gates
    assert res.trace_fingerprint == make_trace(spec).fingerprint
    assert res.metrics["shed"] == 0
    assert res.gateway_stats["requests"] == res.metrics["served"]


def test_day_constant_agrees_with_store():
    from repro.core.feature_store import DAY as STORE_DAY
    assert DAY == STORE_DAY
