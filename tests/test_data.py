"""Simulator + training-data builder semantics."""
import numpy as np

from repro.data.loader import (LoaderConfig, batches, build_examples,
                               sep_token, serve_tokens_consistent)
from repro.data.synthetic import (World, WorldConfig, bootstrap_serve_fn,
                                  events_to_arrays, simulate_day)

DAY = 86400


def _events():
    # user 0: two days of history
    return {
        "user": np.array([0, 0, 0, 0], np.int32),
        "item": np.array([1, 2, 3, 4], np.int32),
        "ts": np.array([100, 200, DAY + 100, DAY + 200], np.int64),
        "attributed": np.ones(4, bool),
    }


def test_midnight_cutoff_hides_same_day():
    lcfg = LoaderConfig(n_items=10, feature_len=8, min_history=1)
    ex = build_examples(_events(), lcfg, "midnight")
    # labels at DAY+100 and DAY+200 both see only day-0 history [1,2]
    assert len(ex["labels"]) == 2
    for row, lab in zip(ex["tokens"], ex["labels"]):
        hist = [t for t in row if t > 0]
        assert hist == [2, 3]  # tokens = items+1
    assert list(ex["labels"]) == [4, 5]


def test_fresh_cutoff_includes_same_day_with_sep():
    lcfg = LoaderConfig(n_items=10, feature_len=8, min_history=1)
    ex = build_examples(_events(), lcfg, "fresh")
    sep = sep_token(10)
    # label at DAY+200 must see [batch 1,2 | SEP | recent 3]
    row = ex["tokens"][list(ex["labels"]).index(5)]
    assert [t for t in row if t > 0] == [2, 3, sep, 4]


def test_batches_shapes_and_masks():
    lcfg = LoaderConfig(n_items=10, feature_len=8, min_history=1)
    ex = build_examples(_events(), lcfg, "midnight")
    b = next(batches(ex, 2, 1))
    assert b["tokens"].shape == (2, 8)
    assert b["loss_mask"].sum() == 2 and b["loss_mask"][:, -1].all()
    assert (b["labels"][:, -1] > 0).all()


def test_serve_tokens_consistent_mirrors_training():
    bf = (np.array([[1, 2]]), np.array([[100, 200]]), np.array([[1, 1]]))
    rf = (np.array([[3]]), np.array([[DAY + 100]]), np.array([[1]]))
    toks, valid = serve_tokens_consistent(bf, rf, n_items=10, feature_len=8)
    assert [t for t in toks[0] if t > 0] == [2, 3, sep_token(10), 4]


def test_common_random_numbers_pair_arms():
    """Identical serve policies ⇒ identical day outcomes (CRN pairing)."""
    cfg = WorldConfig(n_users=50, n_items=200, seed=3)
    outs = []
    for _ in range(2):
        w = World(cfg)
        serve = bootstrap_serve_fn(w, seed=9)
        evs, m = simulate_day(w, 0, serve, lambda e: None, seed=5)
        outs.append((m["impressions"], m["slate_watches"],
                     [(e.user, e.item, e.ts) for e in evs]))
    assert outs[0] == outs[1]


def test_intent_drift_exists():
    cfg = WorldConfig(n_users=80, n_items=200, seed=1, p_switch=0.9)
    w = World(cfg)
    before = w.intent.copy()
    serve = bootstrap_serve_fn(w, seed=0)
    simulate_day(w, 0, serve, lambda e: None, seed=0)
    assert (w.intent != before).mean() > 0.2


def test_events_to_arrays():
    w = World(WorldConfig(n_users=20, n_items=50, seed=0))
    evs, _ = simulate_day(w, 0, bootstrap_serve_fn(w, 0), lambda e: None,
                          seed=0)
    arr = events_to_arrays(evs)
    assert len(arr["user"]) == len(evs)
    assert arr["ts"].dtype == np.int64
