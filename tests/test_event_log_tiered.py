"""Tiered sliding-window EventLog: compaction, demotion, eviction,
bounded memory, the exactness contract against an unbounded-log oracle,
the BackgroundCompactor worker, and cross-thread view consistency while
compaction rewrites the hot tail.

The oracle throughout is an UNTIERED EventLog fed the identical event
stream: inside the retention window, with ``k <= segment_k`` and a query
right edge that does not split a trimmed compacted window, every read
must be bitwise identical.
"""
import threading

import numpy as np
import pytest

from repro.core.event_log import BackgroundCompactor, EventLog

W = 100  # window used throughout


def _pair(seed=0, n_users=16, n=400, t_hi=1000, **kw):
    """(tiered, oracle) logs fed the same seeded stream."""
    rng = np.random.RandomState(seed)
    us = rng.randint(0, n_users, n)
    its = rng.randint(0, 300, n)
    tss = np.sort(rng.randint(0, t_hi, n))  # mostly-ordered arrivals
    kw.setdefault("window", W)
    kw.setdefault("segment_k", 64)
    # default deep retention: nothing evicts, so exactness holds over
    # the whole stream; eviction tests shrink it explicitly
    kw.setdefault("retention_windows", 16)
    log = EventLog(n_users, **kw)
    oracle = EventLog(n_users)
    log.extend(us, its, tss)
    oracle.extend(us, its, tss)
    return log, oracle, (us, its, tss)


def _assert_reads_match(log, oracle, lo, hi, k, n_users=16):
    users = np.arange(n_users)
    got = log.materialize(users, lo, hi, k)
    want = oracle.materialize(users, lo, hi, k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_untiered_log_never_compacts():
    log = EventLog(8)
    log.append(0, 1, 50)
    assert not log.compaction_due(10_000)
    assert log.compact(10_000) == {}
    st = log.ingest_stats()
    assert st["window"] == 0 and st["compactions"] == 0
    assert log.n_events == len(log) == 1


def test_compact_is_oracle_exact_in_retention():
    log, oracle, _ = _pair(seed=1)
    assert log.compaction_due(1000)
    out = log.compact(1000)
    assert out["horizon"] == 1000 and log.counters["compactions"] == 1
    assert not log.compaction_due(1000)  # same boundary: no-op
    assert log.compact(1000) == {}
    # every window-aligned in-retention query, plus above-horizon ones
    for lo, hi in [(0, 1000), (200, 600), (0, W), (900, 1000),
                   (300, 5000), (1000, 5000)]:
        _assert_reads_match(log, oracle, lo, hi, 24)
    # positions survive: delta scans anchored mid-stream stay equal
    for start in (0, 100, 399):
        np.testing.assert_array_equal(
            log.users_with_events(0, 1000, start=start),
            oracle.users_with_events(0, 1000, start=start))
    assert log.n_events == oracle.n_events == 400


def test_late_event_demoted_into_segment():
    log, oracle, _ = _pair(seed=2)
    log.compact(1000)
    # ts below the horizon but inside retention: demoted, still served
    log.append(3, 42, 150)
    oracle.append(3, 42, 150)
    assert log.counters["demoted"] == 1
    _assert_reads_match(log, oracle, 100, 200, 8)
    _assert_reads_match(log, oracle, 0, 1000, 24)
    # the demoted event is position-anchored for late-arrival scans
    assert 3 in log.users_with_events(0, 1000, start=400)


def test_late_event_past_retention_dropped_and_counted():
    log, _, _ = _pair(seed=3, retention_windows=2)
    log.compact(1000)  # floor = 800
    n0 = log.n_events
    log.append(5, 7, 100)          # far below the floor
    assert log.counters["dropped_late"] == 1
    assert log.n_events == n0 + 1  # position consumed, event not retained
    assert 5 not in log.users_with_events(95, 105)


def test_eviction_past_retention_floor():
    log, oracle, _ = _pair(seed=4, retention_windows=3)
    log.compact(1000)  # keeps [700, 1000) warm + hot tail
    st = log.ingest_stats()
    assert st["evicted"] > 0
    assert log.min_ts() >= 700
    # in-retention reads still oracle-exact
    for lo, hi in [(700, 1000), (800, 900), (900, 2000)]:
        _assert_reads_match(log, oracle, lo, hi, 24)
    # a second boundary evicts the oldest surviving window
    log.extend([1], [2], [1050])
    oracle.extend([1], [2], [1050])
    log.compact(1100)
    assert log.min_ts() >= 800
    _assert_reads_match(log, oracle, 800, 1100, 24)


def test_conservation_invariant():
    log, _, _ = _pair(seed=5, retention_windows=2, segment_k=4)
    log.compact(1000)
    log.append(0, 1, 150)   # dropped (below floor 800)
    log.append(0, 1, 850)   # demoted
    log.extend([1, 2], [3, 4], [1001, 1002])
    log.compact(1100)
    st = log.ingest_stats()
    assert st["appended"] == (st["events_hot"] + st["events_warm"]
                             + st["trimmed"] + st["dropped_late"]
                             + st["evicted"])


def test_hot_budget_bounds_tail_growth():
    log = EventLog(8, capacity=16, window=W, hot_budget=64)
    for i in range(200):
        log.append(i % 8, i, 900 + i % W)  # one window, never compacts
    st = log.ingest_stats()
    # allocation stays at need, not doubling headroom past the budget
    assert st["bytes_hot"] <= 200 * (8 + 4 + 8 + 8)
    assert st["hot_overflow"] >= 1
    assert len(log) == 200  # in-window events are never refused
    log2 = EventLog(8, capacity=16, window=W, hot_budget=64)
    for i in range(60):
        log2.append(i % 8, i, 900 + i % W)
    assert log2.ingest_stats()["hot_overflow"] == 0


def test_trim_keeps_freshest_k_and_records_superset():
    log = EventLog(4, window=W, segment_k=3)
    oracle = EventLog(4)
    # user 0: 6 events in window [0, 100) -> 3 trimmed; user 1: 2 events
    rows = [(0, i, 10 * i) for i in range(6)] + [(1, 7, 15), (1, 8, 85)]
    for u, i, t in rows:
        log.append(u, i, t)
        oracle.append(u, i, t)
    log.compact(100)
    assert log.counters["trimmed"] == 3
    # k <= segment_k with aligned right edge: still oracle-exact
    _assert_reads_match(log, oracle, 0, 100, 3, n_users=4)
    _assert_reads_match(log, oracle, 0, 200, 2, n_users=4)
    # a right edge splitting the trimmed window: user scans degrade to a
    # recorded superset (never a miss) -- user 0 must be flagged
    assert 0 in log.users_with_events(0, 25)
    # exact-presence side: user 1's kept rows answer exactly
    assert 1 in log.users_with_events(80, 90)


def test_events_since_resurfaces_demoted_events_in_order():
    log = EventLog(8, window=W)
    for p, (u, t) in enumerate([(0, 10), (1, 120), (2, 130)]):
        log.append(u, p, t)
    log.compact(100)           # event 0 compacted into [0, 100)
    log.append(3, 9, 50)       # late: demoted into the same segment
    v = log.view()
    us, its, tss = v.events_since(0)
    assert us.tolist() == [0, 1, 2, 3]       # append order, merged back
    assert tss.tolist() == [10, 120, 130, 50]
    us2, _, _ = v.events_since(3)
    assert us2.tolist() == [3]
    assert v.n_events == 4


def test_min_ts_and_user_events_span_tiers():
    log, oracle, (us, its, tss) = _pair(seed=6)
    log.compact(1000)
    assert log.min_ts() == oracle.min_ts()
    for u in (0, 3, 15):
        assert log.user_events(u) == oracle.user_events(u)


def test_background_compactor_matches_sync():
    log, _, stream = _pair(seed=7, retention_windows=3, segment_k=8)
    sync_log = EventLog(16, window=W, retention_windows=3, segment_k=8)
    sync_log.extend(*stream)
    comp = BackgroundCompactor(log)
    assert comp.start(1000)
    assert not comp.start(1000)    # one in flight
    comp.join()
    out = comp.poll()
    want = sync_log.compact(1000)
    assert out == want
    assert comp.poll() is None     # drained
    assert log.ingest_stats() == sync_log.ingest_stats()
    _assert_reads_match(log, sync_log, 700, 1100, 24)


def test_background_compactor_buffers_late_appends_during_build():
    log, oracle, _ = _pair(seed=8)
    log.compact(1000)
    oracle.compact = lambda *a, **k: {}  # oracle stays unbounded
    built = threading.Event()
    release = threading.Event()

    def hook(phase):
        if phase == "built":
            built.set()
            release.wait(5)

    log.extend([0], [1], [1050])
    oracle.extend([0], [1], [1050])
    comp = BackgroundCompactor(log)
    assert comp.start(1100, step_hook=hook)
    assert built.wait(5)
    # late event lands while the worker owns the build: parked, then
    # routed into its segment at install -- never lost, never racing
    log.append(2, 9, 950)
    oracle.append(2, 9, 950)
    assert log._compacting and len(log._late_buffer) == 1
    release.set()
    comp.join()
    comp.poll()
    assert log.counters["demoted"] == 1 and not log._late_buffer
    _assert_reads_match(log, oracle, 900, 1100, 24)


def test_background_compactor_error_is_sticky_and_aborts():
    log, _, _ = _pair(seed=9)

    def hook(phase):
        raise RuntimeError("boom")

    comp = BackgroundCompactor(log)
    assert comp.start(1000, step_hook=hook)
    comp.join()
    with pytest.raises(RuntimeError, match="background compaction failed"):
        comp.poll()
    assert not log._compacting          # aborted cleanly
    assert log.compact(1000) != {}      # retry succeeds


def test_keep_from_pins_unconsumed_suffix():
    log = EventLog(8, window=W, retention_windows=1)
    for i in range(10):
        log.append(i % 8, i, 10 * i)    # ts 0..90, one window
    # a trainer that has consumed through position 4 pins 4..9 hot
    log.compact(1000, keep_from=4)
    st = log.ingest_stats()
    assert st["events_hot"] == 6 and st["evicted"] == 4
    v = log.view()
    us, _, _ = v.events_since(4)
    assert len(us) == 6                 # gapless past the cursor


# ----------------------------------------------------------------------
# cross-thread: views stay oracle-exact while compaction rewrites the
# tail (the PR 8 step-barrier pattern, pointed at compaction)
# ----------------------------------------------------------------------

def test_view_frozen_across_compaction_phases():
    log, oracle, _ = _pair(seed=10)
    v = log.view()
    want = [np.copy(a) for a in v.materialize(np.arange(16), 0, 1000, 24)]

    def check(phase):
        got = log.view().materialize(np.arange(16), 0, 1000, 24)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    log.compact(1000, step_hook=check)  # barriers: captured/built/installed
    # the pre-compaction view itself is frozen -- still bitwise equal
    got = v.materialize(np.arange(16), 0, 1000, 24)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_concurrent_readers_during_live_compaction():
    """Reader threads grab views and materialize while the owner thread
    appends and compacts; every view must match an untiered oracle built
    from the same stream prefix (``view.n_events`` anchors the prefix)."""
    n_users = 8
    rng = np.random.RandomState(11)
    stream = [(int(rng.randint(n_users)), int(rng.randint(300)), 5 * t)
              for t in range(600)]
    log = EventLog(n_users, window=W, retention_windows=64, segment_k=64)
    oracle = EventLog(n_users)
    oracle.extend(*map(np.asarray, zip(*stream)))
    errors = []
    stop = threading.Event()

    def reader():
        users = np.arange(n_users)
        try:
            while not stop.is_set():
                v = log.view()
                n = v.n_events
                got = v.materialize(users, 0, 5 * n, 24)
                ou, oi, ot = (np.asarray(c[:n]) for c in zip(*stream))
                ref = EventLog(n_users)
                if n:
                    ref.extend(ou, oi, ot)
                want = ref.materialize(users, 0, 5 * n, 24)
                for g, w in zip(got, want):
                    np.testing.assert_array_equal(g, w)
        except BaseException as e:  # surfaces on the main thread
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for i, (u, it, ts) in enumerate(stream):
        log.append(u, it, ts)
        if i and i % 150 == 0:
            log.compact(ts)
    log.compact(3000)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    _assert_reads_match(log, oracle, 0, 3000, 24, n_users=n_users)
