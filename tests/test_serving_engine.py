"""Serving engine: prefill → inject → decode equals one unpadded pass.

This is the TPU-native form of the paper's claim: injected fresh events
change the model state exactly as if they had been part of the batch
history all along — at O(suffix) cost.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import forward, init_params
from repro.serving.engine import ServingConfig, ServingEngine

ARCHS = ["llama3.2-1b", "mamba2-780m", "jamba-v0.1-52b", "mixtral-8x22b"]


def _engine(arch, **kw):
    cfg = reduced(get_config(arch))
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=cfg.moe.no_drop())
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    scfg = ServingConfig(max_batch=2, prefill_len=24, inject_len=8,
                         cache_capacity=64, **kw)
    return cfg, params, ServingEngine(cfg, params, scfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_inject_then_decode_matches_oracle(arch):
    cfg, params, eng = _engine(arch)
    hists = [[5, 7, 9, 11, 13, 2, 4, 6], [100, 101, 102]]
    fresh = [[21, 22, 23], [30]]
    nxt = [50, 60]

    toks, valid = eng.pad_tokens(hists, 24)
    st = eng.prefill(toks, valid)
    stoks, svalid = eng.pad_tokens(fresh, 8, align="left")
    st = eng.inject(st, stoks, svalid)
    dec = eng.finalize(st)
    logits, dec = eng.decode(dec, np.array([[t] for t in nxt], np.int32))

    for row in range(2):
        stream = hists[row] + fresh[row] + [nxt[row]]
        ref, _ = forward(params, cfg, jnp.asarray([stream], jnp.int32))
        np.testing.assert_allclose(np.asarray(ref[0, -1]),
                                   np.asarray(logits[row]),
                                   atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m"])
def test_double_injection(arch):
    """Two injection rounds (two request waves) still exact."""
    cfg, params, eng = _engine(arch)
    hists = [[3, 1, 4, 1, 5], [9, 2, 6]]
    f1 = [[10, 11], [12]]
    f2 = [[13], [14, 15]]
    toks, valid = eng.pad_tokens(hists, 24)
    st = eng.prefill(toks, valid)
    for f in (f1, f2):
        stoks, svalid = eng.pad_tokens(f, 8, align="left")
        st = eng.inject(st, stoks, svalid)
    dec = eng.finalize(st)
    logits, _ = eng.decode(dec, np.array([[7], [8]], np.int32))
    for row in range(2):
        stream = hists[row] + f1[row] + f2[row] + [7 + row]
        ref, _ = forward(params, cfg, jnp.asarray([stream], jnp.int32))
        np.testing.assert_allclose(np.asarray(ref[0, -1]),
                                   np.asarray(logits[row]),
                                   atol=2e-3, rtol=2e-3)


def test_injection_changes_prediction():
    """Freshness matters: injecting events must move the logits."""
    cfg, params, eng = _engine("llama3.2-1b")
    hists = [[5, 7, 9, 11], [1, 2, 3]]
    toks, valid = eng.pad_tokens(hists, 24)
    st = eng.prefill(toks, valid)
    dec_stale = eng.finalize(st)
    l_stale, _ = eng.decode(dec_stale, np.array([[50], [60]], np.int32))

    stoks, svalid = eng.pad_tokens([[21, 22], [30]], 8, align="left")
    st2 = eng.inject(st, stoks, svalid)
    dec_fresh = eng.finalize(st2)
    l_fresh, _ = eng.decode(dec_fresh, np.array([[50], [60]], np.int32))
    assert float(jnp.max(jnp.abs(l_stale - l_fresh))) > 1e-3


def test_pad_tokens_empty_rows():
    """Empty sequences produce all-pad rows (and so do absent rows)."""
    _, _, eng = _engine("llama3.2-1b")
    toks, valid = eng.pad_tokens([[], [1, 2]], 8)
    assert toks.shape == (2, 8) and valid.shape == (2, 8)
    assert valid[0].sum() == 0 and toks[0].sum() == 0
    np.testing.assert_array_equal(toks[1, -2:], [1, 2])
    # batch with fewer rows than max_batch: trailing rows are pad-only
    toks, valid = eng.pad_tokens([[3]], 8)
    assert valid[1].sum() == 0


def test_pad_tokens_truncation_keeps_tail():
    """Sequences longer than ``length`` keep the most recent tokens, for
    both alignments."""
    _, _, eng = _engine("llama3.2-1b")
    seq = list(range(1, 13))  # longer than length=8
    toks, valid = eng.pad_tokens([seq], 8)
    np.testing.assert_array_equal(toks[0], seq[-8:])
    assert valid[0].all()
    toks, valid = eng.pad_tokens([seq], 8, align="left")
    np.testing.assert_array_equal(toks[0], seq[-8:])
    assert valid[0].all()


def test_pad_tokens_raises_beyond_max_batch():
    """Inputs past max_batch raise instead of silently dropping requests —
    callers with larger waves must pane-split (serving/loop.py does)."""
    _, _, eng = _engine("llama3.2-1b")  # max_batch=2
    with pytest.raises(ValueError, match="max_batch"):
        eng.pad_tokens([[1], [2], [3], [4]], 8)
    # exactly max_batch still fine
    toks, valid = eng.pad_tokens([[1], [2]], 8)
    assert toks.shape == (2, 8)


def test_pad_tokens_left_alignment():
    _, _, eng = _engine("llama3.2-1b")
    toks, valid = eng.pad_tokens([[5, 6], []], 8, align="left")
    np.testing.assert_array_equal(toks[0, :2], [5, 6])
    assert valid[0, :2].all() and not valid[0, 2:].any()
    assert valid[1].sum() == 0


def test_greedy_sample():
    cfg, params, eng = _engine("llama3.2-1b")
    logits = jnp.zeros((2, cfg.vocab_padded)).at[0, 5].set(9.).at[1, 7].set(9.)
    tok = eng.sample(logits)
    assert tok.tolist() == [5, 7]
