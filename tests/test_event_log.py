"""EventLog: columnar growth, CSR index + pending-merge reads, bounds."""
import numpy as np
import pytest

from repro.core.event_log import EventLog


def test_append_and_growth():
    log = EventLog(n_users=3, capacity=16)
    for i in range(100):  # force several doublings
        log.append(i % 3, i, i * 10)
    assert len(log) == 100
    assert log.min_ts() == 0
    assert log.user_events(0)[:2] == [(0, 0), (30, 3)]


def test_extend_columnar_matches_append():
    a, b = EventLog(5), EventLog(5)
    rng = np.random.RandomState(0)
    u = rng.randint(0, 5, 200)
    it = rng.randint(0, 50, 200)
    ts = rng.randint(0, 1000, 200)
    a.extend(u, it, ts)
    for x, y, z in zip(u, it, ts):
        b.append(x, y, z)
    for user in range(5):
        assert a.user_events(user) == b.user_events(user)
    for feats_a, feats_b in zip(a.materialize(np.arange(5), 0, 500, 8),
                                b.materialize(np.arange(5), 0, 500, 8)):
        np.testing.assert_array_equal(feats_a, feats_b)


def test_user_bounds_rejected():
    log = EventLog(4)
    with pytest.raises(IndexError):
        log.append(4, 1, 10)
    with pytest.raises(IndexError):
        log.append(-1, 1, 10)
    with pytest.raises(IndexError):
        log.extend([0, 4], [1, 2], [10, 20])
    assert len(log) == 0  # extend validates before writing


def test_materialize_empty_cases():
    log = EventLog(4)
    # empty log
    items, ts, valid = log.materialize(np.array([0, 1]), 0, 100, 4)
    assert items.shape == (2, 4) and valid.sum() == 0
    # empty user list
    items, ts, valid = log.materialize(np.array([], np.int64), 0, 100, 4)
    assert items.shape == (0, 4)
    log.append(2, 7, 50)
    # empty window (hi <= lo) and out-of-range windows
    for lo, hi in [(100, 100), (100, 50), (60, 100), (0, 50)]:
        assert log.materialize(np.array([2]), lo, hi, 4)[2].sum() == 0
    # hi is exclusive, lo inclusive
    assert log.materialize(np.array([2]), 50, 51, 4)[2].sum() == 1


def test_materialize_right_aligned_truncation():
    log = EventLog(1)
    for t in range(10):
        log.append(0, t, t)
    items, ts, valid = log.materialize(np.array([0]), 0, 100, 4)
    np.testing.assert_array_equal(items[0], [6, 7, 8, 9])  # freshest k
    np.testing.assert_array_equal(valid[0], [1, 1, 1, 1])
    items, ts, valid = log.materialize(np.array([0]), 0, 3, 4)
    np.testing.assert_array_equal(items[0], [0, 0, 1, 2])  # right-aligned
    np.testing.assert_array_equal(valid[0], [0, 1, 1, 1])


def test_materialize_tie_order_is_ts_then_item():
    log = EventLog(1)
    for it in (5, 3, 9):
        log.append(0, it, 100)  # identical timestamps
    items, _, _ = log.materialize(np.array([0]), 0, 200, 3)
    np.testing.assert_array_equal(items[0], [3, 5, 9])


def test_pending_merge_path_matches_rebuilt():
    """Reads with an unsorted pending suffix (the interleaved serve
    pattern) must equal reads after a full index rebuild."""
    rng = np.random.RandomState(3)
    log = EventLog(6)
    log.extend(rng.randint(0, 6, 300), rng.randint(0, 40, 300),
               rng.randint(0, 2000, 300))
    q = np.arange(6)
    log.materialize(q, 0, 2000, 8)  # builds the base index
    # now interleave appends (pending suffix) with reads
    for step in range(40):
        log.append(rng.randint(6), rng.randint(40), rng.randint(0, 2000))
        got = log.materialize(q, 200, 1800, 8)
        assert log._base_n < len(log)  # still on the merge path
        fresh = EventLog(6)
        fresh.extend(log._user[:len(log)], log._item[:len(log)],
                     log._ts[:len(log)])
        want = fresh.materialize(q, 200, 1800, 8)
        for x, y in zip(got, want):
            np.testing.assert_array_equal(x, y)


def test_rebuild_threshold_amortizes():
    """The base index only rebuilds when pending outgrows it."""
    log = EventLog(2)
    log.extend(np.zeros(10, int), np.arange(10), np.arange(10))
    log.materialize(np.array([0]), 0, 100, 4)
    base_after_first = log._base_n
    log.append(1, 5, 50)
    log.materialize(np.array([0, 1]), 0, 100, 4)
    assert log._base_n == base_after_first  # pending merged, not re-sorted


def test_population_read_forces_rebuild_over_merge():
    """A full-population query racing a tiny pending suffix rebuilds the
    base (amortized) instead of allocating query-sized merge panes."""
    u = 2000
    log = EventLog(u)
    rng = np.random.RandomState(0)
    log.extend(rng.randint(0, u, 5000), rng.randint(0, 9, 5000),
               rng.randint(0, 1000, 5000))
    log.materialize(np.arange(u), 0, 1000, 4)   # builds base
    log.append(0, 1, 500)                       # tiny pending suffix
    log.materialize(np.arange(u), 0, 1000, 4)   # population-scale read
    assert log._base_n == len(log)


def test_tail_index_cached_between_writes():
    log = EventLog(4)
    log.extend(np.zeros(20, int), np.arange(20), np.arange(20))
    log.materialize(np.array([0]), 0, 100, 4)
    log.append(1, 7, 5)
    log.materialize(np.array([0, 1]), 0, 100, 4)
    tail_first = log._tail
    got = log.materialize(np.array([1]), 0, 100, 4)
    assert log._tail is tail_first              # no re-sort between writes
    assert [int(i) for i, v in zip(got[0][0], got[2][0]) if v] == [7]
    log.append(1, 8, 6)                         # write invalidates
    log.materialize(np.array([1]), 0, 100, 4)
    assert log._tail is not tail_first


def test_ts_dtype_is_int32_by_default():
    log = EventLog(1)
    log.append(0, 1, 5 * 86400)
    _, ts, _ = log.materialize(np.array([0]), 0, 10 * 86400, 2)
    assert ts.dtype == np.int32
