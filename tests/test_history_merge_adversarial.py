"""Adversarial differential sweep for the merge/inject path.

Three independent implementations of ``history_merge`` — the Pallas kernel
(interpret mode), the vectorized XLA oracle, and the plain-python
row-by-row reference — must agree *exactly* on inputs built to break the
pairwise-rank formulation: all-invalid rows, fully-duplicated item sets,
timestamp-tie storms (where real-time must beat batch), hard truncation,
zero-length buffers, and item id 0 colliding with the padding value.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.history_merge.ops import history_merge
from repro.kernels.history_merge.ref import history_merge_python_padded

IMPLS = ("pallas_interpret", "xla")


def _all_impls_equal(arrs, out_len):
    """Run every impl + the python reference; assert exact agreement."""
    want = history_merge_python_padded(*arrs, out_len=out_len)
    jargs = [jnp.asarray(np.asarray(a, np.int32)) for a in arrs]
    for impl in IMPLS:
        got = history_merge(*jargs, out_len=out_len, impl=impl)
        for name, g, w in zip(("items", "ts", "valid"), got, want):
            np.testing.assert_array_equal(
                np.asarray(g), w, err_msg=f"{impl}:{name}")
    return want


def test_all_invalid_rows():
    """Rows with zero valid events on either or both sides -> empty out."""
    b, lb, lr, k = 4, 6, 3, 5
    rng = np.random.RandomState(0)
    bi = rng.randint(0, 9, (b, lb))
    bt = rng.randint(0, 100, (b, lb))
    ri = rng.randint(0, 9, (b, lr))
    rt = rng.randint(0, 100, (b, lr))
    bv = np.ones((b, lb), np.int32)
    rv = np.ones((b, lr), np.int32)
    bv[0] = 0            # batch side dead
    rv[1] = 0            # rt side dead
    bv[2] = rv[2] = 0    # both dead
    out = _all_impls_equal((bi, bt, bv, ri, rt, rv), k)
    assert out[2][2].sum() == 0          # both-dead row is fully empty
    # batch-dead row keeps only (unique) rt items
    assert out[2][0].sum() == len(set(ri[0].tolist()))
    # fully-valid row keeps its unique items, capped at K
    uniq3 = len(set(bi[3].tolist()) | set(ri[3].tolist()))
    assert out[2][3].sum() == min(uniq3, k)


def test_fully_duplicated_item_sets():
    """batch and rt carry the *same* items — every batch copy must lose to
    its fresher rt twin (rt ts strictly larger), and duplicates inside each
    buffer must also collapse."""
    b, l, k = 2, 8, 8
    items = np.tile(np.arange(l), (b, 1))
    bt = np.full((b, l), 50)
    rt = np.full((b, l), 60)
    v = np.ones((b, l), np.int32)
    out = _all_impls_equal((items, bt, v, items, rt, v), k)
    assert (out[1][out[2] > 0] == 60).all()  # only rt timestamps survive
    # same again but with duplicates *within* each buffer too
    items2 = np.tile(np.arange(l // 2).repeat(2), (b, 1))
    out = _all_impls_equal((items2, bt, v, items2, rt, v), k)
    assert out[2].sum() == b * (l // 2)


def test_ts_tie_storm_realtime_beats_batch():
    """Every event in both buffers has the same timestamp: freshness falls
    through to (is_rt, index) — rt copies of shared items must win."""
    b, l, k = 3, 10, 10
    rng = np.random.RandomState(1)
    bi = rng.randint(0, 6, (b, l))
    ri = rng.randint(0, 6, (b, l))
    ties = np.full((b, l), 777)
    v = np.ones((b, l), np.int32)
    out = _all_impls_equal((bi, ties, v, ri, ties, v), k)
    # all six items appear in some rows; every surviving slot of an item
    # that exists on the rt side must be the rt copy — indistinguishable by
    # ts here, so the assertion is the cross-impl agreement itself, plus:
    for row in range(b):
        kept = out[0][row][out[2][row] > 0]
        assert len(set(kept.tolist())) == len(kept)  # dedup held under ties


def test_out_len_smaller_than_valid_count():
    """K much smaller than the number of unique valid events: keep exactly
    the K freshest, right-aligned ascending."""
    b, lb, lr, k = 2, 12, 6, 3
    rng = np.random.RandomState(2)
    bi = np.tile(np.arange(lb), (b, 1))            # all unique
    bt = rng.randint(0, 1000, (b, lb))
    ri = np.tile(np.arange(lb, lb + lr), (b, 1))   # unique, disjoint
    rt = rng.randint(0, 1000, (b, lr))
    v = np.ones((b, lb), np.int32)
    out = _all_impls_equal((bi, bt, v, ri, rt, v[:, :lr]), k)
    assert (out[2] == 1).all()                     # every slot filled
    for row in range(b):
        all_ts = np.concatenate([bt[row], rt[row]])
        assert set(out[1][row]) == set(np.sort(all_ts)[-k:])


@pytest.mark.parametrize("side", ["rt", "batch", "both"])
def test_zero_length_buffers(side):
    """L_rt == 0 (and friends) must not crash any impl — regression for a
    zero-width BlockSpec division-by-zero in the Pallas wrapper."""
    b, l, k = 2, 4, 6
    rng = np.random.RandomState(3)
    full = (rng.randint(0, 9, (b, l)), rng.randint(0, 50, (b, l)),
            np.ones((b, l), np.int32))
    empty = (np.zeros((b, 0), np.int32),) * 3
    batch = empty if side in ("batch", "both") else full
    rt = empty if side in ("rt", "both") else full
    out = _all_impls_equal((*batch, *rt), k)
    if side == "both":
        expect = 0
    else:  # duplicates within the surviving side still collapse
        expect = sum(len(set(full[0][row].tolist())) for row in range(b))
    assert out[2].sum() == expect


def test_item_zero_collides_with_padding():
    """item id 0 is a real item but also the output padding value: a valid
    event with item 0 must surface with valid=1, and consumers must rely on
    the valid plane (not the item value) to spot padding."""
    bi = np.array([[0, 1], [0, 0]])
    bt = np.array([[10, 20], [10, 20]])
    bv = np.ones((2, 2), np.int32)
    ri = np.array([[0], [5]])
    rt = np.array([[30], [30]])
    rv = np.ones((2, 1), np.int32)
    out = _all_impls_equal((bi, bt, bv, ri, rt, rv), 4)
    # row 0: item 0 deduped to the rt copy (ts 30), item 1 kept
    assert out[0][0].tolist() == [0, 0, 1, 0]
    assert out[2][0].tolist() == [0, 0, 1, 1]
    assert out[1][0].tolist() == [0, 0, 20, 30]
    # row 1: both batch copies of item 0 collapse to the ts=20 one
    assert out[2][1].tolist() == [0, 0, 1, 1]
    assert out[0][1].tolist() == [0, 0, 0, 5]


def test_randomized_sweep_cross_impl():
    """Many random shapes/densities: the three impls agree bit-for-bit."""
    rng = np.random.RandomState(4)
    for _ in range(12):
        b = rng.randint(1, 5)
        lb = rng.randint(0, 20)
        lr = rng.randint(0, 10)
        k = rng.randint(1, 24)
        n_items = rng.choice([1, 3, 30])           # heavy or no collisions
        tmax = rng.choice([1, 5, 1000])            # heavy or no ts ties
        arrs = (rng.randint(0, n_items, (b, lb)), rng.randint(0, tmax, (b, lb)),
                (rng.rand(b, lb) < 0.7).astype(np.int32),
                rng.randint(0, n_items, (b, lr)), rng.randint(0, tmax, (b, lr)),
                (rng.rand(b, lr) < 0.7).astype(np.int32))
        _all_impls_equal(arrs, k)
