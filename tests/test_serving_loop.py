"""End-to-end injection serving loop: cache correctness under interleaved
ingest/serve traffic.

The load-bearing invariant: the prefill-state cache is an *optimization
only* — for any request stream, the cached-inject path must produce the
same scores/slates as full-prefill-per-request, including across LRU
eviction and snapshot-generation rollover (stale cached state must never
serve).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
from repro.models.model import init_params
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.loop import InjectionServer, PrefillStateCache, ServerConfig

DAY = 86400
N_USERS, N_ITEMS = 40, 300
FEATURE_LEN = 24

_CFG = ModelConfig(name="loop-test", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128,
                   vocab_size=N_ITEMS + 256, rope_theta=1e4,
                   tie_embeddings=True)
_PARAMS = init_params(_CFG, jax.random.PRNGKey(0), dtype=jnp.float32)
_ENGINE = ServingEngine(_CFG, _PARAMS, ServingConfig(
    max_batch=4, prefill_len=32, inject_len=8, cache_capacity=64))


def _seed_events(seed=0, n=1500, t_hi=5 * DAY):
    rng = np.random.RandomState(seed)
    return (rng.randint(0, N_USERS, n), rng.randint(0, N_ITEMS, n),
            rng.randint(0, t_hi, n))


def _server(policy="inject", use_cache=True, cache_entries=256,
            snapshot_offset=0, events=None, slate_len=3):
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=N_USERS, feature_len=FEATURE_LEN,
        snapshot_offset=snapshot_offset))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=N_USERS, buffer_len=8, ingest_latency=0))
    for u, i, t in zip(*(events or _seed_events())):
        store.append(int(u), int(i), int(t))
        rts.ingest(int(u), int(i), int(t))
    inj = FeatureInjector(
        InjectionConfig(policy=policy, feature_len=FEATURE_LEN), store, rts)
    return InjectionServer(_ENGINE, inj, ServerConfig(
        slate_len=slate_len, cache_entries=cache_entries,
        use_cache=use_cache))


def _ingest(srv, users, items, ts):
    for u, i, t in zip(users, items, ts):
        srv.injector.batch.append(int(u), int(i), int(t))
        srv.injector.realtime.ingest(int(u), int(i), int(t))


# ----------------------------------------------------------------------

def test_cached_equals_full_prefill_interleaved():
    """Cached-inject scores == full-prefill scores over interleaved
    ingest/serve waves (the differential that makes the cache safe)."""
    cached, full = _server(use_cache=True), _server(use_cache=False)
    rng = np.random.RandomState(1)
    now = 5 * DAY + 100
    for wave in range(4):
        u = rng.randint(0, N_USERS, 10)
        it = rng.randint(0, N_ITEMS, 10)
        t = np.full(10, now - 40)
        _ingest(cached, u, it, t)
        _ingest(full, u, it, t)
        q = rng.randint(0, N_USERS, 11)  # pane-splits at max_batch=4
        rc = cached.serve(q, now)
        rf = full.serve(q, now)
        np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
        np.testing.assert_array_equal(rc.slate, rf.slate)
        now += 300
    assert cached.cache.hits > 0  # the comparison actually exercised hits


def test_cache_hits_skip_prefill():
    srv = _server()
    now = 5 * DAY + 100
    users = np.arange(8)
    srv.serve(users, now)
    n_prefills = srv.prefill_calls
    r = srv.serve(users, now + 10)
    assert srv.prefill_calls == n_prefills  # no new prefill on the hot path
    assert r.cache_hits == 8 and r.cache_misses == 0


def test_lru_eviction_stays_correct():
    """Budget smaller than the working set: evictions happen, results
    still match the uncached oracle."""
    srv = _server(cache_entries=6)
    full = _server(use_cache=False)
    now = 5 * DAY + 100
    for lo in (0, 8, 16, 0):  # revisit evicted users
        q = np.arange(lo, lo + 8) % N_USERS
        rc = srv.serve(q, now)
        rf = full.serve(q, now)
        np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
    assert srv.cache.evictions > 0
    assert len(srv.cache) <= 6


def test_batch_policy_ignores_fresh_events():
    """Control arm sanity: with policy='batch' the cache serves identical
    scores before and after fresh events arrive (that's the staleness the
    paper's injection closes; 'inject' must move)."""
    b_srv, i_srv = _server(policy="batch"), _server(policy="inject")
    now = 5 * DAY + 100
    users = np.arange(6)
    sb0 = b_srv.serve(users, now).scores
    si0 = i_srv.serve(users, now).scores
    _ingest(b_srv, users, (users + 7) % N_ITEMS, np.full(6, now + 5))
    _ingest(i_srv, users, (users + 7) % N_ITEMS, np.full(6, now + 5))
    sb1 = b_srv.serve(users, now + 50).scores
    si1 = i_srv.serve(users, now + 50).scores
    np.testing.assert_allclose(sb0, sb1, atol=1e-5)
    assert np.abs(si0 - si1).max() > 1e-3


def test_fresh_policy_never_caches():
    srv = _server(policy="fresh")
    now = 5 * DAY + 100
    srv.serve(np.arange(4), now)
    srv.serve(np.arange(4), now + 10)
    assert srv.cache.hits == 0 and len(srv.cache) == 0
    assert srv.prefill_calls == 2


def test_warm_precomputes_prefill_states():
    """warm() admits batch-history states so live traffic starts on the
    inject-only path; it must not change served scores."""
    warmed, cold = _server(), _server()
    now = 5 * DAY + 100
    users = np.arange(12)
    n = warmed.warm(users, now)
    assert n == 12 and len(warmed.cache) == 12
    r_warm = warmed.serve(users, now)
    assert r_warm.cache_hits == 12 and r_warm.cache_misses == 0
    r_cold = cold.serve(users, now)
    np.testing.assert_allclose(r_warm.scores, r_cold.scores,
                               atol=2e-3, rtol=2e-3)
    # warm is a no-op for uncacheable configurations
    assert _server(use_cache=False).warm(users, now) == 0
    assert _server(policy="fresh").warm(users, now) == 0


def test_warm_clamps_to_cache_budget():
    """Warming past the LRU budget would prefill states that evict before
    they ever serve — warm() clamps instead of wasting the work."""
    srv = _server(cache_entries=6)
    n = srv.warm(np.arange(20), 5 * DAY + 100)
    assert n == 6 and len(srv.cache) == 6
    assert srv.cache.evictions == 0


def test_history_longer_than_prefill_len_paths_agree():
    """feature_len > prefill_len: both paths must truncate the history
    identically (history to prefill_len, then the suffix appended) or the
    cache would change scores."""
    eng = ServingEngine(_CFG, _PARAMS, ServingConfig(
        max_batch=4, prefill_len=16, inject_len=8, cache_capacity=64))

    def srv_with(use_cache):
        s = _server(use_cache=use_cache)
        return InjectionServer(eng, s.injector, ServerConfig(
            slate_len=3, cache_entries=64, use_cache=use_cache))

    cached, full = srv_with(True), srv_with(False)
    now = 5 * DAY + 100
    users = np.arange(8)  # FEATURE_LEN=24 history > prefill_len=16
    _ingest(cached, users, (users + 3) % N_ITEMS, np.full(8, now - 20))
    _ingest(full, users, (users + 3) % N_ITEMS, np.full(8, now - 20))
    rc, rf = cached.serve(users, now), full.serve(users, now)
    np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
    np.testing.assert_array_equal(rc.slate, rf.slate)


def test_duplicate_users_count_per_row():
    """Hit/miss counters are in request (row) units even when a wave
    repeats a user; the repeated miss still pays only one admission."""
    srv = _server()
    now = 5 * DAY + 100
    r = srv.serve(np.array([5, 5, 5]), now)
    assert r.cache_misses == 3 and r.cache_hits == 0
    assert srv.prefill_calls == 1  # one admission, not three
    r = srv.serve(np.array([5, 5]), now + 10)
    assert r.cache_hits == 2 and r.cache_misses == 0


def test_slate_items_distinct():
    """A slate recommends slate_len distinct items per user."""
    srv = _server(slate_len=4)
    r = srv.serve(np.arange(8), 5 * DAY + 100)
    for row in r.slate:
        assert len(set(row.tolist())) == len(row)


def test_empty_request_wave():
    srv = _server()
    r = srv.serve(np.array([], np.int64), 5 * DAY)
    assert r.scores.shape == (0, _CFG.vocab_padded)
    assert r.slate.shape == (0, 3)


# ----------------------------------------------------------------------
# Satellite: snapshot-generation rollover invalidates the cache
# ----------------------------------------------------------------------

@pytest.mark.parametrize("offset", [0, 6 * 3600])
def test_generation_rollover_invalidates_cache(offset):
    """When maybe_run_due_snapshots rolls a generation (including on a
    non-midnight offset grid), cached prefill states from the old
    generation must not serve: the server must re-prefill from the new
    snapshot and match a never-cached oracle bit-for-bit in decision and
    allclose in scores."""
    events = _seed_events()
    srv = _server(snapshot_offset=offset, events=events)
    users = np.arange(10)
    t1 = 5 * DAY + offset + 100          # inside generation A
    r1 = srv.serve(users, t1)
    gen_a = srv.injector.generation(t1)
    assert gen_a == 5 * DAY + offset
    assert r1.cache_misses == 10

    # events that generation B's snapshot will absorb
    rng = np.random.RandomState(9)
    _ingest(srv, users, rng.randint(0, N_ITEMS, 10), np.full(10, t1 + 500))

    t2 = 6 * DAY + offset + 100          # past the next boundary
    r2 = srv.serve(users, t2)
    gen_b = srv.injector.generation(t2)
    assert gen_b == 6 * DAY + offset and gen_b != gen_a
    assert srv.cache.invalidations >= 10  # old generation purged eagerly
    assert r2.cache_misses == 10          # nothing served from gen A state
    # every remaining entry belongs to the new generation
    assert all(g == gen_b for (_, g) in srv.cache._entries)

    # oracle: a fresh identical stack (same events, same RNG stream) that
    # never cached anything
    oracle = _server(snapshot_offset=offset, events=events, use_cache=False)
    _ingest(oracle, users, np.random.RandomState(9).randint(0, N_ITEMS, 10),
            np.full(10, t1 + 500))
    ro = oracle.serve(users, t2)
    np.testing.assert_allclose(r2.scores, ro.scores, atol=2e-3, rtol=2e-3)
    np.testing.assert_array_equal(r2.slate, ro.slate)


def test_stale_state_differs_from_fresh_state():
    """The rollover test above would be vacuous if generations A and B
    produced identical scores — show the generation roll actually moves
    the features for at least one user."""
    events = _seed_events()
    srv = _server(events=events)
    users = np.arange(10)
    t1 = 5 * DAY + 100
    r1 = srv.serve(users, t1)
    rng = np.random.RandomState(9)
    _ingest(srv, users, rng.randint(0, N_ITEMS, 10), np.full(10, t1 + 500))
    r2 = srv.serve(users, 6 * DAY + 100)
    assert np.abs(r1.scores - r2.scores).max() > 1e-3


# ----------------------------------------------------------------------
# Cache unit behavior
# ----------------------------------------------------------------------

def test_prefill_state_cache_lru_order():
    c = PrefillStateCache(budget=2)
    c.put(1, 0, {"x": 1})
    c.put(2, 0, {"x": 2})
    assert c.get(1, 0)["x"] == 1         # 1 becomes MRU
    c.put(3, 0, {"x": 3})                # evicts 2 (LRU)
    assert c.get(2, 0) is None
    assert c.get(1, 0) is not None and c.get(3, 0) is not None
    assert c.evictions == 1


def test_prefill_state_cache_generation_keys():
    c = PrefillStateCache(budget=8)
    c.put(1, 100, {"x": "old"})
    assert c.get(1, 200) is None         # other generation never hits
    c.put(1, 200, {"x": "new"})
    assert c.invalidate_except(200) == 1
    assert c.get(1, 200)["x"] == "new"
    assert (1, 100) not in c


def test_prefill_state_cache_rejects_zero_budget():
    with pytest.raises(ValueError):
        PrefillStateCache(budget=0)
