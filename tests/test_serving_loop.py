"""End-to-end injection serving: cache correctness under interleaved
ingest/serve traffic.

The load-bearing invariant: the prefill-state cache is an *optimization
only* — for any request stream, the cached-inject path must produce the
same scores/slates as full-prefill-per-request, including across LRU
eviction and snapshot-generation rollover (stale cached state must never
serve).

These tests drive the Gateway's submit/poll surface directly (the wave
shape is just ``submit_many`` + ``drain``); the deprecated
``InjectionServer.serve()`` shim is exercised only by the dedicated
shim-boundary test at the bottom.
"""
import dataclasses

import numpy as np
import pytest

from conftest import DAY, N_ITEMS, N_USERS
from conftest import ingest as _ingest
from conftest import make_gateway, seed_events as _seed_events
from conftest import seeded_injector, tiny_engine
from repro.serving.api import Request
from repro.serving.loop import InjectionServer, PrefillStateCache, ServerConfig
from repro.serving.scheduler import Gateway

_ENGINE = tiny_engine()  # the conftest session-shared tiny platform
_CFG = _ENGINE.cfg


def _injector(policy="inject", snapshot_offset=0, events=None):
    return seeded_injector(policy, snapshot_offset, events)


def _server(policy="inject", use_cache=True, cache_entries=256,
            snapshot_offset=0, events=None, slate_len=3):
    return make_gateway(policy, engine=_ENGINE,
                        snapshot_offset=snapshot_offset, events=events,
                        slate_len=slate_len, cache_entries=cache_entries,
                        use_cache=use_cache)


@dataclasses.dataclass
class _Wave:
    scores: np.ndarray
    slate: np.ndarray
    cache_hits: int
    cache_misses: int


def _serve(gw: Gateway, users, now) -> _Wave:
    """One wave on the streaming surface: submit_many + drain, results
    claimed via poll() (inside drain) in submission order."""
    users = np.asarray(users, np.int64).ravel()
    h0, m0 = gw.cache.hits, gw.cache.misses
    tickets = gw.submit_many(
        [Request(user=int(u), now=int(now)) for u in users])
    done = {t.request_id: t for t in gw.drain(now)}
    assert all(t.request_id in done and t.done for t in tickets)
    if not len(users):
        return _Wave(np.zeros((0, gw.engine.cfg.vocab_padded), np.float32),
                     np.zeros((0, gw.cfg.slate_len), np.int32), 0, 0)
    return _Wave(np.stack([t.response.scores for t in tickets]),
                 np.stack([t.response.slate for t in tickets]),
                 gw.cache.hits - h0, gw.cache.misses - m0)


# ----------------------------------------------------------------------

@pytest.mark.slow
def test_cached_equals_full_prefill_interleaved():
    """Cached-inject scores == full-prefill scores over interleaved
    ingest/serve waves (the differential that makes the cache safe)."""
    cached, full = _server(use_cache=True), _server(use_cache=False)
    rng = np.random.RandomState(1)
    now = 5 * DAY + 100
    for wave in range(4):
        u = rng.randint(0, N_USERS, 10)
        it = rng.randint(0, N_ITEMS, 10)
        t = np.full(10, now - 40)
        _ingest(cached, u, it, t)
        _ingest(full, u, it, t)
        q = rng.randint(0, N_USERS, 11)  # pane-splits at max_batch=4
        rc = _serve(cached, q, now)
        rf = _serve(full, q, now)
        np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
        np.testing.assert_array_equal(rc.slate, rf.slate)
        now += 300
    assert cached.cache.hits > 0  # the comparison actually exercised hits


def test_cache_hits_skip_prefill():
    srv = _server()
    now = 5 * DAY + 100
    users = np.arange(8)
    _serve(srv, users, now)
    n_prefills = srv.prefill_calls
    r = _serve(srv, users, now + 10)
    assert srv.prefill_calls == n_prefills  # no new prefill on the hot path
    assert r.cache_hits == 8 and r.cache_misses == 0


def test_lru_eviction_stays_correct():
    """Budget smaller than the working set: evictions happen, results
    still match the uncached oracle."""
    srv = _server(cache_entries=6)
    full = _server(use_cache=False)
    now = 5 * DAY + 100
    for lo in (0, 8, 16, 0):  # revisit evicted users
        q = np.arange(lo, lo + 8) % N_USERS
        rc = _serve(srv, q, now)
        rf = _serve(full, q, now)
        np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
    assert srv.cache.evictions > 0
    assert len(srv.cache) <= 6


def test_batch_policy_ignores_fresh_events():
    """Control arm sanity: with policy='batch' the cache serves identical
    scores before and after fresh events arrive (that's the staleness the
    paper's injection closes; 'inject' must move)."""
    b_srv, i_srv = _server(policy="batch"), _server(policy="inject")
    now = 5 * DAY + 100
    users = np.arange(6)
    sb0 = _serve(b_srv, users, now).scores
    si0 = _serve(i_srv, users, now).scores
    _ingest(b_srv, users, (users + 7) % N_ITEMS, np.full(6, now + 5))
    _ingest(i_srv, users, (users + 7) % N_ITEMS, np.full(6, now + 5))
    sb1 = _serve(b_srv, users, now + 50).scores
    si1 = _serve(i_srv, users, now + 50).scores
    np.testing.assert_allclose(sb0, sb1, atol=1e-5)
    assert np.abs(si0 - si1).max() > 1e-3


def test_fresh_policy_never_caches():
    srv = _server(policy="fresh")
    now = 5 * DAY + 100
    _serve(srv, np.arange(4), now)
    _serve(srv, np.arange(4), now + 10)
    assert srv.cache.hits == 0 and len(srv.cache) == 0
    assert srv.prefill_calls == 2


def test_warm_precomputes_prefill_states():
    """warm() admits batch-history states so live traffic starts on the
    inject-only path; it must not change served scores."""
    warmed, cold = _server(), _server()
    now = 5 * DAY + 100
    users = np.arange(12)
    n = warmed.warm(users, now)
    assert n == 12 and len(warmed.cache) == 12
    r_warm = _serve(warmed, users, now)
    assert r_warm.cache_hits == 12 and r_warm.cache_misses == 0
    r_cold = _serve(cold, users, now)
    np.testing.assert_allclose(r_warm.scores, r_cold.scores,
                               atol=2e-3, rtol=2e-3)
    # warm is a no-op for uncacheable configurations
    assert _server(use_cache=False).warm(users, now) == 0
    assert _server(policy="fresh").warm(users, now) == 0


def test_warm_clamps_to_cache_budget():
    """Warming past the LRU budget would prefill states that evict before
    they ever serve — warm() clamps instead of wasting the work."""
    srv = _server(cache_entries=6)
    n = srv.warm(np.arange(20), 5 * DAY + 100)
    assert n == 6 and len(srv.cache) == 6
    assert srv.cache.evictions == 0


@pytest.mark.slow
def test_history_longer_than_prefill_len_paths_agree():
    """feature_len > prefill_len: both paths must truncate the history
    identically (history to prefill_len, then the suffix appended) or the
    cache would change scores."""
    eng = tiny_engine(prefill_len=16)

    def srv_with(use_cache):
        return Gateway(eng, _injector(), ServerConfig(
            slate_len=3, cache_entries=64, use_cache=use_cache))

    cached, full = srv_with(True), srv_with(False)
    now = 5 * DAY + 100
    users = np.arange(8)  # FEATURE_LEN=24 history > prefill_len=16
    _ingest(cached, users, (users + 3) % N_ITEMS, np.full(8, now - 20))
    _ingest(full, users, (users + 3) % N_ITEMS, np.full(8, now - 20))
    rc, rf = _serve(cached, users, now), _serve(full, users, now)
    np.testing.assert_allclose(rc.scores, rf.scores, atol=2e-3, rtol=2e-3)
    np.testing.assert_array_equal(rc.slate, rf.slate)


def test_duplicate_users_count_per_row():
    """Hit/miss counters are in request (row) units even when a wave
    repeats a user; the repeated miss still pays only one admission."""
    srv = _server()
    now = 5 * DAY + 100
    r = _serve(srv, np.array([5, 5, 5]), now)
    assert r.cache_misses == 3 and r.cache_hits == 0
    assert srv.prefill_calls == 1  # one admission, not three
    r = _serve(srv, np.array([5, 5]), now + 10)
    assert r.cache_hits == 2 and r.cache_misses == 0


def test_slate_items_distinct():
    """A slate recommends slate_len distinct items per user."""
    srv = _server(slate_len=4)
    r = _serve(srv, np.arange(8), 5 * DAY + 100)
    for row in r.slate:
        assert len(set(row.tolist())) == len(row)


def test_empty_request_wave():
    srv = _server()
    r = _serve(srv, np.array([], np.int64), 5 * DAY)
    assert r.scores.shape == (0, _CFG.vocab_padded)
    assert r.slate.shape == (0, 3)


# ----------------------------------------------------------------------
# Satellite: snapshot-generation rollover invalidates the cache
# ----------------------------------------------------------------------

@pytest.mark.parametrize("offset", [0, 6 * 3600])
def test_generation_rollover_invalidates_cache(offset):
    """When maybe_run_due_snapshots rolls a generation (including on a
    non-midnight offset grid), cached prefill states from the old
    generation must not serve: the server must re-prefill from the new
    snapshot and match a never-cached oracle bit-for-bit in decision and
    allclose in scores."""
    events = _seed_events()
    srv = _server(snapshot_offset=offset, events=events)
    users = np.arange(10)
    t1 = 5 * DAY + offset + 100          # inside generation A
    r1 = _serve(srv, users, t1)
    gen_a = srv.injector.generation(t1)
    assert gen_a == 5 * DAY + offset
    assert r1.cache_misses == 10

    # events that generation B's snapshot will absorb
    rng = np.random.RandomState(9)
    _ingest(srv, users, rng.randint(0, N_ITEMS, 10), np.full(10, t1 + 500))

    t2 = 6 * DAY + offset + 100          # past the next boundary
    r2 = _serve(srv, users, t2)
    gen_b = srv.injector.generation(t2)
    assert gen_b == 6 * DAY + offset and gen_b != gen_a
    # all 10 users changed: their gen-A entries are retained as stale
    # handoff first-victims (not purged eagerly), keyed to gen A so they
    # can never serve a gen-B request
    assert len(srv.cache._handoff_stale) == 10
    assert r2.cache_misses == 10          # nothing served from gen A state
    # every remaining entry is either new-generation or stale-marked
    assert all(g == (gen_b, 0) or k in srv.cache._handoff_stale
               for k in srv.cache._entries for (_, g) in [k])

    # oracle: a fresh identical stack (same events, same RNG stream) that
    # never cached anything
    oracle = _server(snapshot_offset=offset, events=events, use_cache=False)
    _ingest(oracle, users, np.random.RandomState(9).randint(0, N_ITEMS, 10),
            np.full(10, t1 + 500))
    ro = _serve(oracle, users, t2)
    np.testing.assert_allclose(r2.scores, ro.scores, atol=2e-3, rtol=2e-3)
    np.testing.assert_array_equal(r2.slate, ro.slate)


def test_stale_state_differs_from_fresh_state():
    """The rollover test above would be vacuous if generations A and B
    produced identical scores — show the generation roll actually moves
    the features for at least one user."""
    events = _seed_events()
    srv = _server(events=events)
    users = np.arange(10)
    t1 = 5 * DAY + 100
    r1 = _serve(srv, users, t1)
    rng = np.random.RandomState(9)
    _ingest(srv, users, rng.randint(0, N_ITEMS, 10), np.full(10, t1 + 500))
    r2 = _serve(srv, users, 6 * DAY + 100)
    assert np.abs(r1.scores - r2.scores).max() > 1e-3


# ----------------------------------------------------------------------
# The deprecated wave shim: bitwise-verified behind its boundary
# ----------------------------------------------------------------------

def test_legacy_shim_serves_bitwise_and_warns():
    """InjectionServer.serve() is formally deprecated: it must emit
    DeprecationWarning and stay a pure repackaging of the Gateway —
    bitwise-identical slates/scores and identical hit counters to
    submit_many + drain on an identical stack."""
    shim = InjectionServer(_ENGINE, _injector(), ServerConfig(
        slate_len=3, cache_entries=256))
    gw = _server()
    rng = np.random.RandomState(3)
    now = 5 * DAY + 100
    for wave in range(3):
        q = rng.randint(0, N_USERS, 9)
        with pytest.deprecated_call():
            rs = shim.serve(q, now)
        rg = _serve(gw, q, now)
        np.testing.assert_array_equal(rs.slate, rg.slate)
        np.testing.assert_array_equal(rs.scores, rg.scores)
        assert (rs.cache_hits, rs.cache_misses) == \
            (rg.cache_hits, rg.cache_misses)
        now += 300
    assert shim.cache.hits == gw.cache.hits > 0


# ----------------------------------------------------------------------
# Cache unit behavior
# ----------------------------------------------------------------------

def test_prefill_state_cache_lru_order():
    c = PrefillStateCache(budget=2)
    c.put(1, 0, {"x": 1})
    c.put(2, 0, {"x": 2})
    assert c.get(1, 0)["x"] == 1         # 1 becomes MRU
    c.put(3, 0, {"x": 3})                # evicts 2 (LRU)
    assert c.get(2, 0) is None
    assert c.get(1, 0) is not None and c.get(3, 0) is not None
    assert c.evictions == 1


def test_prefill_state_cache_generation_keys():
    c = PrefillStateCache(budget=8)
    c.put(1, 100, {"x": "old"})
    assert c.get(1, 200) is None         # other generation never hits
    c.put(1, 200, {"x": "new"})
    assert c.invalidate_except(200) == 1
    assert c.get(1, 200)["x"] == "new"
    assert (1, 100) not in c


def test_prefill_state_cache_rejects_zero_budget():
    with pytest.raises(ValueError):
        PrefillStateCache(budget=0)


# ----------------------------------------------------------------------
# Satellite: byte-accounting drift audit
# ----------------------------------------------------------------------

def test_byte_accounting_invariant_under_interleaving():
    """``bytes_per_shard`` is a memoized counter — under any interleaving
    of put / get / rekey / invalidate / eviction it must equal the sum
    recomputed from the resident entries (drift would silently break the
    byte-budget eviction), and the byte budget must hold whenever more
    than one entry is resident."""
    rng = np.random.RandomState(0)
    c = PrefillStateCache(budget=12, byte_budget=48 * 1024, shards=4)
    gen = 100

    def recomputed():
        return sum(nb for _, nb in c._entries.values())

    for step in range(600):
        op = rng.randint(0, 6)
        user = int(rng.randint(0, 30))
        if op <= 2:  # puts dominate, including same-key overwrites
            size = int(rng.randint(1, 6000))
            c.put(user, gen, {"caches": np.zeros(size, np.float32)})
        elif op == 3:
            c.get(user, gen)
        elif op == 4 and rng.rand() < 0.2:
            new_gen = gen + DAY
            changed = rng.randint(0, 30, rng.randint(0, 12))
            c.rekey_generation(gen, new_gen, changed)
            gen = new_gen
        elif op == 5 and rng.rand() < 0.1:
            c.invalidate_except(gen)
        assert c.bytes_per_shard == recomputed(), f"drift at step {step}"
        if len(c._entries) > 1:
            assert c.bytes_per_shard <= c.byte_budget
    assert c.evictions > 0 and c.rekeys > 0 and c.invalidations > 0
    c.invalidate_except(gen - 1)  # drain everything (no entry matches)
    c.invalidate_except(gen + 1)
    assert len(c) == 0 and c.bytes_per_shard == 0


def test_gateway_byte_accounting_exact_across_rollover_and_rewarm():
    """The full serving flow — admissions, evictions, a warm-handoff
    generation roll, budgeted re-warming — keeps the gateway cache's
    byte counter exactly equal to the recomputed per-entry sum."""
    gw = _server(cache_entries=8)

    def check():
        assert gw.cache.bytes_per_shard == \
            sum(nb for _, nb in gw.cache._entries.values())

    now = 5 * DAY + 100
    _serve(gw, np.arange(12), now)          # misses + evictions (budget 8)
    check()
    assert gw.cache.evictions > 0
    users = np.arange(6)
    _ingest(gw, users, (users + 5) % N_ITEMS, np.full(6, now + 200))
    _serve(gw, np.arange(10), 6 * DAY + 100)  # rollover: rekey + retain
    check()
    assert gw.cache.rekeys > 0
    # changed users' old-gen entries are retained as stale first-victims
    # through the handoff window; byte accounting must hold for them too
    assert len(gw.cache._handoff_stale) + gw.cache.stale_evictions > 0
    while gw.warm_step(2):                   # budgeted re-warm to empty
        check()
    check()
