"""Hypothesis property tests on system invariants.

Skips cleanly when hypothesis is absent (CI installs it via the ``test``
extra; a bare runtime environment still collects the suite).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.metrics import two_proportion_z
from repro.kernels.history_merge.ops import history_merge
from repro.kernels.history_merge.ref import history_merge_python
from repro.models.ssm import _segsum
from repro.training.optimizer import AdamWConfig, lr_schedule

events = st.lists(
    st.tuples(st.integers(0, 15), st.integers(0, 500)), min_size=0,
    max_size=20)


@settings(max_examples=40, deadline=None)
@given(batch=events, rt=events, k=st.integers(1, 24))
def test_history_merge_properties(batch, rt, k):
    """Kernel output == plain-python oracle, for arbitrary event lists —
    covers duplicates within a buffer, ties, empty buffers, truncation."""
    lb, lr = max(len(batch), 1), max(len(rt), 1)
    bi = np.zeros((1, lb), np.int32); bt = np.zeros((1, lb), np.int32)
    bv = np.zeros((1, lb), np.int32)
    for i, (it, t) in enumerate(batch):
        bi[0, i], bt[0, i], bv[0, i] = it, t, 1
    ri = np.zeros((1, lr), np.int32); rtt = np.zeros((1, lr), np.int32)
    rv = np.zeros((1, lr), np.int32)
    for i, (it, t) in enumerate(rt):
        ri[0, i], rtt[0, i], rv[0, i] = it, t, 1
    oi, ot, ov = history_merge(*(jnp.asarray(a) for a in
                                 (bi, bt, bv, ri, rtt, rv)),
                               out_len=k, impl="xla")
    got = [(int(i), int(t)) for i, t, v in zip(oi[0], ot[0], ov[0]) if v]
    want = history_merge_python(batch, rt, k)
    assert got == want

    # invariants: unique items, ascending ts, bounded length
    items = [i for i, _ in got]
    assert len(set(items)) == len(items)
    assert all(got[i][1] <= got[i + 1][1] for i in range(len(got) - 1))
    assert len(got) <= k


_ENGINES = {}


def _property_engine(arch):
    """One shared tiny engine per arch (jit caches reused across examples)."""
    if arch not in _ENGINES:
        from repro.configs.base import get_config, reduced
        from repro.models.model import init_params
        from repro.serving.engine import ServingConfig, ServingEngine
        cfg = reduced(get_config(arch), d_model=64)
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        eng = ServingEngine(cfg, params, ServingConfig(
            max_batch=2, prefill_len=16, inject_len=8, cache_capacity=48))
        _ENGINES[arch] = eng
    return _ENGINES[arch]


tok_seq = st.lists(st.integers(1, 500), min_size=0, max_size=12)
suffix_seq = st.lists(st.integers(1, 500), min_size=0, max_size=6)


@settings(max_examples=15, deadline=None)
@given(h0=tok_seq, h1=tok_seq, s0=suffix_seq, s1=suffix_seq)
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m"])
def test_prefill_inject_equals_full_prefill(arch, h0, h1, s0, s1):
    """engine.prefill(hist) -> inject(suffix) must produce the same
    next-token logits as one full prefill of hist + suffix, for one
    attention and one SSM arch — including empty suffixes and rows with
    empty history (the merge/inject path's correctness contract)."""
    eng = _property_engine(arch)
    hists, suffixes = [h0, h1], [s0, s1]

    toks, valid = eng.pad_tokens(hists, 16)
    st_ = eng.prefill(toks, valid)
    stoks, svalid = eng.pad_tokens(suffixes, 8, align="left")
    injected = eng.inject(st_, stoks, svalid)
    n_valid = svalid.sum(-1)
    rows = np.arange(2)
    got = jnp.where(jnp.asarray(n_valid > 0)[:, None],
                    injected["logits"][rows, np.maximum(n_valid - 1, 0)],
                    st_["logits"][:, -1])

    ftoks, fvalid = eng.pad_tokens([h + s for h, s in zip(hists, suffixes)], 24)
    want = eng.prefill(ftoks, fvalid)["logits"][:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-3, 3), min_size=1, max_size=12))
def test_segsum_telescopes(xs):
    """segsum[i,j] == sum over (j, i] — the SSD decay-matrix invariant."""
    x = jnp.asarray(xs, jnp.float32)
    out = np.asarray(_segsum(x))
    n = len(xs)
    cs = np.concatenate([[0.0], np.cumsum(np.asarray(xs, np.float64))])
    for i in range(n):
        for j in range(n):
            if j <= i:
                np.testing.assert_allclose(out[i, j], cs[i + 1] - cs[j + 1],
                                           atol=1e-4)
            else:
                assert out[i, j] == -np.inf


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10000), st.integers(1, 400))
def test_lr_schedule_bounds(total, step):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=max(total, 200),
                      min_lr_ratio=0.1)
    lr = float(lr_schedule(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr + 1e-12
    if step >= cfg.warmup_steps:
        assert lr >= cfg.lr * cfg.min_lr_ratio - 1e-12


# ----------------------------------------------------------------------
# Randomized scenario interleavings: pooled+continuous == wave path
# ----------------------------------------------------------------------

_op = st.one_of(
    st.tuples(st.just("submit"), st.integers(0, 39),
              st.sampled_from([None, 0, 1, 2, 5])),   # deadline offset
    st.tuples(st.just("observe"), st.integers(0, 39),
              st.integers(0, 299)),
    st.tuples(st.just("tick"), st.integers(1, 3)),
    st.tuples(st.just("flush"),),
)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(ops1=st.lists(_op, min_size=2, max_size=14),
       ops2=st.lists(_op, min_size=2, max_size=14))
def test_random_interleaving_pooled_continuous_equals_wave(ops1, ops2):
    """Scenario-shaped traffic as a property: an arbitrary interleaving
    of submit/observe/tick/flush ops — submits carrying deadlines
    (including deadline == now), with a generation rollover injected
    between the two op halves — served by the pooled + continuous +
    shedding gateway must be bitwise equal, request by request, to the
    host-LRU wave path (submit + immediate flush at the same clock),
    for every request the shedder admits. Shed tickets must be exactly
    the difference, and nothing may be dropped."""
    from conftest import make_gateway, tiny_engine

    cont = make_gateway(engine=tiny_engine(), pool_slots=16, max_wait=0,
                        pane_service_time=1, shed_policy="deadline")
    wave = make_gateway(engine=tiny_engine())
    now = 5 * 86400 + 100
    pairs = []

    def play(ops):
        nonlocal now
        from repro.serving.api import Request
        for op in ops:
            if op[0] == "submit":
                _, user, dl = op
                req = Request(user=user, now=now,
                              deadline=None if dl is None else now + dl)
                a = cont.submit(req)      # served-or-shed on arrival
                b = wave.submit(req)
                wave.flush(now)           # the wave path: flush per wave
                assert a.done and b.done
                pairs.append((a, b))
            elif op[0] == "observe":
                cont.observe((op[1], op[2], now))
                wave.observe((op[1], op[2], now))
            elif op[0] == "tick":
                now += op[1]
                cont.tick(now)
                wave.tick(now)
            else:
                cont.flush(now)
                wave.flush(now)

    play(ops1)
    now += 86400                          # mid-trace generation rollover
    cont.tick(now)
    wave.tick(now)
    play(ops2)
    cont.drain(now)
    wave.drain(now)

    shed = 0
    for a, b in pairs:
        assert not b.response.shed        # no shed policy on the wave side
        if a.response.shed:
            shed += 1
            assert a.response.telemetry.path == "shed"
            assert a.response.slate.size == 0
            continue
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)
        assert a.response.telemetry.policy == b.response.telemetry.policy
    assert cont.stats()["shed"] == shed   # every rejection accounted for
    assert cont.stats()["rollover"].rollovers >= 1


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(ops1=st.lists(_op, min_size=2, max_size=14),
       ops2=st.lists(_op, min_size=2, max_size=14),
       mid=st.lists(st.tuples(st.integers(0, 39), st.integers(0, 299)),
                    min_size=0, max_size=6))
def test_random_schedule_background_build_equals_sync(ops1, ops2, mid):
    """The off-thread builder as a property: a randomized
    submit/observe/tick/flush schedule spanning a generation rollover,
    served once with ``background_build=True`` and once with the
    synchronous build, must produce bitwise-identical slates for every
    request — with extra observe traffic landing WHILE the background
    build is in flight (``mid``; stamped at the current clock, so both
    gateways' installed planes cover the same event window), and the
    rollover stats reconciled on every deterministic field."""
    import time

    from conftest import make_gateway, tiny_engine
    from repro.serving.api import Request

    eng = tiny_engine()
    bg = make_gateway(engine=eng, background_build=True)
    sync = make_gateway(engine=eng)
    now = 5 * 86400 + 100
    pairs = []

    def play(ops):
        nonlocal now
        for op in ops:
            if op[0] == "submit":
                _, user, dl = op
                req = Request(user=user, now=now,
                              deadline=None if dl is None else now + dl)
                a = bg.submit(req)
                b = sync.submit(req)
                bg.flush(now)
                sync.flush(now)
                pairs.append((a, b))
            elif op[0] == "observe":
                bg.observe((op[1], op[2], now))
                sync.observe((op[1], op[2], now))
            elif op[0] == "tick":
                now += op[1]
                bg.tick(now)
                sync.tick(now)
            else:
                bg.flush(now)
                sync.flush(now)

    play(ops1)
    now += 86400
    bg.tick(now)              # starts the worker on the bg gateway
    for u, it in mid:         # traffic racing the in-flight build
        bg.observe((u, it, now))
        sync.observe((u, it, now))
    t0 = time.monotonic()
    while bg._builder is not None:  # settle: poll until install
        assert time.monotonic() - t0 < 60, "background build stuck"
        time.sleep(0.001)
        bg.tick(now)
    sync.tick(now)
    assert bg.injector.generation(now) == sync.injector.generation(now)
    play(ops2)
    bg.flush(now)
    sync.flush(now)

    for a, b in pairs:
        assert a.done and b.done
        np.testing.assert_array_equal(a.response.slate, b.response.slate)
        np.testing.assert_array_equal(a.response.scores, b.response.scores)
    rb = bg.stats()["rollover"]
    rs = sync.stats()["rollover"]
    for field in ("rollovers", "rekeyed", "invalidated", "retained",
                  "rebuilt", "pending_build_users", "pending_rewarm"):
        assert rb[field] == rs[field], field
    assert rb["rollovers"] >= 1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 500), st.integers(1, 500), st.integers(0, 500),
       st.integers(1, 500))
def test_two_proportion_z_symmetry(x1, n1, x2, n2):
    x1, x2 = min(x1, n1), min(x2, n2)
    z1, p1 = two_proportion_z(x1, n1, x2, n2)
    z2, p2 = two_proportion_z(x2, n2, x1, n1)
    np.testing.assert_allclose(z1, -z2, atol=1e-9)
    np.testing.assert_allclose(p1, p2, atol=1e-9)
    assert 0.0 <= p1 <= 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 30), st.integers(1, 29), st.integers(0, 3))
def test_ring_cache_layout(s, cap, shift_seed):
    """cache_from_prefill reproduces the slot = pos % capacity layout."""
    from repro.models.attention import cache_from_prefill
    cap = min(cap, s + 4)
    k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None]
    k = jnp.broadcast_to(k, (1, s, 2, 4))
    out = cache_from_prefill({"k": k, "v": k}, cap)
    kk = np.asarray(out["k"][0, :, 0, 0])
    if s >= cap:
        # slot i holds position p with p % cap == i, p in [s-cap, s)
        for i in range(cap):
            p = int(kk[i])
            assert p % cap == i and s - cap <= p < s
    else:
        np.testing.assert_array_equal(kk[:s], np.arange(s))
        assert bool(np.asarray(out["valid"])[0, s:].any()) is False


# ----------------------------------------------------------------------
# tiered EventLog == unbounded oracle (PR 10 exactness contract)
# ----------------------------------------------------------------------

_log_ops = st.lists(
    st.one_of(
        # ("e", user, item, ts): ts spread over ~6 windows of 100
        st.tuples(st.just("e"), st.integers(0, 7), st.integers(0, 50),
                  st.integers(0, 599)),
        # ("c", now): compaction point
        st.tuples(st.just("c"), st.integers(0, 700))),
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=_log_ops, k=st.integers(1, 8),
       q=st.tuples(st.integers(0, 6), st.integers(0, 6)))
def test_tiered_log_matches_unbounded_oracle(ops, k, q):
    """Randomized append/compact interleavings (including late events —
    appends after a compaction routinely land below the horizon and take
    the demotion path): every window-aligned in-retention query with
    ``k <= segment_k`` is bitwise the unbounded log's answer, and the
    conservation invariant holds throughout."""
    from repro.core.event_log import EventLog

    # retention deep enough that nothing evicts over the ts domain:
    # every query stays inside the contract's exactness regime
    log = EventLog(8, window=100, retention_windows=16, segment_k=8)
    oracle = EventLog(8)
    for op in ops:
        if op[0] == "e":
            log.append(op[1], op[2], op[3])
            oracle.append(op[1], op[2], op[3])
        else:
            log.compact(op[1])
    st_ = log.ingest_stats()
    assert st_["dropped_late"] == 0 and st_["evicted"] == 0
    assert st_["appended"] == (st_["events_hot"] + st_["events_warm"]
                               + st_["trimmed"])
    assert log.n_events == oracle.n_events
    lo, hi = 100 * min(q), 100 * (max(q) + 1)   # window-aligned
    users = np.arange(8)
    got = log.materialize(users, lo, hi, k)
    want = oracle.materialize(users, lo, hi, k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    np.testing.assert_array_equal(log.users_with_events(lo, hi),
                                  oracle.users_with_events(lo, hi))
    # the frozen view agrees with the live log
    vg = log.view().materialize(users, lo, hi, k)
    for g, w in zip(vg, want):
        np.testing.assert_array_equal(g, w)
