"""A/B statistics: significance tests behave correctly on known inputs."""
import numpy as np

from repro.core.metrics import paired_user_test, two_proportion_z


def test_z_test_detects_large_lift():
    z, p = two_proportion_z(3500, 10000, 3000, 10000)
    assert z > 5 and p < 1e-6


def test_z_test_null_case():
    z, p = two_proportion_z(3000, 10000, 3000, 10000)
    assert abs(z) < 1e-9 and p > 0.99


def _paired_data(lift, n_users=400, seed=0):
    rng = np.random.RandomState(seed)
    imp = rng.poisson(30, n_users) + 1
    base = np.clip(rng.normal(0.3, 0.05, n_users), 0.05, 0.9)
    cw = rng.binomial(imp, base)
    tw = rng.binomial(imp, np.clip(base * (1 + lift), 0, 1))
    return tw, imp.copy(), cw, imp.copy()


def test_paired_detects_real_lift():
    r = paired_user_test(*_paired_data(0.10))
    assert r["significant"] and r["lift"] > 0.05
    assert r["ci_lo"] > 0


def test_paired_null_not_significant():
    # nominal 5% false-positive rate; P(>5 of 20 | p=.05) < 0.03%
    hits = sum(paired_user_test(*_paired_data(0.0, seed=s),
                                n_boot=500)["significant"]
               for s in range(20))
    assert hits <= 5


def test_paired_ci_contains_truth():
    r = paired_user_test(*_paired_data(0.10, n_users=2000))
    assert r["ci_lo"] <= 0.10 <= r["ci_hi"] + 0.02
