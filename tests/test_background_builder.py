"""Concurrency battery for the off-thread snapshot builder.

The `BackgroundSnapshotBuilder` is the repo's first real concurrency:
a worker thread builds the next generation's feature plane against a
frozen ``EventLog.view()`` while the serving thread keeps appending,
then the serving thread installs the finished arrays atomically. These
tests pin the contract from three sides:

* **differential** — the background-built generation is bit-for-bit
  equal to the ``run_snapshot`` oracle, under concurrent appends
  (including late events with old in-window timestamps landing
  mid-build), with the interleaving made deterministic by a
  step-barrier hook on the builder thread;
* **certification** — ``changed_users_between`` still certifies the
  handoff delta after the off-thread path (superset of the true row
  diff, so the warm rekey stays safe);
* **rollover-aware eviction order** — during the handoff window both
  caches hold dual-generation entries for changed users, and those
  evict before any live entry under budget pressure (host LRU by
  entry/byte budget; paged pool by slot pressure, pin-aware).
"""
import threading
import time

import numpy as np
import pytest

from conftest import DAY, N_ITEMS, N_USERS, make_gateway, tiny_engine

from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.serving.api import Request
from repro.serving.scheduler import PrefillStateCache

G1, G2 = 5 * DAY, 6 * DAY


def _seeded_stores(n=2, n_users=200, feature_len=16, seed=0, events=900):
    """``n`` stores fed the identical event stream, snapshotted at G1."""
    rng = np.random.RandomState(seed)
    us = rng.randint(0, n_users, events).astype(np.int64)
    its = rng.randint(0, 500, events).astype(np.int32)
    tss = rng.randint(0, G1, events).astype(np.int64)
    stores = [BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=feature_len)) for _ in range(n)]
    for s in stores:
        s.extend(us, its, tss)
        s.run_snapshot(G1)
    return stores


def _paused_builder(store, chunk=32):
    """Start a background build paused after its first worker chunk.

    Returns ``(builder, release)``: the worker is parked at a
    step-barrier inside the build — the caller appends/asserts with a
    deterministic interleaving, then sets ``release``."""
    entered = threading.Event()
    release = threading.Event()
    calls = {"n": 0}

    def hook():
        calls["n"] += 1
        if calls["n"] == 1:
            entered.set()
            assert release.wait(30), "test never released the builder"

    b = store.begin_snapshot_background(G2, step_hook=hook, chunk=chunk)
    assert entered.wait(30), "builder thread never reached the barrier"
    return b, release


# ----------------------------------------------------------------------
# differential: background build == run_snapshot oracle, bitwise
# ----------------------------------------------------------------------

def test_background_build_equals_oracle_with_midbuild_appends():
    """Deterministic interleaving: the worker is parked mid-build while
    the caller appends new-period events AND late events with old
    in-window timestamps; the installed arrays still equal the oracle's
    idempotent re-run as of install time."""
    full, bg = _seeded_stores()
    rng = np.random.RandomState(3)
    cu = rng.choice(200, 20, replace=False)
    cit = rng.randint(0, 500, 20)
    for s in (full, bg):
        s.extend(cu, cit, np.full(20, G1 + 500))

    b, release = _paused_builder(bg)
    # mid-build traffic: fresh events inside the rolled period, plus a
    # LATE arrival whose ts is old but inside the new window — the
    # previous build can't contain it, the fixup must catch it
    mid_u = np.array([7, 8, 9], np.int64)
    mid_i = np.array([41, 42, 43])
    mid_t = np.array([G2 - 50, G1 + 900, 3 * DAY])
    for s in (full, bg):
        s.extend(mid_u, mid_i, mid_t)
    release.set()
    assert b.join(60) == 0 and b.done

    full.run_snapshot(G2)  # oracle, as of the same log contents
    for a, c in zip(full._snapshots[G2], bg._snapshots[G2]):
        np.testing.assert_array_equal(a, c)
    # the late old-ts event (user 9, ts=3*DAY inside [G2-window, G2))
    # was appended after build start, so the fixup re-filled it
    assert b.late_fixups >= 1


def test_background_build_with_concurrent_append_storm():
    """Free-running (no barrier) build racing a storm of appends from
    the caller thread — the install must still be bitwise equal to the
    oracle run over the exact same final log."""
    full, bg = _seeded_stores(events=4000, n_users=400)
    b = bg.begin_snapshot_background(G2, chunk=16)
    rng = np.random.RandomState(11)
    applied = []
    while not b._built.is_set():
        u = rng.randint(0, 400, 5).astype(np.int64)
        it = rng.randint(0, 500, 5)
        ts = rng.randint(G1, G2, 5)
        bg.extend(u, it, ts)
        applied.append((u, it, ts))
        time.sleep(0)  # yield so the worker makes progress
    assert b.poll() == 0 and b.done
    for u, it, ts in applied:
        full.extend(u, it, ts)
    full.run_snapshot(G2)
    for a, c in zip(full._snapshots[G2], bg._snapshots[G2]):
        np.testing.assert_array_equal(a, c)


def test_background_full_build_on_store_without_previous_generation():
    """No previous frozen generation -> the worker does a full build
    (every user), still equal to the oracle."""
    rng = np.random.RandomState(5)
    mk = lambda: BatchFeatureStore(FeatureStoreConfig(  # noqa: E731
        n_users=64, feature_len=8))
    full, bg = mk(), mk()
    us = rng.randint(0, 64, 300)
    its = rng.randint(0, 100, 300)
    tss = rng.randint(0, G1, 300)
    for s in (full, bg):
        s.extend(us, its, tss)
    b = bg.begin_snapshot_background(G1, chunk=16)
    assert b.full_build
    assert b.join(60) == 0
    full.run_snapshot(G1)
    for a, c in zip(full._snapshots[G1], bg._snapshots[G1]):
        np.testing.assert_array_equal(a, c)


def test_certification_survives_offthread_path():
    """changed_users_between after a background build: certified (not
    None), a superset of the true row diff, and exact on the rows the
    worker pre-diffed — mid-build changed users included."""
    _, bg = _seeded_stores()
    rng = np.random.RandomState(9)
    cu = rng.choice(200, 15, replace=False)
    bg.extend(cu, rng.randint(0, 500, 15), np.full(15, G1 + 700))

    b, release = _paused_builder(bg)
    bg.extend([123], [77], [G2 - 10])  # user changes mid-build
    release.set()
    assert b.join(60) == 0

    certified = bg.changed_users_between(G1, G2)
    assert certified is not None
    pi, pt, pv = bg._snapshots[G1]
    ni, nt, nv = bg._snapshots[G2]
    true_diff = np.where(
        ((ni != pi) | (nt != pt) | (nv != pv)).any(axis=1))[0]
    assert set(true_diff.tolist()) <= set(certified.tolist())
    assert 123 in set(certified.tolist())
    # every user OUTSIDE the certified set is bitwise unchanged — the
    # property the warm rekey rests on
    keep = np.setdiff1d(np.arange(200), certified)
    np.testing.assert_array_equal(ni[keep], pi[keep])


def test_worker_exception_is_sticky():
    """A crash on the builder thread re-raises from poll() — and keeps
    re-raising; the generation must never install."""
    _, bg = _seeded_stores()

    def boom():
        raise RuntimeError("injected fault")

    b = bg.begin_snapshot_background(G2, step_hook=boom)
    b._built.wait(30)
    for _ in range(2):
        with pytest.raises(RuntimeError, match="background build"):
            b.poll()
    assert not b.done and G2 not in bg._snapshots


def test_registered_generation_rejected_like_sync_builder():
    _, bg = _seeded_stores()
    with pytest.raises(ValueError, match="already registered"):
        bg.begin_snapshot_background(G1)


# ----------------------------------------------------------------------
# gateway integration: background_build=True
# ----------------------------------------------------------------------

def _settle(gw, now, timeout=60.0):
    """Tick until the in-flight background build installs."""
    t0 = time.monotonic()
    gw.tick(now)
    while gw._builder is not None:
        assert time.monotonic() - t0 < timeout, "build never installed"
        time.sleep(0.001)
        gw.tick(now)


def test_gateway_background_rollover_bitwise_equal_sync():
    """A gateway with background_build serves bitwise the same slates
    across a rollover as the synchronous-build gateway on the same
    trace, and the rollover stats reconcile on the semantic fields."""
    eng = tiny_engine()
    gws = {"sync": make_gateway(engine=eng),
           "bg": make_gateway(engine=eng, background_build=True)}
    now = 5 * DAY + 100
    users = list(range(8))
    out = {}
    for name, gw in gws.items():
        tk = gw.submit_many([Request(user=u, now=now) for u in users])
        gw.flush(now)
        gw.observe_many([0, 1], [9, 10], [now + 300] * 2)
        if name == "bg":
            _settle(gw, now + DAY)
        else:
            gw.tick(now + DAY)
        assert gw.injector.generation(now + DAY) == 6 * DAY
        tk += gw.submit_many(
            [Request(user=u, now=now + DAY + 5) for u in users])
        gw.flush(now + DAY + 5)
        out[name] = tk
    for a, c in zip(out["sync"], out["bg"]):
        np.testing.assert_array_equal(a.response.slate, c.response.slate)
        np.testing.assert_array_equal(a.response.scores, c.response.scores)
    s1 = gws["sync"].stats()["rollover"]
    s2 = gws["bg"].stats()["rollover"]
    for field in ("rollovers", "rekeyed", "invalidated", "retained"):
        assert s1[field] == s2[field], field
    # the background gateway recorded its install's arrays, so the
    # handoff certified and rekeyed the 6 unchanged users
    assert s2["rekeyed"] == 6 and s2["retained"] == 2


def test_gateway_background_build_off_serving_thread():
    """While the worker builds, clock calls return without advancing
    the build inline: the builder thread is a different thread, and a
    paused worker never blocks tick()."""
    gw = make_gateway(background_build=True)
    now = 5 * DAY + 100
    gw.tick(now)  # catch-up (cold store) runs synchronously, by design
    gw.observe_many([0, 1, 2], [5, 6, 7], [now + 200] * 3)

    entered = threading.Event()
    release = threading.Event()
    orig = gw.injector.batch.begin_snapshot_background

    def paused(ts, **kw):
        def hook():
            if not entered.is_set():
                entered.set()
                release.wait(30)
        return orig(ts, step_hook=hook, chunk=8)

    gw.injector.batch.begin_snapshot_background = paused
    gw.tick(now + DAY)  # starts the worker; does NOT build inline
    assert entered.wait(30)
    assert gw._builder is not None
    worker = gw._builder._thread
    assert worker is not threading.current_thread() and worker.daemon
    # generation has NOT rolled: the build is in flight, serving reads
    # the previous generation (the paper's "static between snapshots")
    assert gw.injector.generation(now + DAY) == 5 * DAY
    for _ in range(3):
        gw.tick(now + DAY)  # O(1) polls while the worker is parked
    assert gw.injector.generation(now + DAY) == 5 * DAY
    release.set()
    _settle(gw, now + DAY)
    assert gw.injector.generation(now + DAY) == 6 * DAY
    st = gw.stats()["rollover"]
    assert st["rollovers"] == 1 and st["build_time_s"] > 0


# ----------------------------------------------------------------------
# rollover-aware eviction order (the handoff window's dual residency)
# ----------------------------------------------------------------------

def _entry(nbytes=64):
    return {"x": np.zeros(nbytes // 8, np.int64)}


def test_host_cache_stale_first_eviction_under_entry_pressure():
    cache = PrefillStateCache(budget=8)
    for u in range(8):
        cache.put(u, 100, _entry())
    cache.rekey_generation(100, 200, changed=[0, 1], retain_changed=True)
    assert len(cache) == 8 and cache.stats()["handoff_stale"] == 2
    # LRU order says user 2's (rekeyed) entry should go next — but the
    # stale dual-generation entries are the designated victims
    cache.put(50, 200, _entry())
    cache.put(51, 200, _entry())
    assert cache.stale_evictions == 2
    assert (0, 100) not in cache and (1, 100) not in cache
    assert (2, 200) in cache  # live LRU survived the handoff window
    # stale set drained: eviction falls back to plain LRU
    cache.put(52, 200, _entry())
    assert cache.stale_evictions == 2 and (2, 200) not in cache


def test_host_cache_stale_first_eviction_under_byte_pressure():
    cache = PrefillStateCache(budget=64, byte_budget=8 * 64)
    for u in range(8):
        cache.put(u, 100, _entry(64))
    cache.rekey_generation(100, 200, changed=[3], retain_changed=True)
    assert cache.stats()["handoff_stale"] == 1
    cache.put(60, 200, _entry(64))  # byte budget exceeded -> evict one
    assert cache.stale_evictions == 1 and (3, 100) not in cache
    assert len(cache) == 8 and cache.bytes_per_shard == 8 * 64


def test_host_cache_stale_cleared_by_next_invalidate():
    cache = PrefillStateCache(budget=8)
    for u in range(4):
        cache.put(u, 100, _entry())
    cache.rekey_generation(100, 200, changed=[0, 1], retain_changed=True)
    cache.invalidate_except(200)  # next handoff sweeps the survivors
    assert len(cache) == 2 and cache.stats()["handoff_stale"] == 0


class _FakePool:
    """Metadata stub: PagedStateCache's table logic only reads these."""
    def __init__(self, n_slots):
        self.n_slots = n_slots
        self.slot_nbytes = 1024
        self.data_shards = 1


def test_paged_cache_stale_first_eviction_pin_aware():
    from repro.serving.pool import PagedStateCache

    cache = PagedStateCache(_FakePool(4))
    slots = {u: cache.admit(u, 100, set()) for u in range(4)}
    cache.rekey_generation(100, 200, changed=[0, 1], retain_changed=True)
    assert cache.stats()["handoff_stale"] == 2
    # slot pressure with stale user 0's slot PINNED by the pane under
    # assembly: the OTHER stale entry must be the victim
    s = cache.admit(7, 200, pinned={slots[0]})
    assert s == slots[1] and cache.stale_evictions == 1
    assert (0, 100) in cache and (1, 100) not in cache
    # unpinned again: the remaining stale entry goes before any live one
    s = cache.admit(8, 200, pinned=set())
    assert s == slots[0] and cache.stale_evictions == 2
    # stale drained: plain pin-aware LRU (user 2 is now the LRU entry)
    s = cache.admit(9, 200, pinned=set())
    assert s == slots[2] and cache.stale_evictions == 2
    assert (3, 200) in cache


def test_gateway_handoff_window_evicts_stale_before_rekeyed():
    """End to end on the host LRU: after a certified handoff with
    retained entries, serving NEW users under budget pressure evicts
    the dual-generation entries first — every rekeyed (live) entry
    survives the storm."""
    gw = make_gateway(cache_entries=10)
    now = 5 * DAY + 100
    users = list(range(8))
    gw.submit_many([Request(user=u, now=now) for u in users])
    gw.flush(now)
    gw.observe_many([0, 1, 2], [11, 12, 13], [now + 500] * 3)
    gw.tick(now + DAY)
    gen_b = gw.injector.generation(now + DAY)
    st = gw.cache.stats()
    assert st["handoff_stale"] == 3 and len(gw.cache) == 8
    # 4 new users -> 12 entries against a budget of 10: 2 evictions,
    # both must come from the retained stale set
    newbies = [20, 21, 22, 23]
    gw.submit_many([Request(user=u, now=now + DAY) for u in newbies])
    gw.flush(now + DAY)
    assert gw.cache.stale_evictions == 2
    assert len(gw.cache) == 10
    for u in (3, 4, 5, 6, 7):          # every rekeyed entry survived
        assert (u, (gen_b, 0)) in gw.cache
    for u in newbies:
        assert (u, (gen_b, 0)) in gw.cache
