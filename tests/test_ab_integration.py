"""End-to-end A/B harness machinery test (micro-scale).

Validates the full phase structure — bootstrap logs → gen-1 training +
deployment (feedback loop) → gen-2 batch/consistent training → paired
arms — without asserting effect sizes (that's the full experiment in
examples/ab_experiment.py; see EXPERIMENTS.md §Paper-claims).
"""
import numpy as np
import pytest

from repro.core.ab import ABConfig, run_experiment
from repro.data.synthetic import WorldConfig


@pytest.fixture(scope="module")
def report():
    ab = ABConfig(
        world=WorldConfig(n_users=50, n_items=250, sessions_per_day=1.5,
                          seed=0),
        bootstrap_days=2, gen1_days=1, ab_days=2, feature_len=24,
        train_epochs=1, train_batch=32, max_examples=1500,
        latency_arms=(3600,))
    return run_experiment(ab, log=None)


def test_all_arms_present(report):
    assert set(report["arms"]) == {"control", "treatment", "consistent",
                                   "stale_3600s"}


def test_paired_impressions_identical(report):
    """Common random numbers: every arm faces the same impressions."""
    imps = {a["impressions"] for a in report["arms"].values()}
    assert len(imps) == 1


def test_tests_structure(report):
    t = report["tests"]["treatment_vs_control"]
    for key in ("lift", "ci_lo", "ci_hi", "p_t", "significant", "z_pooled"):
        assert key in t
    assert t["ci_lo"] <= t["lift"] <= t["ci_hi"]


def test_ctrs_in_sane_range(report):
    for a in report["arms"].values():
        assert 0.0 < a["ctr"] < 0.9
