import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run against the single real CPU device — the 512-device trick is
# strictly local to launch/dryrun.py (see the system design notes).
assert "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "dryrun XLA_FLAGS must not leak into the test environment"
