import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run against the single real CPU device — the forced-host-device
# trick (launch/dryrun.py; benchmarks serving_sharded; the subprocess
# spawned by tests/test_serving_sharded.py) must never leak into this
# process: jax locks the device count at first init, so a leaked flag
# would silently change every test's device topology.
assert "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "forced-host-device XLA_FLAGS must not leak into the test environment"
