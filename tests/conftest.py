"""Shared test scaffolding.

Path setup + the engine/gateway **fixture factory** the serving-side
test modules (test_serving_loop / test_serving_api / test_state_pool /
test_rollover / test_scenarios) build their platforms from, replacing
the per-module copies of the same tiny arch + seeded feature plane.

One engine per (mesh,) is cached for the whole session — the jit caches
live on the engine, so sharing it across modules means each pane shape
compiles once per run instead of once per file. Params come from
``PRNGKey(0)`` at fixed shapes, so every module still sees bitwise the
same model the per-module blocks used to build.

Import the helpers directly (tests/ is rootdir-style, so ``conftest``
is importable):

    from conftest import (DAY, FEATURE_LEN, N_ITEMS, N_USERS,
                          make_gateway, seeded_injector, tiny_engine)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run against the single real CPU device — the forced-host-device
# trick (launch/dryrun.py; benchmarks serving_sharded; the subprocess
# spawned by tests/test_serving_sharded.py) must never leak into this
# process: jax locks the device count at first init, so a leaked flag
# would silently change every test's device topology.
assert "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "forced-host-device XLA_FLAGS must not leak into the test environment"

DAY = 86400
N_USERS, N_ITEMS = 40, 300
FEATURE_LEN = 24

_ENGINES = {}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-wave / long-trace cases "
        "(deselect with -m 'not slow')")


def tiny_model_config(name="tiny-test"):
    """The shared 2-layer/64-wide dense ranker every serving test uses:
    small enough to prefill in milliseconds, deep enough that KV layout
    and cache handoff bugs still surface."""
    from repro.configs.base import ModelConfig
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=N_ITEMS + 256, rope_theta=1e4,
                       tie_embeddings=True)


def tiny_engine(mesh1x1=False, **scfg_kw):
    """Session-cached ServingEngine on the tiny arch (max_batch=4,
    prefill_len=32, inject_len=8 unless overridden). ``mesh1x1`` routes
    through the sharded code path on a 1x1 serving mesh. Engines with
    non-default serving shapes are cached per shape."""
    import jax
    import jax.numpy as jnp

    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    scfg_kw.setdefault("max_batch", 4)
    scfg_kw.setdefault("prefill_len", 32)
    scfg_kw.setdefault("inject_len", 8)
    scfg_kw.setdefault("cache_capacity", 64)
    key = (mesh1x1,) + tuple(sorted(scfg_kw.items()))
    if key not in _ENGINES:
        mesh = None
        if mesh1x1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(1, 1)
        cfg = tiny_model_config()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        _ENGINES[key] = ServingEngine(cfg, params, ServingConfig(**scfg_kw),
                                      mesh=mesh)
    return _ENGINES[key]


def seed_events(seed=0, n=1500, t_hi=5 * DAY):
    """The canonical seeded history: n events over [0, t_hi) uniform in
    (user, item)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    return (rng.randint(0, N_USERS, n), rng.randint(0, N_ITEMS, n),
            rng.randint(0, t_hi, n))


def seeded_injector(policy="inject", snapshot_offset=0, events=None,
                    seed=0):
    """Batch store + realtime service, both fed the same seeded event
    stream, behind a FeatureInjector with the given policy."""
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService

    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=N_USERS, feature_len=FEATURE_LEN,
        snapshot_offset=snapshot_offset))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=N_USERS, buffer_len=8, ingest_latency=0))
    us, its, tss = events if events is not None else seed_events(seed)
    store.extend(us, its, tss)
    rts.extend(us, its, tss)
    return FeatureInjector(
        InjectionConfig(policy=policy, feature_len=FEATURE_LEN), store, rts)


def make_gateway(policy="inject", engine=None, injector=None,
                 snapshot_offset=0, events=None, seed=0, **cfg_kw):
    """Gateway over the shared tiny engine + a freshly seeded platform.
    ``cfg_kw`` goes straight into ServerConfig (slate_len defaults to 3,
    cache_entries to 64, matching the historical per-module setups)."""
    from repro.serving.scheduler import Gateway, ServerConfig

    cfg_kw.setdefault("slate_len", 3)
    cfg_kw.setdefault("cache_entries", 64)
    inj = injector or seeded_injector(policy, snapshot_offset, events, seed)
    return Gateway(engine if engine is not None else tiny_engine(),
                   inj, ServerConfig(**cfg_kw))


def ingest(gw, users, items, ts):
    """Feed (user, item, ts) triples through the gateway's observe
    surface one event at a time (the trickle path)."""
    for u, i, t in zip(users, items, ts):
        gw.observe((int(u), int(i), int(t)))
