"""Sharding rules: spec trees match parameter trees, sharded dims divide."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.archs import ASSIGNED
from repro.configs.base import get_config
from repro.models.model import cache_shapes, param_shapes
from repro.sharding.rules import (ShardingRules, batch_pspec, cache_pspecs,
                                  data_axes, param_pspecs)

def _mesh(sizes, names):
    """AbstractMesh across jax versions: <=0.4.x takes one shape tuple of
    (name, size) pairs; >=0.5 takes (axis_sizes, axis_names)."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


SINGLE = _mesh((16, 16), ("data", "model"))
MULTI = _mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(shapes, specs, mesh):
    def one(s, spec):
        assert isinstance(spec, P), spec
        assert len(spec) <= len(s.shape), (s.shape, spec)
        for dim, ax in zip(s.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (s.shape, spec, dim, n)
    jax.tree.map(one, shapes, specs)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_match_and_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = param_pspecs(cfg, mesh)
    # identical tree structure (tree.map would throw otherwise)
    _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("batch", [128, 1], ids=["b128", "b1"])
def test_cache_specs_match_and_divide(arch, batch):
    cfg = get_config(arch)
    shapes = cache_shapes(cfg, batch, 32768)
    specs = cache_pspecs(cfg, SINGLE, batch)
    _check_divisible(shapes, specs, SINGLE)


def test_batch_pspec():
    assert batch_pspec(SINGLE, 256) == P(("data",), None)
    assert batch_pspec(SINGLE, 1) == P(None, None)
    assert batch_pspec(MULTI, 256) == P(("pod", "data"), None)


def test_data_axes():
    assert data_axes(SINGLE) == ("data",)
    assert data_axes(MULTI) == ("pod", "data")


def test_rules_head_vs_headdim():
    # llama: 32 heads % 16 == 0 -> heads on tp
    r = ShardingRules.make(get_config("llama3.2-1b"), SINGLE)
    assert r.attn_heads_on_tp
    # granite: 24 heads % 16 != 0 -> head_dim on tp
    r = ShardingRules.make(get_config("granite-moe-3b-a800m"), SINGLE)
    assert not r.attn_heads_on_tp
    assert r.tpa(get_config("granite-moe-3b-a800m").head_dim_) == "model"


def test_moe_expert_placement():
    # jamba 16 experts % 16 == 0 -> expert-parallel over tp
    assert ShardingRules.make(get_config("jamba-v0.1-52b"), SINGLE).moe_experts_on_tp
    # mixtral 8 experts -> TP inside experts
    assert not ShardingRules.make(get_config("mixtral-8x22b"), SINGLE).moe_experts_on_tp
