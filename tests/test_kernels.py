"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode (the kernel body executes in Python on
CPU); the TPU lowering is exercised structurally via pl.pallas_call +
BlockSpec construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention, ring_bias
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.history_merge.ops import history_merge
from repro.kernels.history_merge.ref import (history_merge_python,
                                             history_merge_ref)
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref_sequential
from repro.models.ssm import ssd_chunked


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("s,nq,nkv,hd,causal,window,dtype", [
    (256, 4, 2, 64, True, 0, jnp.float32),
    (256, 4, 4, 64, False, 0, jnp.float32),
    (384, 8, 2, 128, True, 0, jnp.float32),      # pad path (384 % 128 != 0 ok)
    (256, 4, 1, 64, True, 128, jnp.float32),     # sliding window, MQA
    (256, 4, 2, 64, True, 0, jnp.bfloat16),
])
def test_flash_attention_vs_ref(s, nq, nkv, hd, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    b = 2
    q = jax.random.normal(k1, (b, s, nq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, s, nkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, s, nkv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = jnp.moveaxis(attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=causal, window=window), 2, 1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("w,nq,nkv,hd,dtype", [
    (512, 4, 2, 64, jnp.float32),
    (1024, 8, 1, 128, jnp.float32),
    (512, 4, 4, 64, jnp.bfloat16),
])
def test_decode_attention_vs_ref(w, nq, nkv, hd, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    b = 3
    q = jax.random.normal(k1, (b, 1, nq, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(k2, (b, w, nkv, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(k3, (b, w, nkv, hd), jnp.float32).astype(dtype)
    pos = jnp.array([10, w // 2, 2 * w], jnp.int32)  # partial, half, wrapped
    out = decode_attention(q, kc, vc, pos, block_k=256, interpret=True)
    ref = jnp.moveaxis(decode_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(kc, 1, 2), jnp.moveaxis(vc, 1, 2),
        ring_bias(pos, w)), 2, 1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

def _ssd_inputs(key, b, s, nh, hp, ds, dtype):
    ks = jax.random.split(key, 5)
    x = (jax.random.normal(ks[0], (b, s, nh, hp), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)) - 2.0)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, s, ds)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, ds)) * 0.3).astype(dtype)
    D = jnp.ones((nh,), jnp.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("s,nh,hp,ds,chunk,dtype", [
    (128, 8, 32, 64, 32, jnp.float32),
    (128, 4, 64, 128, 64, jnp.float32),
    (64, 2, 32, 32, 16, jnp.bfloat16),
])
def test_ssd_kernel_vs_sequential(s, nh, hp, ds, chunk, dtype):
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(2), 2, s, nh, hp, ds, dtype)
    y, h = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    yr, hr = ssd_ref_sequential(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-3, rtol=1e-3)


def test_ssd_kernel_with_initial_state():
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(3), 2, 64, 4, 32, 64,
                                    jnp.float32)
    h0 = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 32, 64))
    y, h = ssd_scan(x, dt, A, B, C, D, chunk=32, init_state=h0, interpret=True)
    yr, hr = ssd_ref_sequential(x, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5,
                               rtol=2e-5)


def test_ssd_chunked_jnp_matches_sequential():
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(5), 2, 128, 8, 32, 64,
                                    jnp.float32)
    y, h = ssd_chunked(x, dt, A, B, C, D, chunk=32)
    yr, hr = ssd_ref_sequential(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5,
                               rtol=2e-5)


# ----------------------------------------------------------------------
# history merge (the paper's injection op)
# ----------------------------------------------------------------------

def _random_events(rng, b, lb, lr, n_items=25, tmax=1000):
    bi = rng.randint(0, n_items, (b, lb)).astype(np.int32)
    bt = rng.randint(0, tmax, (b, lb)).astype(np.int32)
    bv = (rng.rand(b, lb) < 0.8).astype(np.int32)
    ri = rng.randint(0, n_items, (b, lr)).astype(np.int32)
    rt = rng.randint(tmax // 2, 2 * tmax, (b, lr)).astype(np.int32)
    rv = (rng.rand(b, lr) < 0.8).astype(np.int32)
    return bi, bt, bv, ri, rt, rv


@pytest.mark.parametrize("lb,lr,k,seed", [
    (12, 6, 8, 0), (16, 8, 16, 1), (4, 12, 6, 2), (20, 4, 32, 3),
])
def test_history_merge_kernel_matches_python(lb, lr, k, seed):
    rng = np.random.RandomState(seed)
    arrs = _random_events(rng, 3, lb, lr)
    j = [jnp.asarray(a) for a in arrs]
    for impl in ("pallas_interpret", "xla"):
        oi, ot, ov = history_merge(*j, out_len=k, impl=impl)
        for row in range(3):
            batch = [(int(i), int(t)) for i, t, v in
                     zip(arrs[0][row], arrs[1][row], arrs[2][row]) if v]
            rt = [(int(i), int(t)) for i, t, v in
                  zip(arrs[3][row], arrs[4][row], arrs[5][row]) if v]
            want = history_merge_python(batch, rt, k)
            got = [(int(i), int(t)) for i, t, v in
                   zip(oi[row], ot[row], ov[row]) if v]
            assert got == want, (impl, row)


def test_history_merge_kernel_equals_xla_oracle():
    rng = np.random.RandomState(7)
    arrs = [jnp.asarray(a) for a in _random_events(rng, 4, 24, 12)]
    a = history_merge(*arrs, out_len=16, impl="pallas_interpret")
    b = history_merge(*arrs, out_len=16, impl="xla")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
