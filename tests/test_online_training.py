"""Online incremental training + hot-swapped delta weight patches.

Covers the full loop the subsystem adds: the trainer consuming appended
events from a frozen ``EventLog.view()``, the versioned WeightPatch wire
format, ``ServingEngine.apply_patch`` validation, the gateway's
between-panes ``install_patch`` hot swap (bitwise-equivalent to a cold
start from the patched weights, across every cache backend), the
version-keyed cache invalidation that keeps stale states from ever
serving across a swap, and the O(delta) deferred-inject re-warm
(``ServerConfig.delta_rewarm``).

Weight-patching tests build FRESH engines (never the session-cached
``tiny_engine`` — a patch would leak mutated weights into every other
module's fixtures).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (DAY, FEATURE_LEN, N_ITEMS, N_USERS, make_gateway,
                      tiny_engine, tiny_model_config)
from repro.core.event_log import EventLog
from repro.models.model import init_params
from repro.serving.api import Request
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.scheduler import ServerConfig
from repro.training import OnlineTrainer, OnlineTrainerConfig, WeightPatch
from repro.training.online import flatten_with_keystr
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig


def _tiny_params():
    return init_params(tiny_model_config(), jax.random.PRNGKey(0),
                       dtype=jnp.float32)


def _engine_with(params):
    """A private engine this test may patch (or one cold-started from a
    trainer's weights) on the conftest serving shape."""
    return ServingEngine(tiny_model_config(), params, ServingConfig(
        max_batch=4, prefill_len=32, inject_len=8, cache_capacity=64))


def _fast_tcfg(lr=3e-2):
    return TrainConfig(adamw=AdamWConfig(lr=lr, warmup_steps=2,
                                         total_steps=1000),
                       remat=False, param_dtype=jnp.float32)


def _trainer(gw, **cfg_kw):
    """Trainer over the gateway's own event log, starting from the
    engine's exact served weights."""
    return OnlineTrainer(tiny_model_config(), gw.engine.params,
                         gw.injector.batch.log,
                         cfg=OnlineTrainerConfig(**cfg_kw),
                         train_cfg=_fast_tcfg())


def _slates(tickets):
    return (np.stack([t.response.slate for t in tickets]),
            np.stack([t.response.scores for t in tickets]))


def _serve(gw, users, now):
    tk = [gw.submit(Request(user=int(u), now=int(now))) for u in users]
    gw.flush(now)
    return tk


# ----------------------------------------------------------------------
# Trainer: log consumption, learning, leaf freezing
# ----------------------------------------------------------------------

def test_trainer_consumes_log_and_learns():
    """On a perfectly predictable stream (user u always watches item u)
    the loss must fall decisively within a few dozen steps."""
    log = EventLog(n_users=8)
    tr = OnlineTrainer(tiny_model_config(), _tiny_params(), log,
                       cfg=OnlineTrainerConfig(batch_size=8, seq_len=16,
                                               min_new_events=8),
                       train_cfg=_fast_tcfg())
    t = 0
    losses = []
    for _ in range(30):
        for _ in range(16):
            log.append(t % 8, (t % 8), 1000 + t)
            t += 1
        m = tr.step()
        assert m is not None and np.isfinite(m["loss"])
        losses.append(m["loss"])
    assert tr.steps == 30 and tr.cursor == log.n_events
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.7, losses


def test_trainer_cursor_and_min_events():
    log = EventLog(n_users=8)
    tr = OnlineTrainer(tiny_model_config(), _tiny_params(), log,
                       cfg=OnlineTrainerConfig(min_new_events=4),
                       train_cfg=_fast_tcfg())
    assert tr.step() is None and tr.cursor == 0     # empty log
    for i in range(3):
        log.append(0, i, 100 + i)
    assert tr.step() is None and tr.cursor == 0     # below min_new_events
    log.append(0, 7, 200)
    assert tr.step() is not None and tr.cursor == 4  # consumed exactly
    assert tr.step() is None and tr.cursor == 4      # nothing new
    # enough NEW events, but every touched user has a single-event
    # history: untrainable batch -> no step, yet the data is consumed
    for u in (4, 5, 6, 7):
        log.append(u, 10 + u, 300 + u)
    assert tr.step() is None and tr.cursor == 8
    assert tr.steps == 1


def test_trainer_trainable_filter_freezes_leaves():
    gw = make_gateway(engine=tiny_engine())
    tr = _trainer(gw, trainable=("embed",))
    before = {k: np.asarray(v).copy()
              for k, v in flatten_with_keystr(tr.params)}
    assert tr.step() is not None
    after = dict(flatten_with_keystr(tr.params))
    moved = frozen = 0
    for k, b in before.items():
        if "embed" in k:
            moved += int(not np.array_equal(b, np.asarray(after[k])))
        else:
            # frozen by construction: bitwise the pre-step leaf
            np.testing.assert_array_equal(b, np.asarray(after[k]))
            frozen += 1
    assert moved >= 1 and frozen >= 1
    patch = tr.make_patch()
    assert patch.n_leaves >= 1
    assert all("embed" in k for k in patch.leaves)
    with pytest.raises(ValueError):
        _trainer(gw, trainable=("no_such_leaf",))


# ----------------------------------------------------------------------
# WeightPatch wire format
# ----------------------------------------------------------------------

def test_weight_patch_codec_roundtrip():
    leaves = {"['a']['w']": (np.arange(12, dtype=np.float32) * 0.1
                             ).reshape(3, 4),
              "['b']": (jnp.arange(5, dtype=jnp.float32) * 0.3
                        ).astype(jnp.bfloat16)}
    p = WeightPatch(version=3, base_version=2, step=17,
                    leaves=leaves, metadata={"note": "x"})
    q = WeightPatch.from_bytes(p.to_bytes())
    assert (q.version, q.base_version, q.step) == (3, 2, 17)
    assert q.metadata["note"] == "x"
    assert set(q.leaves) == set(leaves)
    np.testing.assert_array_equal(np.asarray(q.leaves["['a']['w']"]),
                                  np.asarray(leaves["['a']['w']"]))
    assert q.leaves["['b']"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(q.leaves["['b']"])).view(np.uint16),
        np.asarray(jax.device_get(leaves["['b']"])).view(np.uint16))
    with pytest.raises(Exception):
        WeightPatch.from_bytes(b"\x00junk" * 5)


def test_engine_apply_patch_validation():
    eng = _engine_with(_tiny_params())
    key, leaf = flatten_with_keystr(eng.params)[0]
    with pytest.raises(KeyError):
        eng.apply_patch({"['nope']": np.zeros(3, np.float32)})
    with pytest.raises(ValueError):
        eng.apply_patch({key: np.zeros(tuple(s + 1 for s in leaf.shape),
                                       np.float32)})
    with pytest.raises(ValueError):
        eng.apply_patch({key: np.zeros(leaf.shape, np.float16)})
    assert eng.apply_patch({}) == 0
    new_leaf = np.asarray(leaf) + 1.0
    assert eng.apply_patch({key: new_leaf}) == 1
    got = dict(flatten_with_keystr(eng.params))[key]
    np.testing.assert_array_equal(np.asarray(got), new_leaf)


# ----------------------------------------------------------------------
# Gateway hot swap
# ----------------------------------------------------------------------

_BACKENDS = {
    "host_lru": {},
    "pooled": {"pool_slots": 8},
    "continuous": {"max_wait": 0},
    "background_build": {"background_build": True},
}


@pytest.mark.parametrize("backend", sorted(_BACKENDS))
def test_hot_swap_bitwise_vs_cold_gateway(backend):
    """After install_patch, every response must be bitwise what a COLD
    gateway built directly from the patched weights serves — across the
    host LRU, the paged pool, continuous batching, and the
    background-build gateway. Old-version cache entries must never
    contaminate a post-swap pane."""
    kw = _BACKENDS[backend]
    gw = make_gateway(engine=_engine_with(_tiny_params()), **kw)
    t1 = 5 * DAY + 100
    users = [0, 1, 2, 3, 4, 5]
    _serve(gw, users, t1)                   # warm the old-version cache
    gw.poll()

    tr = _trainer(gw)
    for _ in range(3):
        tr.step()
    assert tr.steps >= 1
    patch = tr.make_patch()
    assert patch.version == 1 and patch.base_version == 0
    gw.install_patch(patch)

    t2 = t1 + 300
    tk = _serve(gw, users, t2)
    slates, scores = _slates(tk)
    assert all(t.response.telemetry.model_version == 1 for t in tk)
    st = gw.stats()
    assert st.model_version == 1 and st.patches_applied == 1
    assert st.patch_install_max_ms > 0.0

    # stale entries are unreachable: every resident key is new-version
    assert all(g[1] == 1 for (_, g) in gw.cache._entries)

    # cold start FROM the patched weights (trainer params == engine
    # params post-install, leaf for leaf)
    cold = make_gateway(engine=_engine_with(tr.params), **kw)
    ck = _serve(cold, users, t2)
    cs, csc = _slates(ck)
    np.testing.assert_array_equal(slates, cs)
    np.testing.assert_array_equal(scores, csc)


def test_install_patch_base_version_guard():
    gw = make_gateway(engine=_engine_with(_tiny_params()))
    tr = _trainer(gw)
    tr.step()
    p1 = tr.make_patch()
    p2 = tr.make_patch()           # based on version 1
    with pytest.raises(ValueError):
        gw.install_patch(p2)       # gateway still serves version 0
    assert gw.stats().model_version == 0
    gw.install_patch(p1)
    gw.install_patch(p2)           # now in order
    assert gw.stats().model_version == 2
    assert gw.stats().patches_applied == 2
    with pytest.raises(ValueError):
        gw.install_patch(p1)       # never rewind


def test_patch_policy_rewarm_rebuilds_under_new_version():
    gw = make_gateway(engine=_engine_with(_tiny_params()),
                      patch_policy="rewarm", rewarm_budget=8)
    t1 = 5 * DAY + 100
    users = [0, 1, 2, 3]
    _serve(gw, users, t1)
    tr = _trainer(gw)
    tr.step()
    gw.install_patch(tr.make_patch())
    assert gw.stats().rollover.pending_rewarm == len(users)
    pc0 = gw.prefill_calls
    gw.tick(t1 + 60)               # budgeted re-warm between panes
    assert gw.stats().rollover.pending_rewarm == 0
    assert gw.prefill_calls > pc0
    assert all(g[1] == 1 for (_, g) in gw.cache._entries)
    # the rebuilt states serve as hits, bitwise equal to a cold gateway
    h0 = gw.cache.hits
    tk = _serve(gw, users, t1 + 120)
    assert gw.cache.hits - h0 == len(users)
    cold = make_gateway(engine=_engine_with(tr.params))
    ck = _serve(cold, users, t1 + 120)
    np.testing.assert_array_equal(_slates(tk)[0], _slates(ck)[0])
    np.testing.assert_array_equal(_slates(tk)[1], _slates(ck)[1])


def test_attach_trainer_background_install():
    """Production shape: worker thread trains + emits, tick installs."""
    gw = make_gateway(engine=_engine_with(_tiny_params()))
    t1 = 5 * DAY + 100
    _serve(gw, [0, 1], t1)
    tr = _trainer(gw, min_new_events=1, steps_per_patch=1,
                  interval_s=0.01)
    gw.attach_trainer(tr)
    tr.start()
    try:
        deadline = time.time() + 30.0
        n = 0
        while time.time() < deadline:
            gw.tick(t1 + 60)
            if gw.stats().patches_applied >= 1:
                break
            # keep feeding the stream so the worker has data to consume
            gw.observe((n % 4, n % N_ITEMS, t1 + 200 + n))
            n += 1
            time.sleep(0.02)
    finally:
        tr.stop()
    gw.tick(t1 + 90)               # install anything still queued
    st = gw.stats()
    assert st.patches_applied >= 1
    assert st.model_version == st.patches_applied
    assert all(t_.response.telemetry.model_version == st.model_version
               for t_ in _serve(gw, [0, 1], t1 + DAY // 2))
    # a mismatched trainer must be rejected at attach time
    tr2 = _trainer(gw)
    tr2.make_patch()               # advances tr2 to version 1
    with pytest.raises(ValueError):
        gw.attach_trainer(tr2)


def test_snapshot_rollover_composes_with_model_version():
    """The two cache-key axes are independent: a snapshot roll after a
    patch keeps serving the patched weights, and entries from every
    (old snapshot, old version) combo are unreachable."""
    gw = make_gateway(engine=_engine_with(_tiny_params()),
                      rewarm_budget=4)
    t1 = 5 * DAY + 100
    users = [0, 1, 2, 3]
    _serve(gw, users, t1)
    tr = _trainer(gw)
    tr.step()
    gw.install_patch(tr.make_patch())
    _serve(gw, users, t1 + 60)     # re-admit under (gen_a, 1)
    gw.tick(t1 + DAY)              # snapshot rolls: gen_b
    gen_b = gw.injector.generation(t1 + DAY)
    st = gw.stats()
    assert st.model_version == 1
    assert st.rollover.rollovers >= 1
    tk = _serve(gw, users, t1 + DAY + 60)
    assert all(t.response.telemetry.generation == gen_b
               and t.response.telemetry.model_version == 1 for t in tk)
    assert all(g == (gen_b, 1) for (_, g) in gw.cache._entries)


# ----------------------------------------------------------------------
# O(delta) re-warm (ServerConfig.delta_rewarm)
# ----------------------------------------------------------------------

def _short_history_events(n=200, seed=4, t_hi=5 * DAY):
    """Seeded histories SHORT of feature_len, so appended events extend
    the snapshot row as a strict prefix (no window shift)."""
    rng = np.random.RandomState(seed)
    return (rng.randint(0, N_USERS, n), rng.randint(0, N_ITEMS, n),
            rng.randint(0, t_hi, n))


def test_delta_rewarm_bitwise():
    """The deferred-delta path must be bitwise the PRE-rollover inject
    path (same cached state, token-for-token the same inject stream),
    produce identical slates to a fresh-prefill gateway, and save the
    re-warm prefills it defers."""
    evts = _short_history_events()
    users = [0, 1, 2, 3, 4, 5]
    changed = [0, 1, 2]
    t1 = 5 * DAY + 100
    t2 = 6 * DAY + 100
    eng = tiny_engine()            # no weight patching here: shareable

    def _feed(g):
        # two delta events (land in gen B's snapshot) + one fresh event
        # (after gen B's cutoff) per changed user, distinct (item, ts)
        for u in changed:
            g.observe((u, 50 + u, 5 * DAY + 600 + u))
            g.observe((u, 80 + u, 5 * DAY + 700 + u))
        for u in changed:
            g.observe((u, 120 + u, 6 * DAY + 50 + u))

    # the gateway under test: delta re-warm on
    gw = make_gateway(engine=eng, events=evts, delta_rewarm=True,
                      rewarm_budget=8)
    _serve(gw, users, t1)
    _feed(gw)
    pc0 = gw.prefill_calls
    gw.tick(t1 + DAY)              # roll to gen B; delta re-warm runs
    st = gw.stats().rollover
    assert st.delta_rewarms == len(changed)
    assert gw.prefill_calls == pc0         # zero prefills paid
    tk = _serve(gw, users, t2)
    assert gw.prefill_calls == pc0         # all hits, inject path
    assert all(t.response.telemetry.cache_hit for t in tk)
    slates, scores = _slates(tk)

    # oracle 1: never-rolled gateway — the deferral IS this computation
    nr = make_gateway(engine=eng, events=evts, run_batch_jobs=False)
    nr.injector.batch.maybe_run_due_snapshots(t1)   # gen A only, ever
    _serve(nr, users, t1)
    _feed(nr)
    nk = _serve(nr, users, t2)
    ns, nsc = _slates(nk)
    np.testing.assert_array_equal(slates, ns)
    np.testing.assert_array_equal(scores, nsc)

    # oracle 2: cold gateway at gen B (fresh prefill of the new rows).
    # RoPE positions shift by the deferred-delta length, so scores agree
    # to tolerance, not bitwise; the ranked slates must still match.
    cold = make_gateway(engine=eng, events=evts)
    _feed(cold)
    cold.tick(t1 + DAY)
    ck = _serve(cold, users, t2)
    cs, csc = _slates(ck)
    np.testing.assert_array_equal(slates, cs)
    np.testing.assert_allclose(scores, csc, rtol=2e-4, atol=2e-4)


def test_delta_rewarm_falls_back_when_row_not_prefix():
    """A user whose history already fills feature_len shifts the
    snapshot window at the roll — not a prefix extension — and must take
    the full re-warm prefill instead (results still correct)."""
    us, its, tss = _short_history_events()
    # user 9 gets a FULL window: feature_len+4 events before gen A
    extra_n = FEATURE_LEN + 4
    us = np.concatenate([us, np.full(extra_n, 9)])
    its = np.concatenate([its, np.arange(extra_n) % N_ITEMS])
    tss = np.concatenate([tss, 4 * DAY + np.arange(extra_n)])
    evts = (us, its, tss)
    t1 = 5 * DAY + 100
    eng = tiny_engine()
    gw = make_gateway(engine=eng, events=evts, delta_rewarm=True,
                      rewarm_budget=8)
    _serve(gw, [9], t1)
    gw.observe((9, 33, 5 * DAY + 600))
    gw.tick(t1 + DAY)
    st = gw.stats().rollover
    assert st.delta_rewarms == 0 and st.rebuilt == 1
    tk = _serve(gw, [9], 6 * DAY + 100)
    cold = make_gateway(engine=eng, events=evts)
    cold.observe((9, 33, 5 * DAY + 600))
    cold.tick(t1 + DAY)
    ck = _serve(cold, [9], 6 * DAY + 100)
    np.testing.assert_array_equal(_slates(tk)[0], _slates(ck)[0])
    np.testing.assert_array_equal(_slates(tk)[1], _slates(ck)[1])


def test_delta_rewarm_pending_overflow_drops_to_prefill():
    """When pending delta + the realtime suffix outgrow one inject, the
    serve path drops the deferred entry and the row pays a full prefill
    — bitwise the cold path, never a truncated inject."""
    evts = _short_history_events()
    t1 = 5 * DAY + 100
    t2 = 6 * DAY + 200
    eng = tiny_engine()
    gw = make_gateway(engine=eng, events=evts, delta_rewarm=True,
                      rewarm_budget=8)
    _serve(gw, [0], t1)

    def _feed(g):
        for j in range(2):         # delta: extends the snapshot row
            g.observe((0, 60 + j, 5 * DAY + 600 + j))
    _feed(gw)
    gw.tick(t1 + DAY)
    assert gw.stats().rollover.delta_rewarms == 1

    def _flood(g):                 # 7 fresh: 2 + 7 > inject_len=8
        for j in range(7):
            g.observe((0, 100 + j, 6 * DAY + 50 + j))
    _flood(gw)
    inv0 = gw.cache.invalidations
    tk = _serve(gw, [0], t2)
    assert gw.cache.invalidations == inv0 + 1      # entry dropped
    assert tk[0].response.telemetry.path == "prefill"
    cold = make_gateway(engine=eng, events=evts)
    _feed(cold)
    cold.tick(t1 + DAY)
    _flood(cold)
    ck = _serve(cold, [0], t2)
    np.testing.assert_array_equal(_slates(tk)[0], _slates(ck)[0])
    np.testing.assert_array_equal(_slates(tk)[1], _slates(ck)[1])


def test_delta_rewarm_config_accepts_both_backends():
    # PR 10 extended O(delta) rewarm to the paged pool: the old
    # host-LRU-only rejection is gone
    cfg = ServerConfig(delta_rewarm=True, pool_slots=16)
    assert cfg.delta_rewarm and cfg.pool_slots == 16
    with pytest.raises(ValueError):
        ServerConfig(patch_policy="evict-all")
    with pytest.raises(ValueError):
        ServerConfig(log_compaction="eager")


def test_stats_surface_new_fields():
    gw = make_gateway(engine=tiny_engine())
    d = gw.stats().as_dict()
    assert d["model_version"] == 0 and d["patches_applied"] == 0
    assert d["patch_install_max_ms"] == 0.0
    assert d["rollover"]["delta_rewarms"] == 0


def test_delta_rewarm_pool_backend_bitwise():
    """Satellite: the paged device pool takes the same O(delta) deferred
    re-warm as the host LRU — identical delta_rewarms count, zero
    prefills paid at the roll, and slates/scores bitwise equal to the
    host-LRU gateway on the same stream."""
    evts = _short_history_events()
    users = [0, 1, 2, 3, 4, 5]
    changed = [0, 1, 2]
    t1 = 5 * DAY + 100
    t2 = 6 * DAY + 100
    eng = tiny_engine()

    def _feed(g):
        for u in changed:
            g.observe((u, 50 + u, 5 * DAY + 600 + u))
            g.observe((u, 80 + u, 5 * DAY + 700 + u))
        for u in changed:
            g.observe((u, 120 + u, 6 * DAY + 50 + u))

    def _run(**kw):
        gw = make_gateway(engine=eng, events=evts, delta_rewarm=True,
                          rewarm_budget=8, **kw)
        _serve(gw, users, t1)
        _feed(gw)
        pc0 = gw.prefill_calls
        gw.tick(t1 + DAY)
        assert gw.stats().rollover.delta_rewarms == len(changed)
        assert gw.prefill_calls == pc0
        tk = _serve(gw, users, t2)
        assert gw.prefill_calls == pc0
        assert all(t.response.telemetry.cache_hit for t in tk)
        return _slates(tk)

    ps, psc = _run(pool_slots=16)
    hs, hsc = _run()
    np.testing.assert_array_equal(ps, hs)
    np.testing.assert_array_equal(psc, hsc)


def test_pool_pending_dies_with_entry():
    """Pending inject tokens are host metadata keyed like the pool's
    entries: eviction, drop, and re-admission must all clear them so a
    recycled slot never inherits another generation's pending stream."""
    from repro.serving.pool import PagedStateCache

    class _StubPool:               # slot-table ops never touch the device
        n_slots = 2
        slot_nbytes = 128
        data_shards = 1

    pc = PagedStateCache(_StubPool())
    pc.admit(0, 0, pinned=set())
    pc.set_pending(0, 0, [(1, 2)])
    assert pc.has_entry(0, 0) and pc.get_pending(0, 0) == [(1, 2)]
    pc.admit(1, 0, pinned=set())
    pc.admit(2, 0, pinned=set())   # slot pressure: evicts user 0 (LRU)
    assert not pc.has_entry(0, 0) and pc.get_pending(0, 0) is None
    pc.admit(0, 0, pinned=set())   # re-admitted into a recycled slot
    assert pc.get_pending(0, 0) is None
    pc.set_pending(0, 0, [(3, 4)])
    pc.admit(0, 0, pinned=set())   # re-admission supersedes the deferral
    assert pc.get_pending(0, 0) is None
    pc.set_pending(0, 0, [(3, 4)])
    pc.drop(0, 0)
    assert pc.get_pending(0, 0) is None
    with pytest.raises(KeyError):
        pc.set_pending(9, 0, [(5, 6)])
    # rekey carries pending to the new generation key
    pc.set_pending(2, 0, [(7, 8)])
    pc.rekey_entry(2, 0, 1)
    assert pc.get_pending(2, 0) is None
    assert pc.get_pending(2, 1) == [(7, 8)]
    # generation-wide purge clears the sidecar with the table
    pc.invalidate_except(0)
    assert pc.get_pending(2, 1) is None and not pc._pending


def test_trainer_missed_events_accounting():
    """Compaction with ``keep_from`` pinned at the trainer's cursor never
    loses unconsumed events (missed_events stays 0); compacting WITHOUT
    the pin under tight retention evicts unconsumed rows, and the trainer
    counts exactly the hole."""
    log = EventLog(8, window=100, retention_windows=1)
    tr = OnlineTrainer(tiny_model_config(), _tiny_params(), log,
                       cfg=OnlineTrainerConfig(batch_size=8, seq_len=16,
                                               min_new_events=8,
                                               window=10_000),
                       train_cfg=_fast_tcfg())
    for i in range(16):
        log.append(i % 8, i % 8, 10 * i)
    log.compact(1000, keep_from=tr.cursor)      # pins everything
    assert log.ingest_stats()["evicted"] == 0
    tr.step()
    assert tr.missed_events == 0 and tr.cursor == 16
    for i in range(16):
        log.append(i % 8, i % 8, 1000 + 10 * i)
    log.compact(2400)  # floor 2300: every retained event evicts (the
    assert log.ingest_stats()["evicted"] == 32  # pinned 16 + the new 16)
    tr.step()
    # ...but only the 16 the trainer had not consumed count as missed
    assert tr.missed_events == 16 and tr.cursor == 32
