"""Request-level serving API: typed Request/Response lifecycle, the
micro-batching Gateway, mixed-policy panes, deadlines, telemetry, and
the legacy wave wrapper's bitwise-compatibility contract.

The load-bearing claims, matching the redesign's acceptance criteria:

  * the Gateway serves **bitwise-identical** slates/scores to the
    legacy wave API on the same request trace — whether the trace
    arrives as waves (submit_many+flush) or trickles in request by
    request (per-request submit, pane-full flushes) — on a single
    device AND through the 1×1-mesh sharded code path;
  * a **mixed-policy pane** (batch/inject/fresh rows coexisting)
    serves every row the same result as a single-policy server of that
    row's policy — arms are request labels, not deployments;
  * a **deadline** flushes a partial pane on the clock; nothing is
    served before it fires, everything queued is served when it does;
  * construction-time validation fails fast with clear messages
    instead of shape errors inside jit.
"""
import dataclasses

import numpy as np
import pytest

from conftest import DAY, N_ITEMS, N_USERS
from conftest import ingest as _ingest
from conftest import make_gateway, seeded_injector, tiny_engine
from repro.core.ab import ARM_POLICIES, arm_requests, request_arm
from repro.serving.api import (Event, Request, as_event, assign_arms,
                               hash_arm)
from repro.serving.loop import InjectionServer, ServeResult
from repro.serving.scheduler import Gateway, ServerConfig

_ENGINE = tiny_engine()  # the conftest session-shared tiny platform
_CFG = _ENGINE.cfg


def _mesh_engine():
    return tiny_engine(mesh1x1=True)  # the 1×1-mesh sharded code path


def _injector(policy="inject"):
    return seeded_injector(policy)


def _gateway(policy="inject", engine=None, **cfg_kw):
    return make_gateway(policy, engine=engine or _ENGINE, **cfg_kw)


# ----------------------------------------------------------------------
# Construction-time validation
# ----------------------------------------------------------------------

def test_request_validation():
    with pytest.raises(ValueError, match="unknown policy"):
        Request(user=1, now=0, policy="bogus")
    with pytest.raises(ValueError, match="slate_len"):
        Request(user=1, now=0, slate_len=0)
    with pytest.raises(ValueError, match="deadline"):
        Request(user=1, now=100, deadline=99)
    with pytest.raises(ValueError, match="user"):
        Request(user=-1, now=0)
    # frozen: a request cannot be mutated after validation
    r = Request(user=1, now=0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.user = 2
    # deadline == now is legal (serve at the next clock advance)
    assert Request(user=1, now=5, deadline=5).deadline == 5


def test_server_config_validation():
    with pytest.raises(ValueError, match="slate_len"):
        ServerConfig(slate_len=0)
    with pytest.raises(ValueError, match="cache_entries"):
        ServerConfig(cache_entries=0)
    with pytest.raises(ValueError, match="cache_bytes"):
        ServerConfig(cache_bytes=0)


def test_gateway_construction_validation():
    # slate_len beyond the item vocabulary fails at construction, not as
    # a shape error inside the decode jit
    with pytest.raises(ValueError, match="vocab"):
        _gateway(slate_len=_CFG.vocab_size + 1)
    # an unknown policy string on the injector fails at the facade
    inj = _injector()
    object.__setattr__(inj.cfg, "policy", "bogus")
    with pytest.raises(ValueError, match="unknown default policy"):
        Gateway(_ENGINE, inj, ServerConfig())


def test_submit_rejects_oversized_slate_len():
    gw = _gateway()
    with pytest.raises(ValueError, match="vocab"):
        gw.submit(Request(user=1, now=0, slate_len=_CFG.vocab_size + 1))
    assert gw.pending == 0  # the bad request never entered the queue


def test_submit_rejects_out_of_range_user():
    """An unknown user fails at the call site with a clear message —
    inside pane execution it would be a numpy IndexError that takes the
    whole pane (including innocent co-batched requests) down."""
    gw = _gateway()
    with pytest.raises(ValueError, match="out of range"):
        gw.submit(Request(user=N_USERS, now=0))
    assert gw.pending == 0


def test_drain_dequeues_each_pane_as_it_serves(monkeypatch):
    """If a later pane raises mid-drain, already-served tickets must be
    out of the queue: a retried flush may re-try the failed pane but
    must never re-execute responses the caller already holds."""
    gw = _gateway()
    now = 5 * DAY + 100
    real_execute = type(gw)._execute
    calls = {"n": 0}

    def flaky(self, pane, gen):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected pane failure")
        real_execute(self, pane, gen)

    monkeypatch.setattr(type(gw), "_execute", flaky)
    reqs = [Request(user=u, now=now) for u in range(8)]  # 2 panes at b=4
    with pytest.raises(RuntimeError, match="injected"):
        gw.submit_many(reqs)
    # pane 1 served and dequeued; pane 2 failed and stayed queued
    assert gw.pending == 4 and gw.requests == 4
    monkeypatch.setattr(type(gw), "_execute", real_execute)
    first_pane_ids = [t.response.telemetry.pane_id
                      for t in gw.flush(now) if t.response]
    # recovery serves ONLY the failed pane; earlier responses untouched
    assert gw.requests == 8 and gw.pending == 0
    assert len(first_pane_ids) == 4


def test_submit_many_validates_whole_batch_before_enqueuing():
    """A bad request mid-batch must not strand earlier rows in the
    queue with their ticket handles lost to the exception."""
    gw = _gateway()
    reqs = [Request(user=1, now=0),
            Request(user=2, now=0, slate_len=_CFG.vocab_size + 1)]
    with pytest.raises(ValueError, match="vocab"):
        gw.submit_many(reqs)
    assert gw.pending == 0  # nothing enqueued, nothing orphaned


def test_as_event_coercions():
    assert as_event((1, 2, 3)) == Event(1, 2, 3)
    assert as_event(Event(1, 2, 3)) == Event(1, 2, 3)

    class Rec:
        user, item, ts = 4, 5, 6
    assert as_event(Rec()) == Event(4, 5, 6)
    with pytest.raises(TypeError, match="event"):
        as_event("nope")


# ----------------------------------------------------------------------
# Wave wrapper vs Gateway: bitwise equivalence on the same trace
# ----------------------------------------------------------------------

def _run_trace_wave(srv: InjectionServer):
    """The legacy path: pre-grouped waves through serve(users, now)."""
    rng = np.random.RandomState(3)
    now = 5 * DAY + 100
    scores, slates = [], []
    for wave in range(3):
        u = rng.randint(0, N_USERS, 10)
        _ingest(srv.gateway, u, (u + 3) % N_ITEMS, np.full(10, now - 30))
        q = rng.randint(0, N_USERS, 11)  # 2 full panes + a padded one
        with pytest.warns(DeprecationWarning):
            r = srv.serve(q, now)
        scores.append(r.scores)
        slates.append(r.slate)
        now += 300
    return np.concatenate(scores), np.concatenate(slates)


def _run_trace_trickle(gw: Gateway):
    """The same trace as per-request arrivals: submit() one at a time
    (full panes flush eagerly, in arrival order), flush() at wave end."""
    rng = np.random.RandomState(3)
    now = 5 * DAY + 100
    scores, slates = [], []
    for wave in range(3):
        u = rng.randint(0, N_USERS, 10)
        _ingest(gw, u, (u + 3) % N_ITEMS, np.full(10, now - 30))
        q = rng.randint(0, N_USERS, 11)
        tickets = [gw.submit(Request(user=int(x), now=now)) for x in q]
        gw.flush(now)
        scores.append(np.stack([t.response.scores for t in tickets]))
        slates.append(np.stack([t.response.slate for t in tickets]))
        now += 300
    return np.concatenate(scores), np.concatenate(slates)


@pytest.mark.slow
@pytest.mark.parametrize("mesh", [False, True], ids=["plain", "mesh1x1"])
def test_wave_vs_gateway_bitwise(mesh):
    """The redesign's core contract: the Gateway serves bitwise-identical
    results to the legacy wave API on the same request trace — including
    when arrivals trickle in (different pane composition: rows are
    independent, so micro-batching may regroup them freely)."""
    eng = _mesh_engine() if mesh else _ENGINE
    sw, lw = _run_trace_wave(InjectionServer(eng, _injector(),
                                             ServerConfig(slate_len=3,
                                                          cache_entries=64)))
    sg, lg = _run_trace_trickle(_gateway(engine=eng))
    np.testing.assert_array_equal(lw, lg)   # slates: bitwise
    np.testing.assert_array_equal(sw, sg)   # scores: bitwise


def test_wave_wrapper_matches_submit_many_flush():
    """serve(users, now) is literally submit_many + flush on default
    requests — same tickets, same order, same counters."""
    a, b = _gateway(), _gateway()
    srv = InjectionServer.__new__(InjectionServer)
    srv.gateway = a
    users = np.random.RandomState(5).randint(0, N_USERS, 9)
    now = 5 * DAY + 100
    with pytest.warns(DeprecationWarning):
        r = srv.serve(users, now)
    assert isinstance(r, ServeResult)
    tickets = b.submit_many(
        [Request(user=int(u), now=now) for u in users])
    b.flush(now)
    np.testing.assert_array_equal(
        r.scores, np.stack([t.response.scores for t in tickets]))
    np.testing.assert_array_equal(
        r.slate, np.stack([t.response.slate for t in tickets]))
    assert a.panes == b.panes and a.prefill_calls == b.prefill_calls


def test_legacy_serve_honors_non_monotonic_now():
    """The pre-Gateway loop served each wave AT the call's ``now`` even
    when an earlier call used a later time (replay/backfill tools rely
    on it); the shim must rewind the gateway's otherwise-monotonic
    clock rather than silently serving at max(now, previous now)."""
    t0, t1 = 5 * DAY + 100, 6 * DAY + 100  # a generation apart
    users = np.arange(6)
    time_traveler = InjectionServer(_ENGINE, _injector(),
                                    ServerConfig(slate_len=3,
                                                 cache_entries=64))
    oracle = InjectionServer(_ENGINE, _injector(),
                             ServerConfig(slate_len=3, cache_entries=64))
    with pytest.warns(DeprecationWarning):
        time_traveler.serve(users, t1)        # clock moves to t1
        r_back = time_traveler.serve(users, t0)   # ...then rewinds
        r_ref = oracle.serve(users, t0)           # fresh server at t0
    np.testing.assert_array_equal(r_back.scores, r_ref.scores)
    np.testing.assert_array_equal(r_back.slate, r_ref.slate)


def test_legacy_serve_emits_deprecation_warning():
    srv = InjectionServer(_ENGINE, _injector(),
                          ServerConfig(slate_len=3, cache_entries=16))
    with pytest.warns(DeprecationWarning, match="Gateway"):
        srv.serve(np.arange(4), 5 * DAY + 100)


# ----------------------------------------------------------------------
# Mixed-policy panes
# ----------------------------------------------------------------------

def test_mixed_policy_pane_matches_single_policy_servers():
    """Rows with different per-request policies coexist in one pane and
    each row matches a single-policy server of its policy, row for row —
    the A/B split as request labels instead of deployments."""
    now = 5 * DAY + 100
    users = np.arange(8)
    policies = ["batch", "inject", "fresh", "inject",
                "batch", "fresh", "inject", "batch"]
    fresh_items = (users + 7) % N_ITEMS

    gw = _gateway()  # default policy "inject"; per-request overrides
    _ingest(gw, users, fresh_items, np.full(8, now - 20))
    tickets = gw.submit_many(
        [Request(user=int(u), now=now, policy=p)
         for u, p in zip(users, policies)])
    gw.flush(now)
    # the pane really was mixed (not silently re-partitioned by policy)
    pane_pols = {}
    for t in tickets:
        pane_pols.setdefault(t.response.telemetry.pane_id, set()).add(
            t.response.telemetry.policy)
    assert any(len(ps) > 1 for ps in pane_pols.values())

    for pol in ("batch", "inject", "fresh"):
        ref = _gateway(pol)
        _ingest(ref, users, fresh_items, np.full(8, now - 20))
        rt = ref.submit_many([Request(user=int(u), now=now) for u in users])
        ref.flush(now)
        for i, p in enumerate(policies):
            if p != pol:
                continue
            np.testing.assert_allclose(
                tickets[i].response.scores, rt[i].response.scores,
                atol=2e-3, rtol=2e-3)
            np.testing.assert_array_equal(
                tickets[i].response.slate, rt[i].response.slate)


def test_mixed_pane_policies_actually_differ():
    """The mixed-pane test above would be vacuous if the three policies
    served identical scores — show they move for at least one row."""
    now = 5 * DAY + 100
    users = np.arange(6)
    gw = _gateway()
    _ingest(gw, users, (users + 7) % N_ITEMS, np.full(6, now - 20))
    outs = {}
    for pol in ("batch", "inject"):
        t = gw.submit_many([Request(user=int(u), now=now, policy=pol)
                            for u in users])
        gw.flush(now)
        outs[pol] = np.stack([x.response.scores for x in t])
    assert np.abs(outs["batch"] - outs["inject"]).max() > 1e-3


def test_fresh_rows_in_mixed_pane_never_cached():
    """Ephemeral admissions: a fresh-policy row rides the pane's
    admission prefill but must not enter the (user, generation) cache —
    its history depends on the request cutoff."""
    gw = _gateway()
    now = 5 * DAY + 100
    gw.submit_many([Request(user=0, now=now, policy="fresh"),
                    Request(user=1, now=now, policy="inject")])
    gw.flush(now)
    gen = gw.injector.generation(now)
    assert (1, (gen, 0)) in gw.cache and (0, (gen, 0)) not in gw.cache


# ----------------------------------------------------------------------
# Scheduling: pane-full, deadlines, duplicates
# ----------------------------------------------------------------------

def test_pane_full_flush_on_submit():
    gw = _gateway()
    now = 5 * DAY + 100
    tk = [gw.submit(Request(user=u, now=now + u)) for u in range(3)]
    assert gw.pending == 3 and not any(t.done for t in tk)
    t4 = gw.submit(Request(user=3, now=now + 3))  # fills the max_batch=4 pane
    assert gw.pending == 0 and t4.done and all(t.done for t in tk)
    # queue delay telemetry: served at the newest arrival's clock
    assert tk[0].response.telemetry.queue_delay == 3
    assert t4.response.telemetry.queue_delay == 0


def test_deadline_triggers_partial_pane_flush():
    """A short pane flushes when the clock reaches a queued deadline —
    latency beats utilization once a deadline fires."""
    gw = _gateway()
    now = 5 * DAY + 100
    t1 = gw.submit(Request(user=1, now=now, deadline=now + 30))
    t2 = gw.submit(Request(user=2, now=now + 5))
    assert gw.pending == 2 and not t1.done
    served = gw.tick(now + 29)           # deadline not reached
    assert served == [] and gw.pending == 2
    served = gw.tick(now + 30)           # deadline fires -> partial pane
    assert {t.request_id for t in served} == {t1.request_id, t2.request_id}
    assert t1.done and t2.done and gw.pending == 0
    assert t1.response.telemetry.queue_delay == 30
    assert gw.stats()["deadline_flushes"] == 1
    # slate is real: the padded pane still decodes distinct items
    assert len(set(t1.response.slate.tolist())) == 3


def test_submit_at_deadline_flushes_immediately():
    """An arrival whose clock reaches a pending deadline triggers the
    flush itself — no tick needed."""
    gw = _gateway()
    now = 5 * DAY + 100
    t1 = gw.submit(Request(user=1, now=now, deadline=now + 10))
    t2 = gw.submit(Request(user=2, now=now + 10))  # clock hits t1's deadline
    assert t1.done and t2.done and gw.pending == 0


def test_deadline_equal_to_now_at_submit_serves_immediately():
    """The boundary of ``_deadline_due`` (deadline <= clock): a request
    arriving already AT its deadline must flush inside the submit call
    itself, served at ``now`` with zero delay — not wait for a tick, and
    not count as a miss (it was served exactly on time)."""
    gw = _gateway()
    now = 5 * DAY + 100
    t = gw.submit(Request(user=1, now=now, deadline=now))
    assert t.done
    tel = t.response.telemetry
    assert tel.served_at == now and tel.queue_delay == 0
    assert gw.stats()["deadline_flushes"] == 1
    assert gw.stats()["deadline_misses"] == 0


def test_multiple_deadlines_fire_on_one_tick():
    """One coarse tick jumping past several queued deadlines: a single
    deadline flush serves them all, and each request served past its
    own deadline is counted as a miss — late service must never be
    silent."""
    gw = _gateway()
    now = 5 * DAY + 100
    t1 = gw.submit(Request(user=1, now=now, deadline=now + 5))
    t2 = gw.submit(Request(user=2, now=now, deadline=now + 5))
    t3 = gw.submit(Request(user=3, now=now + 1, deadline=now + 7))
    served = gw.tick(now + 10)
    assert {x.request_id for x in served} == \
        {t1.request_id, t2.request_id, t3.request_id}
    assert gw.stats()["deadline_flushes"] == 1  # one flush, not three
    assert gw.stats()["deadline_misses"] == 3   # all served late
    assert all(x.response.telemetry.served_at == now + 10 for x in served)


def test_deadline_fires_during_rewarm_window():
    """A deadline flush landing inside a rollover's re-warm window: the
    tick that fires the deadline must still serve the partial pane (on
    the new generation) AND keep spending the re-warm budget — the two
    duties of ``tick`` cannot starve each other."""
    gw = _gateway(rewarm_budget=1)
    now = 5 * DAY + 100
    users = np.arange(8)
    gw.warm(users, now)
    # events inside the next generation's window: all eight users change
    # across the 6*DAY boundary, so the rollover invalidates their
    # cached states and queues them for budgeted re-warm
    _ingest(gw, users, (users + 3) % N_ITEMS, np.full(8, now + 50))
    now2 = 6 * DAY + 10
    gw.tick(now2)
    st = gw.stats()
    assert st["rollover"].rollovers == 1
    pending0 = st["rollover"].pending_rewarm
    assert pending0 > 0
    t = gw.submit(Request(user=3, now=now2 + 1, deadline=now2 + 3))
    assert not t.done
    served = gw.tick(now2 + 3)          # deadline fires mid re-warm
    assert [x.request_id for x in served] == [t.request_id]
    assert t.response.telemetry.generation == 6 * DAY
    assert gw.stats()["deadline_misses"] == 0
    # the re-warm queue kept draining across the deadline tick
    assert gw.stats()["rollover"].pending_rewarm < pending0


def test_duplicate_users_one_wave_single_admission():
    """A wave repeating one cold user counts per-row misses but pays one
    admission prefill (same contract as the legacy wave path)."""
    gw = _gateway()
    now = 5 * DAY + 100
    tk = gw.submit_many([Request(user=5, now=now)] * 3)
    gw.flush(now)
    assert all(t.done for t in tk)
    assert gw.cache.misses == 3 and gw.cache.hits == 0
    assert gw.prefill_calls == 1
    # all three rows got identical results (same user, same state)
    np.testing.assert_array_equal(tk[0].response.slate, tk[1].response.slate)
    np.testing.assert_array_equal(tk[0].response.scores, tk[2].response.scores)
    tk2 = gw.submit_many([Request(user=5, now=now + 10)] * 2)
    gw.flush(now + 10)
    assert gw.cache.hits == 2 and all(
        t.response.telemetry.cache_hit for t in tk2)


def test_cache_aware_ordering_over_the_queue():
    """When more than a pane's worth is queued, hits group into pure-hit
    panes ahead of misses (the wave path's 3x win, preserved)."""
    gw = _gateway()
    now = 5 * DAY + 100
    gw.warm(np.arange(4), now)           # users 0..3 cached
    reqs = [Request(user=u, now=now) for u in (0, 30, 1, 31, 2, 32, 3, 33)]
    tk = gw.submit_many(reqs)            # 2 full panes, interleaved hit/miss
    assert all(t.done for t in tk)
    hit_panes = {t.response.telemetry.pane_id for t in tk
                 if t.response.telemetry.cache_hit}
    miss_panes = {t.response.telemetry.pane_id for t in tk
                  if not t.response.telemetry.cache_hit}
    assert hit_panes and miss_panes and not (hit_panes & miss_panes)


# ----------------------------------------------------------------------
# Per-request slate lengths
# ----------------------------------------------------------------------

def test_per_request_slate_len_masked_decode():
    """Rows with different slate_lens share one pane: each row gets
    exactly its length, items distinct, and the greedy prefix matches
    what a uniform decode of the pane max would have chosen."""
    gw = _gateway(slate_len=4)
    now = 5 * DAY + 100
    lens = [1, 2, 4, 3]
    tk = gw.submit_many([Request(user=u, now=now, slate_len=sl)
                         for u, sl in zip(range(4), lens)])
    gw.flush(now)
    uniform = _gateway(slate_len=4)
    tu = uniform.submit_many([Request(user=u, now=now) for u in range(4)])
    uniform.flush(now)
    for t, tu_i, sl in zip(tk, tu, lens):
        slate = t.response.slate
        assert slate.shape == (sl,)
        assert len(set(slate.tolist())) == sl
        assert t.response.telemetry.slate_len == sl
        np.testing.assert_array_equal(slate, tu_i.response.slate[:sl])


def test_engine_masked_decode_slate_matches_unmasked():
    """decode_slate(row_lens=) == plain decode_slate with tails masked
    to -1 — the masked program changes layout, never the chosen items."""
    eng = _ENGINE
    rng = np.random.RandomState(0)
    hists = [list(rng.randint(1, _CFG.vocab_size, 20)) for _ in range(4)]
    toks, valid = eng.pad_tokens(hists, 32)
    state = eng.prefill(toks, valid)
    first = state["logits"][:, -1]
    full = eng.decode_slate(state, first, 4)
    lens = np.array([1, 4, 2, 3], np.int32)
    masked = eng.decode_slate(state, first, 4, row_lens=lens)
    for r in range(4):
        np.testing.assert_array_equal(masked[r, :lens[r]], full[r, :lens[r]])
        assert (masked[r, lens[r]:] == -1).all()


# ----------------------------------------------------------------------
# Telemetry + facade
# ----------------------------------------------------------------------

def test_telemetry_paths_and_generation():
    gw = _gateway()
    now = 5 * DAY + 100
    users = np.arange(4)
    t1 = gw.submit_many([Request(user=int(u), now=now) for u in users])
    gw.flush(now)
    assert all(t.response.telemetry.path == "prefill" for t in t1)
    gen = gw.injector.generation(now)
    assert all(t.response.telemetry.generation == gen for t in t1)
    # no fresh events since the probe -> pure cache reads
    t2 = gw.submit_many([Request(user=int(u), now=now + 5) for u in users])
    gw.flush(now + 5)
    assert all(t.response.telemetry.path == "cached" for t in t2)
    assert all(t.response.telemetry.cache_hit for t in t2)
    # fresh events arrive -> the hits take the inject path
    _ingest(gw, users, (users + 9) % N_ITEMS, np.full(4, now + 6))
    t3 = gw.submit_many([Request(user=int(u), now=now + 10) for u in users])
    gw.flush(now + 10)
    assert all(t.response.telemetry.path == "inject" for t in t3)
    st = gw.stats()
    assert st["paths"] == {"prefill": 4, "cached": 4, "inject": 4,
                           "decay": 0}
    assert st["queue_delay"]["window"] == 12


def test_tick_rolls_generation_with_warm_handoff():
    """gateway.tick is the clock: a day boundary rolls the snapshot. By
    default the rollover is a warm handoff — users whose snapshot rows
    are unchanged keep their cached states under the new generation
    (rekeyed, not purged); with warm_handoff=False the legacy
    purge-everything rollover applies."""
    gw = _gateway()
    now = 5 * DAY + 100
    gw.submit_many([Request(user=u, now=now) for u in range(4)])
    gw.flush(now)
    gen_a = gw.injector.generation(now)
    assert len(gw.cache) == 4
    gw.tick(now + DAY)  # no events between generations: nothing changed
    gen_b = gw.injector.generation(now + DAY)
    assert gen_b != gen_a
    assert len(gw.cache) == 4 and gw.cache.rekeys == 4
    assert gw.cache.invalidations == 0
    assert all(g == (gen_b, 0) for (_, g) in gw.cache._entries)
    st = gw.stats()["rollover"]
    assert st["rollovers"] == 1 and st["rekeyed"] == 4

    # legacy contract, still available: purge-everything rollover
    gw = _gateway(warm_handoff=False)
    gw.submit_many([Request(user=u, now=now) for u in range(4)])
    gw.flush(now)
    gw.tick(now + DAY)
    assert len(gw.cache) == 0 and gw.cache.invalidations == 4
    assert gw.cache.rekeys == 0


def test_observe_feeds_both_stores():
    gw = _gateway()
    now = 5 * DAY + 100
    n_log = len(gw.injector.batch._log)
    gw.observe(Event(user=3, item=17, ts=now))
    assert len(gw.injector.batch._log) == n_log + 1
    sfx = gw.injector.fresh_suffix(np.array([3]), now + 1)
    assert (17, now) in sfx[0]


def test_warm_through_gateway():
    gw = _gateway(cache_entries=6)
    n = gw.warm(np.arange(20), 5 * DAY + 100)
    assert n == 6 and len(gw.cache) == 6 and gw.cache.evictions == 0


# ----------------------------------------------------------------------
# Per-request A/B assignment
# ----------------------------------------------------------------------

def test_hash_arm_deterministic_and_salted():
    a = [hash_arm(u) for u in range(200)]
    assert a == [hash_arm(u) for u in range(200)]      # stable
    assert set(a) == {"control", "treatment"}          # both arms used
    b = [hash_arm(u, salt=1) for u in range(200)]
    assert a != b                                      # re-randomizable
    assert assign_arms(np.arange(5)) == tuple(hash_arm(u) for u in range(5))
    with pytest.raises(ValueError):
        hash_arm(1, arms=())


def test_arm_requests_label_the_wave():
    reqs = arm_requests(np.arange(10), now=123, salt=0)
    for u, r in enumerate(reqs):
        assert r.tag == request_arm(u) and r.policy == ARM_POLICIES[r.tag]
        assert r.user == u and r.now == 123
    # both arms really occur and serve together in mixed panes
    gw = _gateway()
    tk = gw.submit_many(arm_requests(np.arange(8), now=5 * DAY + 100))
    assert {t.response.telemetry.tag for t in tk} == {"control", "treatment"}
