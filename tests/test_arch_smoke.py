"""Per-architecture smoke tests (deliverable f).

Each assigned arch gets a REDUCED same-family variant (2 layers,
d_model<=512, <=4 experts) running one forward AND one train step on CPU,
asserting output shapes and absence of NaNs. Decode-capable archs also run
one serve step. Frontend archs (vlm/audio) exercise their prefix-embedding
stubs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ASSIGNED
from repro.configs.base import get_config, reduced
from repro.models.frontend import audio_stub_embeds, vision_stub_embeds
from repro.models.model import (cache_from_prefill, decode_step, forward,
                                init_cache, init_params, prefill)
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step

B, S = 2, 32


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


def _prefix(cfg, n=4):
    if cfg.frontend == "vision":
        return vision_stub_embeds(jax.random.PRNGKey(2), B, n, cfg.d_model,
                                  jnp.float32)
    if cfg.frontend == "audio":
        return audio_stub_embeds(jax.random.PRNGKey(2), B, n, cfg.d_model,
                                 jnp.float32)
    return None


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nan(arch):
    cfg, params, toks = _setup(arch)
    pfx = _prefix(cfg)
    logits, aux = forward(params, cfg, toks, prefix_embeds=pfx)
    s_total = S + (pfx.shape[1] if pfx is not None else 0)
    assert logits.shape == (B, s_total, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg, params, toks = _setup(arch)
    pfx = _prefix(cfg)
    s_total = S + (pfx.shape[1] if pfx is not None else 0)
    batch = {"tokens": toks,
             "labels": jax.random.randint(jax.random.PRNGKey(3),
                                          (B, s_total), 0, cfg.vocab_size)}
    if pfx is not None:
        batch["prefix_embeds"] = pfx
        lm = np.ones((B, s_total), bool)
        lm[:, :pfx.shape[1]] = False
        batch["loss_mask"] = jnp.asarray(lm)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3), remat=True, q_chunk=16,
                       param_dtype=jnp.float32)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert float(metrics["loss"]) > 0 and not np.isnan(float(metrics["loss"]))
    assert not np.isnan(float(metrics["grad_norm"]))
    # parameters actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, params2))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_decode_step(arch):
    cfg, params, toks = _setup(arch)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=cfg.moe.no_drop())
    _, caches = prefill(params, cfg, toks)
    dc = cache_from_prefill(cfg, caches, capacity=64)
    pos = jnp.full((B,), S, jnp.int32)
    nxt = jax.random.randint(jax.random.PRNGKey(4), (B, 1), 0, cfg.vocab_size)
    logits, dc2 = decode_step(params, cfg, dc, nxt, pos)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not jnp.isnan(logits).any()


def test_moe_aux_loss_nonzero():
    cfg, params, toks = _setup("mixtral-8x22b")
    _, aux = forward(params, cfg, toks)
    assert float(aux) > 0


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert kinds.count("attn") == 4 and kinds.count("ssm") == 28  # 1:7
    assert cfg.mlp_kinds().count("moe") == 16  # every other layer


def test_moe_expert_parallel_split_matches_baseline():
    """The all-to-all EP f-split path (§Perf) is numerically identical."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.moe import init_moe, moe_apply
    from repro.models.common import KeyGen

    cfg = dataclasses.replace(
        reduced(get_config("mixtral-8x22b")), d_ff=64)
    cfg = dataclasses.replace(cfg, moe=cfg.moe.no_drop())
    params = init_moe(KeyGen(jax.random.PRNGKey(0)), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    base, aux0 = moe_apply(params, x, cfg)
    ep, aux1 = moe_apply(params, x, cfg, moe_sharding=("ep", None, 4))
    np.testing.assert_allclose(np.asarray(base), np.asarray(ep),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux0), float(aux1))
