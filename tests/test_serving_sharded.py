"""Sharded serving path: spec resolution, byte-accounted LRU, and
sharded-vs-single-device equivalence.

Two layers of coverage:
  * in-process — a degenerate 1×1 mesh runs the FULL sharded code path
    (serving_pspecs resolution, NamedSharding jits, device_put placement)
    on the single real CPU device and must be bit-identical to the
    mesh-free engine;
  * subprocess — tools/sharded_equiv_check.py forces 8 host devices (the
    dry-run XLA_FLAGS pattern) in a child process and asserts slate
    identity across a real 8-way data-parallel mesh. A subprocess keeps
    the forced device count out of this process (conftest.py asserts the
    flag never leaks into the tier-1 environment).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
from repro.launch.mesh import make_serving_mesh
from repro.models.model import init_params, param_shapes, prefill
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.loop import InjectionServer, PrefillStateCache, ServerConfig
from repro.sharding.rules import seq_cache_pspecs, serving_pspecs

DAY = 86400
N_USERS, N_ITEMS = 40, 300

_CFG = ModelConfig(name="shard-test", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128,
                   vocab_size=N_ITEMS + 256, rope_theta=1e4,
                   tie_embeddings=True)
_PARAMS = init_params(_CFG, jax.random.PRNGKey(0), dtype=jnp.float32)
_SCFG = ServingConfig(max_batch=4, prefill_len=32, inject_len=8,
                      cache_capacity=64)


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)
    except TypeError:  # jax <= 0.4.x signature
        return AbstractMesh(tuple(zip(names, sizes)))


def _server(mesh=None, cache_bytes=None, use_cache=True):
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=N_USERS, feature_len=24))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=N_USERS, buffer_len=8, ingest_latency=0))
    rng = np.random.RandomState(0)
    store.extend(rng.randint(0, N_USERS, 1500),
                 rng.randint(0, N_ITEMS, 1500),
                 rng.randint(0, 5 * DAY, 1500))
    rng = np.random.RandomState(0)
    rts.extend(rng.randint(0, N_USERS, 1500),
               rng.randint(0, N_ITEMS, 1500),
               rng.randint(0, 5 * DAY, 1500))
    inj = FeatureInjector(InjectionConfig(policy="inject", feature_len=24),
                          store, rts)
    eng = ServingEngine(_CFG, _PARAMS, _SCFG, mesh=mesh)
    return InjectionServer(eng, inj, ServerConfig(
        slate_len=3, cache_entries=64, cache_bytes=cache_bytes,
        use_cache=use_cache))


# ----------------------------------------------------------------------
# In-process: the sharded code path on a 1×1 mesh == the plain engine
# ----------------------------------------------------------------------

def test_mesh_1x1_bitwise_equals_plain_engine():
    plain, sharded = _server(mesh=None), _server(mesh=make_serving_mesh(1, 1))
    assert sharded.engine.data_shards == 1
    now = 5 * DAY + 100
    rng = np.random.RandomState(1)
    for wave in range(3):  # miss wave, then hit waves with fresh suffixes
        u = rng.randint(0, N_USERS, 6)
        for srv in (plain, sharded):
            srv.injector.batch.extend(u, (u + 3) % N_ITEMS,
                                      np.full(6, now - 30))
            srv.injector.realtime.extend(u, (u + 3) % N_ITEMS,
                                         np.full(6, now - 30))
        q = rng.randint(0, N_USERS, 9)
        rp, rs = plain.serve(q, now), sharded.serve(q, now)
        # one device, identical op order -> identical floats, not just close
        np.testing.assert_array_equal(rp.scores, rs.scores)
        np.testing.assert_array_equal(rp.slate, rs.slate)
        now += 200
    assert sharded.cache.hits > 0


def test_mesh_engine_rejects_uneven_batch():
    mesh = _abstract_mesh((8, 1), ("data", "model"))
    with pytest.raises(ValueError, match="multiple of the data-axis"):
        serving_pspecs(_CFG, mesh, max_batch=6)


def test_serving_params_replicated_over_data():
    """Serving replicates weights across data-parallel replicas: no param
    spec may reference the data axis (FSDP is stripped), while cache and
    token specs must shard their batch dim over it."""
    mesh = _abstract_mesh((8, 2), ("data", "model"))
    sp = serving_pspecs(_CFG, mesh, max_batch=16)

    def axes_of(spec):
        out = set()
        for ax in spec:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    out.add(a)
        return out

    for spec in jax.tree.leaves(sp.params,
                                is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in axes_of(spec), spec
    assert "data" in axes_of(sp.tokens)
    assert sp.data_shards == 8
    for spec in jax.tree.leaves(sp.seq_caches,
                                is_leaf=lambda x: isinstance(x, P)):
        assert axes_of(spec) <= {"data", "model"}


@pytest.mark.parametrize("arch_cfg", [_CFG], ids=["dense"])
def test_seq_cache_specs_match_prefill_tree(arch_cfg):
    """seq_cache_pspecs must mirror the exact pytree prefill returns —
    a structure mismatch would fail deep inside jit out_shardings."""
    mesh = _abstract_mesh((2, 1), ("data", "model"))
    specs = seq_cache_pspecs(arch_cfg, mesh, batch=4)
    shapes = param_shapes(arch_cfg, dtype=jnp.float32)
    toks = jax.ShapeDtypeStruct((4, 16), jnp.int32)
    valid = jax.ShapeDtypeStruct((4, 16), jnp.bool_)
    _, caches = jax.eval_shape(
        lambda p, t, v: prefill(p, arch_cfg, t, valid=v), shapes, toks, valid)
    # same treedef (tree.map raises otherwise) and rank compatibility
    jax.tree.map(
        lambda s, spec: None if len(spec) <= len(s.shape) else
        pytest.fail(f"{spec} too long for {s.shape}"),
        caches, specs, is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# Byte-accounted LRU
# ----------------------------------------------------------------------

def _entry(kbytes):
    return {"caches": {"k": np.zeros((kbytes * 1024 // 4,), np.float32)},
            "last_logits": np.zeros((0,), np.float32)}


def test_cache_byte_accounting_per_shard():
    c = PrefillStateCache(budget=100, shards=4)
    c.put(1, 0, _entry(64))
    assert c.bytes_per_shard == 64 * 1024 // 4
    c.put(1, 0, _entry(32))  # replacement accounts delta, not sum
    assert c.bytes_per_shard == 32 * 1024 // 4
    c.invalidate_except(99)
    assert c.bytes_per_shard == 0 and len(c) == 0
    assert c.stats()["shards"] == 4


def test_cache_byte_budget_evicts_lru():
    c = PrefillStateCache(budget=100, byte_budget=100 * 1024, shards=1)
    for u in range(4):
        c.put(u, 0, _entry(40))  # 4 * 40KiB > 100KiB -> keep newest 2
    assert len(c) == 2 and c.evictions == 2
    assert c.get(0, 0) is None and c.get(3, 0) is not None
    assert c.bytes_per_shard <= 100 * 1024


def test_cache_byte_budget_always_keeps_newest():
    """A byte budget smaller than one entry must still admit the entry
    the current pane is about to serve from."""
    c = PrefillStateCache(budget=100, byte_budget=1024, shards=1)
    c.put(7, 0, _entry(64))
    assert len(c) == 1 and c.get(7, 0) is not None


def test_server_tracks_entry_bytes():
    srv = _server(mesh=make_serving_mesh(1, 1))
    srv.serve(np.arange(8), 5 * DAY + 100)
    st = srv.cache.stats()
    assert st["entries"] == 8
    # sanity: per-entry cost is the sliced sequence-form state, nonzero
    # and far below the full-pane footprint
    assert 0 < st["bytes_per_shard"] < 64 * 2 ** 20


def test_warm_stops_at_byte_budget():
    """warm() must not keep prefilling once the byte budget is full —
    the extra states would evict each other before ever serving."""
    srv = _server(mesh=make_serving_mesh(1, 1), cache_bytes=300_000)
    warmed = srv.warm(np.arange(40), 5 * DAY + 100)
    # stopped within one pane of the first byte-pressure eviction,
    # far short of all 40 users
    assert warmed < 40
    assert warmed <= len(srv.cache) + srv.engine.scfg.max_batch


def test_sampled_slate_decode_raises():
    """A temperature>0 engine must fail loudly, not silently serve
    greedy slates."""
    import dataclasses as _dc
    eng = ServingEngine(_CFG, _PARAMS,
                        _dc.replace(_SCFG, temperature=0.7))
    with pytest.raises(NotImplementedError, match="greedy-only"):
        eng.decode_slate({"caches": None}, None, 3)


def test_byte_budget_eviction_stays_correct():
    """Serving under heavy byte pressure (entries evict constantly) must
    still match the uncached oracle — eviction can cost speed, never
    correctness."""
    tight = _server(mesh=make_serving_mesh(1, 1), cache_bytes=300_000)
    oracle = _server(mesh=None, use_cache=False)
    now = 5 * DAY + 100
    for lo in (0, 8, 0):
        q = np.arange(lo, lo + 8) % N_USERS
        rt, ro = tight.serve(q, now), oracle.serve(q, now)
        np.testing.assert_allclose(rt.scores, ro.scores, atol=2e-3,
                                   rtol=2e-3)
        np.testing.assert_array_equal(rt.slate, ro.slate)
    assert tight.cache.evictions > 0


# ----------------------------------------------------------------------
# Subprocess: real 8-device mesh (dry-run XLA_FLAGS pattern)
# ----------------------------------------------------------------------

def test_sharded_equivalence_on_8_host_devices():
    root = os.path.join(os.path.dirname(__file__), "..")
    script = os.path.join(root, "tools", "sharded_equiv_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-EQUIV OK" in out.stdout
