"""Model-level consistency: prefill+decode == forward, extend == full,
sliding-window ring semantics, pad-invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import (cache_from_prefill, decode_step, extend,
                                forward, init_cache, init_params, prefill)

FAMS = ["llama3.2-1b", "mamba2-780m", "jamba-v0.1-52b", "mixtral-8x22b",
        "granite-moe-3b-a800m", "codeqwen1.5-7b"]


def _cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe:  # capacity dropping is seq-length dependent; disable for
        cfg = dataclasses.replace(cfg, moe=cfg.moe.no_drop())  # consistency
    return cfg


def _params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = _params(cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, caches = prefill(params, cfg, toks[:, :S - 1])
    dc = cache_from_prefill(cfg, caches, capacity=64)
    dec, _ = decode_step(params, cfg, dc, toks[:, S - 1:],
                         jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", FAMS)
def test_extend_matches_full_prefill(arch):
    """The injection path: prefix cache + suffix == one full pass."""
    cfg = _cfg(arch)
    params = _params(cfg)
    B, S, SP = 2, 16, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, pc = prefill(params, cfg, toks[:, :SP])
    ext, _ = extend(params, cfg, pc, toks[:, SP:],
                    jnp.full((B,), SP, jnp.int32))
    np.testing.assert_allclose(np.asarray(full[:, SP:]), np.asarray(ext),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", FAMS)
def test_multi_step_decode(arch):
    cfg = _cfg(arch)
    params = _params(cfg)
    B, S, ND = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + ND), 0,
                              cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, caches = prefill(params, cfg, toks[:, :S])
    dc = cache_from_prefill(cfg, caches, capacity=64)
    for i in range(ND):
        dec, dc = decode_step(params, cfg, dc, toks[:, S + i: S + i + 1],
                              jnp.full((B,), S + i, jnp.int32))
        np.testing.assert_allclose(np.asarray(full[:, S + i]),
                                   np.asarray(dec[:, 0]),
                                   atol=3e-4, rtol=3e-4)


def test_sliding_window_matches_full_when_window_covers():
    base = _cfg("llama3.2-1b")
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0,
                              base.vocab_size)
    params = _params(base)
    full, _ = forward(params, base, toks)
    swa = dataclasses.replace(base, sliding_window=64)  # window > seq
    out, _ = forward(params, swa, toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(out), atol=1e-5)


def test_sliding_window_changes_output_when_smaller():
    base = _cfg("llama3.2-1b")
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 0,
                              base.vocab_size)
    params = _params(base)
    full, _ = forward(params, base, toks)
    swa = dataclasses.replace(base, sliding_window=4)
    out, _ = forward(params, swa, toks)
    assert float(jnp.max(jnp.abs(full[:, -1] - out[:, -1]))) > 1e-3


def test_swa_ring_decode_matches_swa_forward():
    """Ring cache of capacity=window reproduces sliding-window attention."""
    cfg = dataclasses.replace(_cfg("llama3.2-1b"), sliding_window=8)
    params = _params(cfg)
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    _, caches = prefill(params, cfg, toks[:, :S - 1])
    dc = cache_from_prefill(cfg, caches, capacity=1024)  # clamps to window=8
    assert dc["pos0"]["k"].shape[2] == 8
    dec, _ = decode_step(params, cfg, dc, toks[:, S - 1:],
                         jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "jamba-v0.1-52b"])
def test_left_pad_invariance(arch):
    """Left-padded batch rows produce the same last-token logits as the
    unpadded sequence (attention masks + SSM identity steps)."""
    cfg = _cfg(arch)
    params = _params(cfg)
    S, PAD = 12, 5
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, S), 1, cfg.vocab_size)
    ref, _ = forward(params, cfg, toks)
    padded = jnp.concatenate(
        [jnp.zeros((1, PAD), jnp.int32), toks], axis=1)
    valid = jnp.concatenate(
        [jnp.zeros((1, PAD), bool), jnp.ones((1, S), bool)], axis=1)
    out, _ = forward(params, cfg, padded, valid=valid)
    np.testing.assert_allclose(np.asarray(ref[0, -1]), np.asarray(out[0, -1]),
                               atol=2e-4, rtol=2e-4)
