"""Differential tests: vectorized feature plane == loop-based oracle.

The array-backed ``BatchFeatureStore``/``RealtimeFeatureService`` must be
bit-for-bit identical to the retired per-user-loop implementations
(``core/_reference.py``) on randomized event streams — including duplicate
deliveries, identical timestamps, out-of-order ingest, and empty users.
"""
import numpy as np
import pytest

from repro.core._reference import (ReferenceBatchFeatureStore,
                                   ReferenceRealtimeFeatureService)
from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService

DAY = 86400


def _assert_features_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
        assert x.dtype == y.dtype


def _random_stream(rng, n_users, n_events, max_ts):
    users = rng.randint(0, n_users, n_events)
    items = rng.randint(0, 40, n_events)
    tss = rng.randint(0, max_ts, n_events)
    # inject duplicate deliveries (at-least-once) and ts ties
    for _ in range(n_events // 4):
        i = rng.randint(n_events)
        j = rng.randint(n_events)
        users[i], items[i], tss[i] = users[j], items[j], tss[j]
    return users, items, tss


@pytest.mark.parametrize("seed", range(8))
def test_batch_store_matches_reference(seed):
    rng = np.random.RandomState(seed)
    n_users = rng.randint(1, 20)
    k = rng.randint(1, 12)
    window = int(rng.choice([1000, 3 * DAY, 30 * DAY]))
    cfg = FeatureStoreConfig(n_users=n_users, feature_len=k, window=window)
    vec, ref = BatchFeatureStore(cfg), ReferenceBatchFeatureStore(cfg)
    users, items, tss = _random_stream(rng, n_users, rng.randint(0, 400),
                                       5 * DAY)
    vec.extend(users, items, tss)
    for u, it, t in zip(users, items, tss):
        ref.append(int(u), int(it), int(t))

    # users with no events and repeated query users are both exercised
    q = rng.randint(0, n_users, rng.randint(0, 30))
    for cutoff in [0, 17, DAY, int(rng.randint(0, 6 * DAY))]:
        _assert_features_equal(vec.lookup_at_cutoff(q, cutoff),
                               ref.lookup_at_cutoff(q, cutoff))
    for snap_ts in [DAY, 2 * DAY + 13]:
        vec.run_snapshot(snap_ts)
        ref.run_snapshot(snap_ts)
    for now in [0, DAY, DAY + 1, 3 * DAY]:
        _assert_features_equal(vec.lookup(q, now), ref.lookup(q, now))
    for u in range(n_users):
        assert vec.user_events(u) == ref.user_events(u)


@pytest.mark.parametrize("seed", range(8))
def test_realtime_matches_reference(seed):
    rng = np.random.RandomState(100 + seed)
    n_users = rng.randint(1, 16)
    cfg = RealtimeConfig(
        n_users=n_users, buffer_len=rng.randint(1, 10),
        ingest_latency=int(rng.choice([0, 30, 300])),
        retention=int(rng.choice([500, 3600, DAY])))
    vec, ref = RealtimeFeatureService(cfg), ReferenceRealtimeFeatureService(cfg)
    users, items, tss = _random_stream(rng, n_users, rng.randint(0, 600),
                                       2 * DAY)
    # interleave single ingest and redelivery; arrival order matters for
    # the bounded buffer, so feed both services identically
    for u, it, t in zip(users, items, tss):
        vec.ingest(int(u), int(it), int(t))
        ref.ingest(int(u), int(it), int(t))
        if rng.rand() < 0.1:  # redelivery
            vec.ingest(int(u), int(it), int(t))
            ref.ingest(int(u), int(it), int(t))
    assert vec.events_ingested == ref.events_ingested
    q = rng.randint(0, n_users, rng.randint(0, 40))
    for now in [0, 1000, int(rng.randint(0, 3 * DAY)), 3 * DAY]:
        _assert_features_equal(vec.lookup(q, now), ref.lookup(q, now))


def test_realtime_memory_bounded():
    """Ring storage never grows past n_users * buffer_len regardless of
    ingest volume, and stays exact under sustained overwrite."""
    cfg = RealtimeConfig(n_users=3, buffer_len=4, ingest_latency=0,
                         retention=10**6)
    vec, ref = RealtimeFeatureService(cfg), ReferenceRealtimeFeatureService(cfg)
    rng = np.random.RandomState(7)
    for i in range(300):
        u, it, t = rng.randint(3), rng.randint(20), rng.randint(0, 5000)
        vec.ingest(u, it, t)
        ref.ingest(u, it, t)
        if i % 37 == 0:
            _assert_features_equal(vec.lookup(np.arange(3), 5000),
                                   ref.lookup(np.arange(3), 5000))
    assert vec._items.shape == (3, 4) and vec._ts.shape == (3, 4)
    _assert_features_equal(vec.lookup(np.arange(3), 2500),
                           ref.lookup(np.arange(3), 2500))


def test_realtime_extend_matches_sequential_ingest():
    """Columnar bulk ingest == one-by-one ingest, including batches that
    overflow a user's ring several times over."""
    rng = np.random.RandomState(11)
    cfg = RealtimeConfig(n_users=4, buffer_len=3, ingest_latency=0,
                         retention=10**6)
    a, b = RealtimeFeatureService(cfg), RealtimeFeatureService(cfg)
    for _ in range(5):  # several batches: cursors carry across batches
        u = rng.randint(0, 4, 25)
        it = rng.randint(0, 30, 25)
        t = rng.randint(0, 1000, 25)
        a.extend(u, it, t)
        for x, y, z in zip(u, it, t):
            b.ingest(int(x), int(y), int(z))
        q = np.arange(4)
        _assert_features_equal(a.lookup(q, 1000), b.lookup(q, 1000))
    assert a.events_ingested == b.events_ingested


def test_batch_store_interleaved_appends_match_reference():
    """The serve loop's observe/lookup interleaving (reads racing an
    unsorted pending suffix) stays bit-for-bit with the oracle."""
    rng = np.random.RandomState(13)
    cfg = FeatureStoreConfig(n_users=8, feature_len=6, window=3 * DAY)
    vec, ref = BatchFeatureStore(cfg), ReferenceBatchFeatureStore(cfg)
    q = rng.randint(0, 8, 12)
    for i in range(200):
        u, it, t = rng.randint(8), rng.randint(40), rng.randint(0, 4 * DAY)
        vec.append(u, it, t)
        ref.append(u, it, t)
        if i % 9 == 0:
            cutoff = int(rng.randint(0, 5 * DAY))
            _assert_features_equal(vec.lookup_at_cutoff(q, cutoff),
                                   ref.lookup_at_cutoff(q, cutoff))


def test_snapshot_retention_evicts_but_stays_consistent():
    cfg = FeatureStoreConfig(n_users=3, feature_len=4, snapshot_retention=2)
    full = FeatureStoreConfig(n_users=3, feature_len=4)
    vec, ref = BatchFeatureStore(cfg), ReferenceBatchFeatureStore(full)
    rng = np.random.RandomState(5)
    for _ in range(30):
        u, it, t = rng.randint(3), rng.randint(20), rng.randint(0, 5 * DAY)
        vec.append(u, it, t)
        ref.append(u, it, t)
    for d in range(1, 6):
        vec.run_snapshot(d * DAY)
        ref.run_snapshot(d * DAY)
    assert len(vec._snapshots) == 2           # arrays bounded
    assert len(vec._snapshot_times) == 5      # schedule intact
    q = np.array([0, 1, 2])
    # reads of evicted generations recompute from the log, exactly
    for now in [DAY, 2 * DAY + 5, 5 * DAY]:
        _assert_features_equal(vec.lookup(q, now), ref.lookup(q, now))


def test_empty_stores_agree():
    cfg = FeatureStoreConfig(n_users=5, feature_len=6)
    vec, ref = BatchFeatureStore(cfg), ReferenceBatchFeatureStore(cfg)
    q = np.array([0, 4, 4])
    _assert_features_equal(vec.lookup(q, DAY), ref.lookup(q, DAY))
    _assert_features_equal(vec.lookup_at_cutoff(q, DAY),
                           ref.lookup_at_cutoff(q, DAY))
    vec.run_snapshot(DAY)
    ref.run_snapshot(DAY)
    _assert_features_equal(vec.lookup(q, DAY + 1), ref.lookup(q, DAY + 1))


def test_append_events_compat():
    class Ev:
        def __init__(self, u, i, t):
            self.user, self.item, self.ts = u, i, t

    evs = [Ev(0, 3, 100), Ev(1, 4, 50), Ev(0, 5, 75)]
    cfg = FeatureStoreConfig(n_users=2, feature_len=4)
    vec, ref = BatchFeatureStore(cfg), ReferenceBatchFeatureStore(cfg)
    vec.append_events(evs)
    ref.append_events(evs)
    _assert_features_equal(vec.lookup_at_cutoff(np.array([0, 1]), 200),
                           ref.lookup_at_cutoff(np.array([0, 1]), 200))
