"""The ``decay`` injection-policy arm: exponential time-decay item
scores (per-window half-life recency weighting, Interest Clock style)
served model-free through the full Gateway path, in mixed-policy panes
next to engine-served rows.
"""
import numpy as np

from conftest import DAY, make_gateway, tiny_engine
from repro.core.ab import ARM_POLICIES, DECAY_ARM_POLICIES, request_arm
from repro.core.injection import decay_scores
from repro.serving.api import POLICIES, Request

T1 = 5 * DAY + 100


def _serve(gw, users, now, policy=None):
    tk = [gw.submit(Request(user=int(u), now=now, policy=policy))
          for u in users]
    gw.flush(now)
    return tk


def test_decay_scores_formula():
    items = np.array([[3, 7, 3], [0, 0, 5]], np.int32)
    ts = np.array([[10, 20, 30], [0, 0, 40]], np.int32)
    valid = np.array([[1, 1, 1], [0, 0, 1]], np.int32)
    hl, now = 10, 40
    sc = decay_scores((items, ts, valid), now, hl, n_items=8)
    assert sc.shape == (2, 8) and sc.dtype == np.float64
    # user 0: item 3 at ages 30 and 10, item 7 at age 20
    np.testing.assert_allclose(sc[0, 3], 0.5 ** 3 + 0.5 ** 1)
    np.testing.assert_allclose(sc[0, 7], 0.5 ** 2)
    # invalid slots contribute nothing (item 0 stays 0 for user 1)
    np.testing.assert_allclose(sc[1], np.eye(8)[5] * 1.0)
    assert sc[0, [0, 1, 2, 4, 5, 6]].sum() == 0


def test_decay_slate_is_argsort_of_cutoff_features():
    gw = make_gateway(engine=tiny_engine())
    gw.tick(T1)
    users = [0, 5, 11]
    tk = _serve(gw, users, T1, policy="decay")
    feats = gw.injector.batch.lookup_at_cutoff(np.asarray(users), T1)
    # scored over the engine's full vocab: score vectors keep the same
    # shape on every serve path (items past N_ITEMS just never occur)
    want = decay_scores(feats, T1, gw.injector.cfg.half_life,
                        gw.engine.cfg.vocab_size)
    for j, t in enumerate(tk):
        tel = t.response.telemetry
        assert tel.path == "decay" and tel.policy == "decay"
        assert not tel.cache_hit
        order = np.argsort(-want[j], kind="stable")
        np.testing.assert_array_equal(t.response.slate,
                                      order[:3].astype(np.int32))
        np.testing.assert_array_equal(t.response.scores,
                                      want[j].astype(np.float32))


def test_decay_rows_pay_no_engine_and_leave_no_cache_entry():
    gw = make_gateway(engine=tiny_engine())
    gw.tick(T1)
    pc0, len0 = gw.prefill_calls, len(gw.cache)
    _serve(gw, [1, 2], T1, policy="decay")
    assert gw.prefill_calls == pc0 and len(gw.cache) == len0
    assert gw.stats().paths["decay"] == 2
    # deterministic: a fresh gateway over the same stream serves
    # bitwise-identical decay slates
    gw2 = make_gateway(engine=tiny_engine())
    gw2.tick(T1)
    a = _serve(gw, [3], T1, policy="decay")[0].response
    b = _serve(gw2, [3], T1, policy="decay")[0].response
    np.testing.assert_array_equal(a.slate, b.slate)
    np.testing.assert_array_equal(a.scores, b.scores)


def test_mixed_policy_pane_carveout_is_inert():
    """One pane mixing decay and engine rows: the engine rows must be
    bitwise what an unmixed gateway serves (the decay carve-out cannot
    perturb pane assembly), and vice versa."""
    eng = tiny_engine()
    gw = make_gateway(engine=eng, max_wait=8)
    gw.tick(T1)
    reqs = [(4, "inject"), (5, "decay"), (6, "batch"), (7, "decay")]
    tk = [gw.submit(Request(user=u, now=T1, policy=p)) for u, p in reqs]
    gw.flush(T1)
    assert [t.response.telemetry.path for t in tk] == \
        ["prefill", "decay", "prefill", "decay"]
    ref = make_gateway(engine=eng)
    ref.tick(T1)
    rk = [_serve(ref, [u], T1, policy=p)[0] for u, p in reqs]
    for got, want in zip(tk, rk):
        np.testing.assert_array_equal(got.response.slate,
                                      want.response.slate)
        np.testing.assert_array_equal(got.response.scores,
                                      want.response.scores)


def test_decay_policy_registered_and_armed():
    assert "decay" in POLICIES
    # the historical two-arm hash mapping is untouched (experiment
    # continuity); the three-arm experiment is a separate mapping
    assert set(ARM_POLICIES) == {"control", "treatment"}
    assert DECAY_ARM_POLICIES["decay"] == "decay"
    arms = [request_arm(u, arms=DECAY_ARM_POLICIES) for u in range(500)]
    assert set(arms) == {"control", "treatment", "decay"}
    # deterministic per (user, salt) and unchanged for two-arm callers
    assert arms == [request_arm(u, arms=DECAY_ARM_POLICIES)
                    for u in range(500)]
    assert [request_arm(u) for u in range(50)] == \
        [request_arm(u, arms=ARM_POLICIES) for u in range(50)]


def test_decay_gateway_policy_default_and_warm_noop():
    """A gateway whose DEFAULT policy is decay: warm() must be a no-op
    (nothing cacheable to pre-build) and every request takes the decay
    path without an explicit per-request override."""
    gw = make_gateway(policy="decay", engine=tiny_engine())
    gw.warm(np.arange(8), T1)
    assert len(gw.cache) == 0 and gw.prefill_calls == 0
    tk = _serve(gw, [0, 1], T1)
    assert all(t.response.telemetry.path == "decay" for t in tk)
    assert gw.stats().ingest["appended"] >= 0  # counters surfaced
