"""Benchmark harness — one section per paper result/figure + kernel/serving
microbenches and the roofline aggregation.

  PYTHONPATH=src python -m benchmarks.run [--only SECTION]
  PYTHONPATH=src python benchmarks/run.py --suite feature_plane [--smoke]

Sections
  ab_lift            paper §IV: A/B lift table (reads experiments/ab_report.json)
  latency_ablation   engagement vs feature staleness (same report)
  injection_overhead paper §III-B: history_merge op throughput
  serving_phases     prefill vs inject vs decode cost (O(suffix) claim)
  kernel_micro       Pallas-kernel oracle timings (XLA path on CPU)
  roofline           aggregate dry-run JSONs into the §Roofline table
  feature_plane      vectorized EventLog stores vs the loop reference
                     (snapshot materialization + batched lookups at
                     1k/100k/1M users; writes BENCH_feature_plane.json)
  serving            end-to-end InjectionServer: cached-inject vs
                     full-prefill-per-request under interleaved ingest at
                     1k/10k users (writes BENCH_serving.json)
  serving_sharded    the same loop data-parallel over 1/2/8-device
                     ("data","model") meshes — rps scaling + sharded-vs-
                     single-device equivalence (writes
                     BENCH_serving_sharded.json)
  scheduler          request-level Gateway (per-request submits through
                     the micro-batching scheduler) vs the legacy wave
                     path on the same traffic: throughput parity at 100%
                     hit rate + the per-request queue+serve latency
                     percentiles only the request API can measure, plus
                     the continuous scheduler over the paged device
                     state pool (max_wait=0: zero sim-time queue delay,
                     slates bitwise equal to the wave path, compiled
                     gather/scatter collective count recorded from
                     tools/slot_pool_check.py)
                     (writes BENCH_scheduler.json)
  rollover           the daily-boundary cost: eager purge + synchronous
                     snapshot build (legacy) vs warm handoff +
                     incremental build — boundary stall, post-rollover
                     first-wave prefill storm, miss-storm depth, p99
                     (writes BENCH_rollover.json)
  scenarios          production traffic regimes (diurnal / flash_crowd /
                     cold_start_storm / churn_heavy / mixed_fleet) from
                     the seeded trace generator, each gated on its SLO
                     contract; flash_crowd proves deadline-aware load
                     shedding bounds p99 (writes BENCH_scenarios.json)
  ingest             tiered sliding-window EventLog under sustained
                     ingest: bounded steady-state memory across window
                     rollovers, bitwise exactness vs an unbounded-log
                     oracle (late-arrival demotion included), and the
                     churn_compact scenario — compaction live on gateway
                     ticks with mixed engine/decay panes — holding its
                     SLO contract (writes BENCH_ingest.json)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if any("serving_sharded" in a for a in sys.argv):  # also --suite=… form
    # the dry-run's forced-host-device trick: the sharded suite simulates
    # its 8-device mesh on one CPU. Must land in XLA_FLAGS before the
    # first jax init (the import right below), so it keys off argv.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _timeit(fn, *args, n=20, warmup=3):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


# ----------------------------------------------------------------------
def bench_ab_lift():
    print("\n== ab_lift (paper §IV: engagement lift table) ==")
    path = os.path.join(ROOT, "experiments", "ab_report.json")
    if not os.path.exists(path):
        print("  [skip] run examples/ab_experiment.py first")
        return
    for tag, fname in (("regime A (intent drift)", "ab_report.json"),
                       ("regime B (trust bias)", "ab_report_regimeB.json")):
        path = os.path.join(ROOT, "experiments", fname)
        if not os.path.exists(path):
            continue
        rep = json.load(open(path))
        ctrl = rep["arms"]["control"]["ctr"]
        print(f"  -- {tag} --")
        print(f"  {'arm':14s} {'ctr':>8s} {'lift%':>8s} {'p':>8s} sig")
        print(f"  {'control':14s} {ctrl:8.4f} {'--':>8s} {'--':>8s}")
        for name, t in rep["tests"].items():
            arm = name.replace("_vs_control", "")
            if arm.startswith("stale_"):
                continue
            print(f"  {arm:14s} {rep['arms'][arm]['ctr']:8.4f} "
                  f"{t['lift']*100:+8.2f} {t['p_t']:8.4f} "
                  f"{'YES' if t['significant'] else 'no'}")


def bench_latency_ablation():
    print("\n== latency_ablation (engagement vs feature staleness) ==")
    path = os.path.join(ROOT, "experiments", "ab_report.json")
    if not os.path.exists(path):
        print("  [skip] run examples/ab_experiment.py --latency first")
        return
    rep = json.load(open(path))
    rows = [(n, a) for n, a in rep["arms"].items() if n.startswith("stale_")]
    if not rows:
        print("  [skip] no latency arms in the report (use --latency)")
        return
    print(f"  {'staleness':>12s} {'ctr':>8s}")
    print(f"  {'24h batch':>12s} {rep['arms']['control']['ctr']:8.4f}")
    for n, a in sorted(rows, key=lambda r: -int(r[0].split('_')[1][:-1])):
        lam = int(n.split("_")[1][:-1])
        print(f"  {lam:>11d}s {a['ctr']:8.4f}")
    print(f"  {'inject(rt)':>12s} {rep['arms']['treatment']['ctr']:8.4f}")


# ----------------------------------------------------------------------
def bench_injection_overhead():
    print("\n== injection_overhead (history_merge at serving shapes) ==")
    from repro.kernels.history_merge.ops import history_merge
    rng = np.random.RandomState(0)
    print(f"  {'batch':>6s} {'L_hist':>7s} {'L_rt':>5s} {'K':>4s} "
          f"{'us/req (xla)':>13s}")
    for b, lb, lr, k in [(64, 64, 16, 64), (256, 64, 16, 64),
                         (256, 256, 32, 256), (1024, 64, 16, 64)]:
        args = (rng.randint(0, 5000, (b, lb)).astype(np.int32),
                rng.randint(0, 10**6, (b, lb)).astype(np.int32),
                np.ones((b, lb), np.int32),
                rng.randint(0, 5000, (b, lr)).astype(np.int32),
                rng.randint(10**6, 2 * 10**6, (b, lr)).astype(np.int32),
                np.ones((b, lr), np.int32))
        jargs = [jnp.asarray(a) for a in args]
        dt = _timeit(lambda *a: history_merge(*a, out_len=k, impl="xla"),
                     *jargs, n=10)
        print(f"  {b:6d} {lb:7d} {lr:5d} {k:4d} {dt / b * 1e6:13.2f}")


def bench_serving_phases():
    print("\n== serving_phases (inject is O(suffix), not O(history)) ==")
    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine
    for arch in ("llama3.2-1b", "mamba2-780m"):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        eng = ServingEngine(cfg, params, ServingConfig(
            max_batch=8, prefill_len=512, inject_len=16, cache_capacity=1024))
        rng = np.random.RandomState(0)
        hists = [list(rng.randint(1, cfg.vocab_size, 500)) for _ in range(8)]
        toks, valid = eng.pad_tokens(hists, 512)
        t_prefill = _timeit(eng.prefill, toks, valid, n=5)
        state = eng.prefill(toks, valid)
        fresh = [list(rng.randint(1, cfg.vocab_size, 8)) for _ in range(8)]
        stoks, svalid = eng.pad_tokens(fresh, 16, align="left")
        t_inject = _timeit(lambda s, sv: eng.inject(state, s, sv),
                           stoks, svalid, n=5)
        dec = eng.finalize(eng.inject(state, stoks, svalid))
        tok = np.array([[1]] * 8, np.int32)
        t_decode = _timeit(lambda t: eng.decode(dec, t)[0], tok, n=5)
        print(f"  {arch:14s} prefill(512)={t_prefill*1e3:7.1f}ms "
              f"inject(16)={t_inject*1e3:6.1f}ms "
              f"decode(1)={t_decode*1e3:6.1f}ms "
              f"ratio inject/prefill={t_inject/t_prefill:.2f}")


def bench_kernel_micro():
    print("\n== kernel_micro (oracle-path timings on CPU; Pallas targets TPU) ==")
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.models.ssm import ssd_chunked
    from repro.kernels.ssd_scan.ref import ssd_ref_sequential
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 8, 1024, 64))
    k = jax.random.normal(k2, (2, 2, 1024, 64))
    v = jax.random.normal(k3, (2, 2, 1024, 64))
    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    print(f"  attention_ref  1k seq: {_timeit(ref, q, k, v, n=5)*1e3:8.1f} ms")

    x = jax.random.normal(k1, (2, 1024, 8, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(k2, (2, 1024, 8)) - 2)
    A = -jnp.exp(jax.random.normal(k3, (8,)) * 0.3)
    B = jax.random.normal(k1, (2, 1024, 128)) * 0.3
    C = jax.random.normal(k2, (2, 1024, 128)) * 0.3
    D = jnp.ones((8,))
    chunked = jax.jit(lambda *a: ssd_chunked(*a, chunk=256))
    seq = jax.jit(ssd_ref_sequential)
    t_c = _timeit(chunked, x, dt, A, B, C, D, n=5)
    t_s = _timeit(seq, x, dt, A, B, C, D, n=5)
    print(f"  ssd chunked vs sequential 1k: {t_c*1e3:7.1f} ms vs "
          f"{t_s*1e3:7.1f} ms (speedup {t_s/t_c:.1f}x — the SSD trick)")


# ----------------------------------------------------------------------
DAY = 86400


def _time_once(fn, *args, repeat=3):
    """Best-of-N wall time for host-side (numpy) work."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_feature_plane(smoke: bool = False, out_path: str = None):
    """Vectorized array-backed feature plane vs the retired loop reference.

    Measures, per population size:
      * full-population snapshot materialization (``run_snapshot``)
      * batched ``lookup_at_cutoff`` (4096 users)
      * realtime ``lookup`` (256-user serve batch)
      * the serving loop's interleaved pattern — alternating 256-event
        ingest with 256-user realtime + cutoff lookups (reads racing an
        unsorted pending suffix), 50 rounds
    The loop reference is only timed up to 100k users (1M would take
    minutes per snapshot — which is the point of this refactor).
    """
    print("\n== feature_plane (vectorized EventLog vs loop reference) ==")
    from repro.core._reference import (ReferenceBatchFeatureStore,
                                       ReferenceRealtimeFeatureService)
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService

    sizes = [(1_000, 16), (10_000, 8)] if smoke \
        else [(1_000, 32), (100_000, 32), (1_000_000, 8)]
    ref_limit = 100_000
    cutoff = 15 * DAY

    def interleaved(batch_store, rt_service, rng, rounds=50):
        """The serve pattern: observe a wave of events, then look up."""
        n_users = batch_store.cfg.n_users
        for r in range(rounds):
            u = rng.randint(0, n_users, 256)
            it = rng.randint(0, 50_000, 256)
            t = np.full(256, cutoff + r * 60)
            for x, y, z in zip(u.tolist(), it.tolist(), t.tolist()):
                batch_store.append(x, y, z)
                rt_service.ingest(x, y, z)
            now = cutoff + r * 60 + 30
            rt_service.lookup(u, now)
            batch_store.lookup_at_cutoff(u, now)

    results = []
    print(f"  {'users':>9s} {'events':>9s} {'snap(vec)':>10s} "
          f"{'snap(ref)':>10s} {'speedup':>8s} {'lookup4k(vec)':>14s} "
          f"{'lookup4k(ref)':>14s} {'rt256(vec)':>11s} "
          f"{'serve50(vec)':>13s} {'serve50(ref)':>13s}")
    for n_users, ev_per_user in sizes:
        rng = np.random.RandomState(0)
        n = n_users * ev_per_user
        users = rng.randint(0, n_users, n).astype(np.int64)
        items = rng.randint(0, 50_000, n).astype(np.int32)
        tss = rng.randint(0, 30 * DAY, n).astype(np.int64)

        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=64))
        store.extend(users, items, tss)
        # first snapshot pays the lazy index rebuild — charge it honestly
        t_snap_vec, _ = _time_once(store.run_snapshot, cutoff, repeat=1)
        t2, _ = _time_once(store.run_snapshot, cutoff + DAY, repeat=1)
        t_snap_vec = min(t_snap_vec, t2)
        q4k = rng.randint(0, n_users, 4096)
        t_lkp_vec, _ = _time_once(store.lookup_at_cutoff, q4k, cutoff)

        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=16, ingest_latency=0,
            retention=30 * DAY))
        rts.extend(users, items, tss)
        q256 = rng.randint(0, n_users, 256)
        t_rt_vec, _ = _time_once(rts.lookup, q256, cutoff)

        t_snap_ref = t_lkp_ref = t_serve_ref = None
        if n_users <= ref_limit:
            ref = ReferenceBatchFeatureStore(FeatureStoreConfig(
                n_users=n_users, feature_len=64))
            for u, it, t in zip(users.tolist(), items.tolist(), tss.tolist()):
                ref.append(u, it, t)
            t_snap_ref, _ = _time_once(ref.run_snapshot, cutoff, repeat=1)
            t_lkp_ref, _ = _time_once(ref.lookup_at_cutoff, q4k, cutoff,
                                      repeat=1)
            rref = ReferenceRealtimeFeatureService(RealtimeConfig(
                n_users=n_users, buffer_len=16, ingest_latency=0,
                retention=30 * DAY))
            for u, it, t in zip(users.tolist(), items.tolist(), tss.tolist()):
                rref.ingest(u, it, t)
            # correctness spot-check rides along with the timing run
            for a, b in zip(store.lookup_at_cutoff(q4k, cutoff),
                            ref.lookup_at_cutoff(q4k, cutoff)):
                np.testing.assert_array_equal(a, b)
            for a, b in zip(rts.lookup(q256, cutoff),
                            rref.lookup(q256, cutoff)):
                np.testing.assert_array_equal(a, b)
            t_serve_ref, _ = _time_once(
                interleaved, ref, rref, np.random.RandomState(1), repeat=1)
        # interleaved timing mutates the stores — run it last
        t_serve_vec, _ = _time_once(
            interleaved, store, rts, np.random.RandomState(1), repeat=1)
        speedup = t_snap_ref / t_snap_vec if t_snap_ref else None
        results.append({
            "n_users": n_users, "n_events": n,
            "snapshot_vec_s": t_snap_vec, "snapshot_ref_s": t_snap_ref,
            "snapshot_speedup": speedup,
            "lookup4096_vec_s": t_lkp_vec, "lookup4096_ref_s": t_lkp_ref,
            "realtime256_vec_s": t_rt_vec,
            "interleaved50_vec_s": t_serve_vec,
            "interleaved50_ref_s": t_serve_ref,
        })
        fmt = lambda v, w: f"{v*1e3:{w}.2f}ms" if v is not None else " " * w + "--"
        print(f"  {n_users:9d} {n:9d} {fmt(t_snap_vec, 8)} "
              f"{fmt(t_snap_ref, 8)} "
              f"{speedup and f'{speedup:7.1f}x' or '     --'} "
              f"{fmt(t_lkp_vec, 12)} {fmt(t_lkp_ref, 12)} "
              f"{fmt(t_rt_vec, 9)} {fmt(t_serve_vec, 11)} "
              f"{fmt(t_serve_ref, 11)}")
    # smoke runs get their own file so they never clobber the committed
    # full-size record
    default_name = ("BENCH_feature_plane_smoke.json" if smoke
                    else "BENCH_feature_plane.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "feature_plane", "smoke": smoke,
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_serving(smoke: bool = False, out_path: str = None):
    """End-to-end InjectionServer: cached-inject vs full-prefill-per-request.

    Interleaved workload at each population size: every round ingests a
    wave of fresh events (offline log + realtime stream) then serves
    request batches of random users; the cached server pays inject(suffix)
    + decode per hit, the baseline re-prefills the full history on every
    request. Reports requests/sec and p50/p99 per-step (one fixed-shape
    pane) latency, then spot-checks the two paths produce the same logits.
    """
    print("\n== serving (cached-inject vs full-prefill, interleaved ingest) ==")
    from repro.configs.base import ModelConfig
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.loop import InjectionServer, ServerConfig

    n_items = 4000
    feature_len = 240   # long batch history — the cost re-prefill pays
    cfg = ModelConfig(
        name="itfi-ranker-bench", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=16, prefill_len=256, inject_len=16, cache_capacity=512))

    sizes = [(1_000, 1)] if smoke else [(1_000, 3), (10_000, 3)]
    ev_per_user = 64 if smoke else 256
    results = []

    def build(n_users, use_cache):
        rng = np.random.RandomState(0)
        n = n_users * ev_per_user
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=feature_len))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        us = rng.randint(0, n_users, n).astype(np.int64)
        its = rng.randint(0, n_items, n).astype(np.int64)
        tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=feature_len), store, rts)
        return InjectionServer(eng, inj, ServerConfig(
            slate_len=4, cache_entries=4096, use_cache=use_cache))

    def req_users(rng, n_users, size):
        """Request traffic with hot-user locality (sessions): 80% of
        requests come from the hottest 10% of users — uniform traffic
        would make every serving cache useless by construction."""
        hot = max(n_users // 10, 1)
        pick_hot = rng.rand(size) < 0.8
        return np.where(pick_hot, rng.randint(0, hot, size),
                        rng.randint(0, n_users, size))

    wave = 64  # requests per serve() call (4 panes — lets the server's
    #            cache-aware batching group hit rows into pure-hit panes)

    def workload(srv, n_users, rounds, waves_per_round, seed=1):
        """Interleaved ingest/serve; returns per-wave serve latencies.

        Before timing, the cache is warmed over (up to budget) users — the
        daily job's post-snapshot precompute pass. The baseline server
        ignores warm(); its every request re-prefills by construction.
        """
        rng = np.random.RandomState(seed)
        now = 5 * DAY + 100

        def ingest_wave():
            u = req_users(rng, n_users, 64)
            it = rng.randint(0, n_items, 64)
            t = np.full(64, now - 30)
            srv.injector.batch.extend(u, it, t)
            srv.injector.realtime.extend(u, it, t)

        # untimed: roll the snapshot, warm the cache (daily-job precompute),
        # and compile every jit on the request path (incl. inject — needs a
        # fresh wave to exist)
        srv.warm(np.arange(n_users), now)  # clamps itself to the budget
        ingest_wave()
        srv.serve(req_users(rng, n_users, wave), now)
        h0, m0 = srv.cache.hits, srv.cache.misses

        lat = []
        for r in range(rounds):
            ingest_wave()
            for _ in range(waves_per_round):
                q = req_users(rng, n_users, wave)
                t0 = time.perf_counter()
                srv.serve(q, now)
                lat.append(time.perf_counter() - t0)
            now += 60
        return np.asarray(lat), srv.cache.hits - h0, srv.cache.misses - m0

    rounds = 4 if smoke else 12
    print(f"  {'users':>7s} {'path':>12s} {'req/s':>8s} {'p50':>8s} "
          f"{'p99':>9s} {'hit%':>6s} {'prefills':>9s}   (p50/p99 per "
          f"{wave}-request wave)")
    for n_users, waves in sizes:
        row = {"n_users": n_users}
        for tag, use_cache in (("cached", True), ("full", False)):
            srv = build(n_users, use_cache)
            lat, hits, misses = workload(srv, n_users, rounds,
                                         waves_per_round=waves)
            n_req = len(lat) * wave
            rps = n_req / lat.sum()
            st = srv.stats()
            hit = hits / max(hits + misses, 1)
            row[tag] = {
                "requests": int(n_req), "rps": float(rps),
                "wave_requests": wave,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "hit_rate": float(hit), "stats": st,
            }
            print(f"  {n_users:7d} {tag:>12s} {rps:8.1f} "
                  f"{row[tag]['p50_ms']:6.1f}ms {row[tag]['p99_ms']:7.1f}ms "
                  f"{hit * 100:5.1f}% {st['prefill_calls']:9d}")
        row["speedup"] = row["cached"]["rps"] / row["full"]["rps"]

        # logits spot-check: identical stacks, same request -> same scores
        sc = build(n_users, True)
        sf = build(n_users, False)
        rng = np.random.RandomState(2)
        now = 5 * DAY + 100
        wave_u = rng.randint(0, n_users, 64)
        wave_i = rng.randint(0, n_items, 64)
        for srv in (sc, sf):
            srv.injector.batch.extend(wave_u, wave_i, np.full(64, now - 30))
            srv.injector.realtime.extend(wave_u, wave_i, np.full(64, now - 30))
        q = rng.randint(0, n_users, eng.scfg.max_batch)
        sc.serve(q, now - 60)  # populate the cache, then hit it
        a = sc.serve(q, now)
        b_ = sf.serve(q, now)
        diff = float(np.abs(a.scores - b_.scores).max())
        row["logits_max_abs_diff"] = diff
        row["logits_allclose"] = bool(diff < 2e-3)
        row["slates_equal"] = bool((a.slate == b_.slate).all())
        print(f"  {n_users:7d} speedup={row['speedup']:.2f}x "
              f"logits max|Δ|={diff:.2e} "
              f"slates_equal={row['slates_equal']}")
        results.append(row)

    default_name = ("BENCH_serving_smoke.json" if smoke
                    else "BENCH_serving.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "serving", "smoke": smoke,
                   "config": {"arch": cfg.name, "max_batch": eng.scfg.max_batch,
                              "prefill_len": eng.scfg.prefill_len,
                              "inject_len": eng.scfg.inject_len,
                              "feature_len": feature_len,
                              "slate_len": 4},
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_scheduler(smoke: bool = False, out_path: str = None):
    """Request-level Gateway vs the legacy wave path on the same traffic.

    Three rows per population size, separating two different costs:

      1. ``wave`` — the legacy pre-grouped ``serve(users, now)`` path.
      2. ``gateway_wave`` — the SAME waves through the request API
         (``submit_many`` + ``flush``). The scheduler sees the whole
         wave at once, so it forms the identical panes (incl. the
         cache-aware hit/miss partitioning): this isolates the
         facade's own cost (typed requests, tickets, per-request
         telemetry), which must stay within ~10% of the wave path —
         the redesign's parity bar.
      3. ``gateway_trickle`` — per-request ``submit`` at one
         sim-second per arrival with a pane-deadline of 2*max_batch
         sim-seconds (pane-full flushes, deadline tail via ``tick``).
         At 100% hit rate this too is pane-for-pane identical work; at
         lower hit rates it honestly pays the *scheduling-granularity*
         cost of latency-bounded micro-batching — an eager pane-full
         flush never holds more than one pane, so it cannot regroup
         hits around misses the way a whole-wave drain can, and more
         panes carry an admission prefill.

    The trickle row is also the one that can measure what a wave API
    cannot: every request's individual queue+serve wall latency
    (submit -> response), recorded as req_p50/p99 next to the pane
    serve latency and the sim-time queue-delay telemetry.

      4. ``gateway_continuous`` — the same per-request trickle through
         the continuous scheduler (``max_wait=0``) over the paged
         device-resident state pool (``pool_slots``): every arrival is
         served immediately in a padded partial pane, so the sim-time
         queue delay collapses to zero (vs the trickle row's
         deadline-bounded p99) at the price of one engine pane per
         request. Its slates are checked bitwise against the wave
         path's (``slates_equal_wave``) — the pool's one-hot
         gather/scatter and the partial-pane padding are exact — and
         the compiled gather/scatter collective count (expected 0) is
         recorded from a ``tools/slot_pool_check.py`` subprocess run.

    Rounds are **interleaved across the three paths** (wave round,
    gateway_wave round, trickle round, repeat): shared CI hosts
    throttle on a seconds-to-minutes timescale, and sequential
    per-path measurement hands whole slow windows to one path —
    interleaving spreads them evenly so the ratios compare serving
    work, not scheduler luck.
    """
    print("\n== scheduler (request-level Gateway vs wave path) ==")
    import warnings as _warnings

    from repro.configs.base import ModelConfig
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.models.model import init_params
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.loop import InjectionServer
    from repro.serving.scheduler import Gateway, ServerConfig

    n_items = 4000
    feature_len = 240
    cfg = ModelConfig(
        name="itfi-ranker-bench", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=16, prefill_len=256, inject_len=16, cache_capacity=512))

    sizes = [1_000] if smoke else [1_000, 10_000]
    ev_per_user = 64 if smoke else 256
    rounds = 3 if smoke else 10
    wave = 64                    # requests per round-wave (4 panes)
    deadline = 2 * eng.scfg.max_batch  # sim-seconds a request may queue

    def build(n_users):
        rng = np.random.RandomState(0)
        n = n_users * ev_per_user
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=feature_len))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        us = rng.randint(0, n_users, n).astype(np.int64)
        its = rng.randint(0, n_items, n).astype(np.int64)
        tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
        return FeatureInjector(InjectionConfig(
            policy="inject", feature_len=feature_len), store, rts)

    def req_users(rng, n_users, size):
        hot = max(n_users // 10, 1)
        pick_hot = rng.rand(size) < 0.8
        return np.where(pick_hot, rng.randint(0, hot, size),
                        rng.randint(0, n_users, size))

    def ingest(inj_or_gw, rng, n_users, now):
        u = req_users(rng, n_users, 64)
        it = rng.randint(0, n_items, 64)
        t = np.full(64, now - 30)
        inj = getattr(inj_or_gw, "injector", inj_or_gw)
        inj.batch.extend(u, it, t)
        inj.realtime.extend(u, it, t)

    results = []
    print(f"  {'users':>7s} {'path':>16s} {'req/s':>8s} {'req p50':>9s} "
          f"{'req p99':>9s} {'pane p50':>9s} {'pane p99':>9s} {'hit%':>6s}")
    for n_users in sizes:
        row = {"n_users": n_users, "wave_requests": wave, "rounds": rounds}
        scfg = ServerConfig(slate_len=4, cache_entries=4096)
        t00 = 5 * DAY + 100

        # four independent stacks fed identical seeded traffic; their
        # timed rounds run interleaved (see docstring)
        pool_slots = 1024
        srv = InjectionServer(eng, build(n_users), scfg)   # wave
        gww = Gateway(eng, build(n_users), scfg)           # gateway_wave
        gwt = Gateway(eng, build(n_users), scfg)           # trickle
        gwc = Gateway(eng, build(n_users), ServerConfig(   # continuous
            slate_len=4, pool_slots=pool_slots, max_wait=0))
        st_w = {"rng": np.random.RandomState(1), "now": t00, "lat": [],
                "slates": []}
        st_gw = {"rng": np.random.RandomState(1), "now": t00, "lat": []}
        st_tr = {"rng": np.random.RandomState(1), "now": t00,
                 "req_lat": [], "pane_lat": [], "pending": [],
                 "t_total": 0.0}
        st_c = {"rng": np.random.RandomState(1), "now": t00,
                "req_lat": [], "slates": [], "t_total": 0.0}

        def wave_round(s, timed=True):
            ingest(srv.gateway, s["rng"], n_users, s["now"])
            q = req_users(s["rng"], n_users, wave)
            t0 = time.perf_counter()
            res = srv.serve(q, s["now"])
            if timed:
                s["lat"].append(time.perf_counter() - t0)
            s["slates"].append(np.asarray(res.slate))
            s["now"] += 60

        def gateway_wave_round(s, timed=True):
            ingest(gww, s["rng"], n_users, s["now"])
            q = req_users(s["rng"], n_users, wave)
            t0 = time.perf_counter()
            gww.submit_many([Request(user=int(u), now=s["now"]) for u in q])
            gww.flush(s["now"])
            if timed:
                s["lat"].append(time.perf_counter() - t0)
            s["now"] += 60

        def trickle_round(s, timed=True):
            ingest(gwt, s["rng"], n_users, s["now"])
            t_seg0 = time.perf_counter()
            for u in req_users(s["rng"], n_users, wave):
                t = gwt.submit(Request(user=int(u), now=s["now"],
                                       deadline=s["now"] + deadline))
                s["pending"].append(t)
                s["now"] += 1  # one arrival per sim-second
                if t.done and timed:  # this submit filled + flushed a pane
                    done_wall = time.perf_counter()
                    # the flush ran inside this submit call, so the
                    # triggering request's submit->done wall time IS the
                    # pane's serve latency
                    s["pane_lat"].append(done_wall - t.submitted_wall)
                    s["req_lat"] += [done_wall - p.submitted_wall
                                     for p in s["pending"] if p.done]
                s["pending"] = [p for p in s["pending"] if not p.done]
            gwt.tick(s["now"] + deadline)  # deadline-flush the tail
            done_wall = time.perf_counter()
            if timed:
                s["t_total"] += done_wall - t_seg0
                s["req_lat"] += [done_wall - p.submitted_wall
                                 for p in s["pending"] if p.done]
            s["pending"] = [p for p in s["pending"] if not p.done]
            # next round's arrivals start past the tail-flush tick's
            # clock (now + deadline) — backdated stamps would inflate
            # the sim-time queue-delay telemetry
            s["now"] += deadline + 4

        def continuous_round(s, timed=True):
            ingest(gwc, s["rng"], n_users, s["now"])
            t_seg0 = time.perf_counter()
            for u in req_users(s["rng"], n_users, wave):
                t = gwc.submit(Request(user=int(u), now=s["now"]))
                assert t.done  # max_wait=0: served on arrival
                if timed:
                    s["req_lat"].append(
                        time.perf_counter() - t.submitted_wall)
                s["slates"].append(np.asarray(t.response.slate))
                s["now"] += 1  # one arrival per sim-second
            gwc.poll()  # claim the completion stream
            if timed:
                s["t_total"] += time.perf_counter() - t_seg0
            # keep the four clocks in lockstep with the trickle stack
            s["now"] += deadline + 4

        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore", DeprecationWarning)
            # untimed: warm every cache, compile every jit
            for g in (srv, gww, gwt, gwc):
                g.warm(np.arange(n_users), t00)
            wave_round(st_w, timed=False)
            gateway_wave_round(st_gw, timed=False)
            trickle_round(st_tr, timed=False)
            continuous_round(st_c, timed=False)
            counters = [(g.cache.hits, g.cache.misses)
                        for g in (srv, gww, gwt, gwc)]
            for _ in range(rounds):  # timed, interleaved
                wave_round(st_w)
                gateway_wave_round(st_gw)
                trickle_round(st_tr)
                continuous_round(st_c)

        def hit_rate(g, h0m0):
            hits, misses = g.cache.hits - h0m0[0], g.cache.misses - h0m0[1]
            return float(hits / max(hits + misses, 1))

        lat = np.asarray(st_w["lat"])
        row["wave"] = {
            "rps": float(rounds * wave / lat.sum()),
            "wave_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "wave_p99_ms": float(np.percentile(lat, 99) * 1e3),
            "hit_rate": hit_rate(srv, counters[0]),
        }
        lat = np.asarray(st_gw["lat"])
        row["gateway_wave"] = {
            "rps": float(rounds * wave / lat.sum()),
            "wave_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "wave_p99_ms": float(np.percentile(lat, 99) * 1e3),
            "hit_rate": hit_rate(gww, counters[1]),
        }
        pane_lat = np.asarray(st_tr["pane_lat"])
        req_lat = np.asarray(st_tr["req_lat"])
        st = gwt.stats()
        row["gateway_trickle"] = {
            "rps": float(rounds * wave / st_tr["t_total"]),
            "req_p50_ms": float(np.percentile(req_lat, 50) * 1e3),
            "req_p99_ms": float(np.percentile(req_lat, 99) * 1e3),
            "pane_p50_ms": float(np.percentile(pane_lat, 50) * 1e3),
            "pane_p99_ms": float(np.percentile(pane_lat, 99) * 1e3),
            "hit_rate": hit_rate(gwt, counters[2]),
            "queue_delay_sim": st["queue_delay"],
            "paths": st["paths"], "deadline_flushes": st["deadline_flushes"],
        }
        req_lat = np.asarray(st_c["req_lat"])
        cst = gwc.stats()
        wave_slates = np.concatenate(st_w["slates"])
        cont_slates = np.stack(st_c["slates"])
        trickle_p99 = row["gateway_trickle"]["queue_delay_sim"]["p99"]
        cont_p99 = cst["queue_delay"]["p99"]
        row["gateway_continuous"] = {
            "rps": float(rounds * wave / st_c["t_total"]),
            "req_p50_ms": float(np.percentile(req_lat, 50) * 1e3),
            "req_p99_ms": float(np.percentile(req_lat, 99) * 1e3),
            "hit_rate": hit_rate(gwc, counters[3]),
            "queue_delay_sim": cst["queue_delay"],
            "paths": cst["paths"], "panes": cst["panes"],
            "pool_slots": pool_slots,
            "slot_bytes": gwc.pool.slot_nbytes,
            "slates_equal_wave": bool(
                np.array_equal(wave_slates, cont_slates)),
            # the latency lever: sim-time p99 queue delay vs the
            # deadline-bounded trickle (>= 2x better is the bar; with
            # max_wait=0 the continuous path's delay is identically 0)
            "p99_queue_delay_vs_trickle": {
                "trickle": float(trickle_p99),
                "continuous": float(cont_p99),
                "improved_2x": bool(2 * cont_p99 <= trickle_p99),
            },
        }
        row["facade_ratio"] = (row["gateway_wave"]["rps"]
                               / row["wave"]["rps"])
        row["trickle_ratio"] = (row["gateway_trickle"]["rps"]
                                / row["wave"]["rps"])
        w, gwv, g = row["wave"], row["gateway_wave"], row["gateway_trickle"]
        print(f"  {n_users:7d} {'wave':>16s} {w['rps']:8.1f} {'--':>9s} "
              f"{'--':>9s} {w['wave_p50_ms']:7.1f}ms {w['wave_p99_ms']:7.1f}ms "
              f"{w['hit_rate'] * 100:5.1f}%")
        print(f"  {n_users:7d} {'gateway_wave':>16s} {gwv['rps']:8.1f} "
              f"{'--':>9s} {'--':>9s} {gwv['wave_p50_ms']:7.1f}ms "
              f"{gwv['wave_p99_ms']:7.1f}ms {gwv['hit_rate'] * 100:5.1f}%")
        print(f"  {n_users:7d} {'gateway_trickle':>16s} {g['rps']:8.1f} "
              f"{g['req_p50_ms']:7.1f}ms {g['req_p99_ms']:7.1f}ms "
              f"{g['pane_p50_ms']:7.1f}ms {g['pane_p99_ms']:7.1f}ms "
              f"{g['hit_rate'] * 100:5.1f}%")
        c = row["gateway_continuous"]
        print(f"  {n_users:7d} {'gateway_cont':>16s} {c['rps']:8.1f} "
              f"{c['req_p50_ms']:7.1f}ms {c['req_p99_ms']:7.1f}ms "
              f"{'--':>9s} {'--':>9s} {c['hit_rate'] * 100:5.1f}%")
        print(f"  {n_users:7d} facade ratio (gateway_wave/wave) = "
              f"{row['facade_ratio']:.2f} (parity bar: >= 0.90); trickle "
              f"ratio = {row['trickle_ratio']:.2f}; per-request latency is "
              f"the column the wave path cannot fill")
        qd = c["p99_queue_delay_vs_trickle"]
        print(f"  {n_users:7d} continuous: queue_delay_sim p99 "
              f"{qd['trickle']:.0f}s -> {qd['continuous']:.0f}s "
              f"(improved_2x={qd['improved_2x']}), slates_equal_wave="
              f"{c['slates_equal_wave']}, {c['panes']} panes over "
              f"{pool_slots} pool slots")
        results.append(row)

    # the zero-collective proof for the pool's compiled gather/scatter:
    # run the HLO scan in a subprocess (it forces an 8-device CPU
    # topology via XLA_FLAGS, which must never leak into this process)
    # and record the count next to the rows it certifies
    import subprocess
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "slot_pool_check.py")],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    pool_ok = (proc.returncode == 0
               and "SLOT-POOL OK collectives=0" in proc.stdout)
    slot_pool_check = {"ok": bool(pool_ok),
                       "collectives": 0 if pool_ok else None}
    print(f"  slot_pool_check: ok={pool_ok} collectives="
          f"{slot_pool_check['collectives']} (8-way data mesh HLO scan)")

    default_name = ("BENCH_scheduler_smoke.json" if smoke
                    else "BENCH_scheduler.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "scheduler", "smoke": smoke,
                   "config": {"arch": cfg.name, "max_batch": eng.scfg.max_batch,
                              "prefill_len": eng.scfg.prefill_len,
                              "inject_len": eng.scfg.inject_len,
                              "feature_len": feature_len, "slate_len": 4,
                              "deadline_s": deadline},
                   "slot_pool_check": slot_pool_check,
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_rollover(smoke: bool = False, out_path: str = None):
    """What a generation rollover costs, before and after this PR.

    Two independent measurements, because the stall and the storm live
    at different scales:

    **build** (store only, population scale) — the daily boundary used
    to re-materialize the full ``(n_users, feature_len)`` plane
    synchronously inside the clock call that crossed it. Times the full
    ``run_snapshot`` oracle vs the incremental ``SnapshotBuilder``
    (changed-user delta + copy-forward) at 1M users with a ~1% changed
    fraction, reporting total build time AND the max single
    budget-bounded ``step()`` — the worst stall any one ``tick`` pays
    under amortization.

    **serving** (end-to-end gateway) — the old rollover purged the
    whole prefill-state cache, so the first post-rollover waves were a
    100% miss storm of full prefills. Drives identical seeded traffic
    (hot-user locality, warmed cache, ~10% of users changed across the
    boundary) through three gateways: ``eager`` (warm_handoff=False +
    synchronous build — the legacy behavior), ``warm`` (handoff +
    budget-sliced incremental build), and ``background`` (handoff +
    off-thread build: boundary ticks are O(1) polls). Records the
    boundary-crossing clock-call wall time, per-wave prefill-path rows,
    hit rate and latency for the post-rollover waves, the miss-storm
    depth (waves until a wave is all-hit again), and the rekeyed
    fraction. Responses are asserted bitwise identical across all
    modes — the handoff and the off-thread build are optimizations
    only.
    """
    print("\n== rollover (eager purge + sync build vs warm handoff + "
          "incremental) ==")
    from repro.configs.base import ModelConfig
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.models.model import init_params
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.scheduler import Gateway, ServerConfig

    results = {}

    # ---- part A: build amortization at population scale ---------------
    n_build = 50_000 if smoke else 1_000_000
    ev_per_user = 4 if smoke else 8
    budget = max(n_build // 500, 1)  # users per step() slice
    g1, g2 = 5 * DAY, 6 * DAY
    rng = np.random.RandomState(0)
    n = n_build * ev_per_user
    stores = [BatchFeatureStore(FeatureStoreConfig(
        n_users=n_build, feature_len=64)) for _ in range(3)]
    us = rng.randint(0, n_build, n).astype(np.int64)
    its = rng.randint(0, 50_000, n).astype(np.int32)
    tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
    for s in stores:
        s.extend(us, its, tss)
        s.run_snapshot(g1)
    # ~1% of users get events inside the rolled period
    cu = rng.choice(n_build, n_build // 100, replace=False)
    cit = rng.randint(0, 50_000, len(cu))
    for s in stores:
        s.extend(cu, cit, np.full(len(cu), g1 + 500))
        # pre-build the log's lazy sorted index so both paths time BUILD
        # work, not shared index maintenance: the first population-scale
        # read after an append pays an amortized full re-sort either way
        # (EventLog._ensure_base), and during a live serving day that
        # cost is paid continuously by ordinary reads, not by the
        # snapshot job that happens to run next
        s._log._rebuild()
    full, inc, bgs = stores
    t_full, _ = _time_once(full.run_snapshot, g2, repeat=1)
    t0 = time.perf_counter()
    builder = inc.begin_snapshot(g2)  # delta scan + copy-forward alloc
    t_create = time.perf_counter() - t0
    step_times = []
    while not builder.done:
        t0 = time.perf_counter()
        builder.step(budget)
        step_times.append(time.perf_counter() - t0)
    for a, b in zip(full._snapshots[g2], inc._snapshots[g2]):
        np.testing.assert_array_equal(a, b)  # the oracle differential
    # the worst single clock call a gateway pays: builder creation rides
    # the first slice (Gateway._step_snapshot_build creates + steps)
    worst_slice = max([t_create + step_times[0]] + step_times[1:])
    t_inc_total = t_create + sum(step_times)
    results["build"] = {
        "n_users": n_build, "n_events": int(inc._log.n_events),
        "changed_users": int(builder.n_changed),
        "changed_frac": builder.n_changed / n_build,
        "step_budget_users": budget,
        "full_build_s": t_full,
        "incremental_create_s": float(t_create),
        "incremental_total_s": float(t_inc_total),
        "incremental_steps": len(step_times),
        "incremental_max_clock_slice_s": float(worst_slice),
        "bitwise_equal_oracle": True,
        "speedup_total": t_full / max(t_inc_total, 1e-9),
        "stall_reduction": t_full / max(worst_slice, 1e-9),
    }
    b = results["build"]
    print(f"  build @ {n_build} users: full={t_full*1e3:.0f}ms "
          f"incremental total={b['incremental_total_s']*1e3:.0f}ms "
          f"({b['changed_users']} changed, {b['incremental_steps']} steps "
          f"of {budget}) worst clock slice="
          f"{b['incremental_max_clock_slice_s']*1e3:.1f}ms -> "
          f"stall {b['stall_reduction']:.0f}x smaller, "
          f"total {b['speedup_total']:.1f}x faster")

    # background builder: the whole copy-forward + fill + diff runs on a
    # worker thread; the serving thread pays only builder creation, O(1)
    # polls, and the finalize (late fixup + install). Every slice below
    # is serving-thread wall time — the stall a clock call would pay.
    t_wall0 = time.perf_counter()
    bg_builder = bgs.begin_snapshot_background(g2)
    bg_create = time.perf_counter() - t_wall0
    bg_slices = [bg_create]  # creation rides the boundary tick
    polls = 0
    while True:
        t0 = time.perf_counter()
        rem = bg_builder.poll()
        bg_slices.append(time.perf_counter() - t0)
        polls += 1
        if rem == 0:
            break
        time.sleep(1e-3)
    bg_wall = time.perf_counter() - t_wall0
    for a, c in zip(full._snapshots[g2], bgs._snapshots[g2]):
        np.testing.assert_array_equal(a, c)  # off-thread differential
    results["build"]["background"] = {
        "create_s": float(bg_create),
        "wall_total_s": float(bg_wall),
        "serving_thread_busy_s": float(sum(bg_slices)),
        "polls": polls,
        "max_clock_slice_s": float(max(bg_slices)),
        "worker_steps": int(bg_builder.steps),
        "bitwise_equal_oracle": True,
        "stall_reduction": t_full / max(max(bg_slices), 1e-9),
    }
    bb = results["build"]["background"]
    print(f"  background @ {n_build} users: wall="
          f"{bb['wall_total_s']*1e3:.0f}ms across {polls} polls, "
          f"serving thread busy {bb['serving_thread_busy_s']*1e3:.1f}ms, "
          f"worst clock slice={bb['max_clock_slice_s']*1e3:.2f}ms -> "
          f"stall {bb['stall_reduction']:.0f}x smaller than full, "
          f"bitwise equal to oracle")

    # ---- part B: the post-rollover miss storm --------------------------
    n_items = 4000
    feature_len = 240
    n_users = 400 if smoke else 2_000
    sv_ev_per_user = 32 if smoke else 64
    post_waves = 6 if smoke else 12
    pre_waves = 2 if smoke else 4
    wave = 64
    changed_frac = 0.10

    cfg = ModelConfig(
        name="itfi-ranker-bench", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=16, prefill_len=256, inject_len=16, cache_capacity=512))

    def build_gw(mode):
        rng = np.random.RandomState(0)
        n = n_users * sv_ev_per_user
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=feature_len))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        us = rng.randint(0, n_users, n).astype(np.int64)
        its = rng.randint(0, n_items, n).astype(np.int64)
        tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=feature_len), store, rts)
        if mode == "eager":
            scfg = ServerConfig(slate_len=4, cache_entries=4096,
                                warm_handoff=False)
        elif mode == "warm":
            scfg = ServerConfig(slate_len=4, cache_entries=4096,
                                warm_handoff=True,
                                snapshot_build_budget=max(n_users // 4, 1))
        else:  # background: off-thread build, O(1) boundary ticks
            scfg = ServerConfig(slate_len=4, cache_entries=4096,
                                warm_handoff=True, background_build=True)
        return Gateway(eng, inj, scfg)

    def req_users(rng, size):
        hot = max(n_users // 10, 1)
        pick_hot = rng.rand(size) < 0.8
        return np.where(pick_hot, rng.randint(0, hot, size),
                        rng.randint(0, n_users, size))

    def serve_wave(gw, rng, now):
        q = req_users(rng, wave)
        t0 = time.perf_counter()
        tk = gw.submit_many([Request(user=int(u), now=int(now))
                             for u in q])
        gw.flush(now)
        dt = time.perf_counter() - t0
        prefills = sum(t.response.telemetry.path == "prefill" for t in tk)
        hits = sum(t.response.telemetry.cache_hit for t in tk)
        return dt, prefills, hits, tk

    t00 = 5 * DAY + 100
    rngc = np.random.RandomState(5)
    changed = rngc.choice(n_users, int(n_users * changed_frac),
                          replace=False)
    changed_items = rngc.randint(0, n_items, len(changed))

    mode_rows = {}
    fingerprints = {}
    for mode in ("eager", "warm", "background"):
        gw = build_gw(mode)
        rng = np.random.RandomState(1)
        now = t00
        gw.warm(np.arange(n_users), now)     # daily-job precompute
        serve_wave(gw, np.random.RandomState(99), now)  # compile, untimed
        pre = [serve_wave(gw, rng, now + 60 * i)[:3]
               for i in range(pre_waves)]
        # the rolled period's events: ~10% of users change
        gw.observe_many(changed, changed_items,
                        np.full(len(changed), now + 3600))
        # cross the boundary on the clock; the eager gateway pays the
        # full synchronous build + purge inside ONE call, the warm
        # gateway amortizes budget-bounded slices across ticks
        t_boundary = now + DAY
        tick_times = []
        while gw.injector.generation(t_boundary) != 6 * DAY:
            t0 = time.perf_counter()
            gw.tick(t_boundary)
            tick_times.append(time.perf_counter() - t0)
            if mode == "background":
                # ticks are O(1) polls; the worker needs wall time
                time.sleep(1e-3)
            assert len(tick_times) < (2000 if mode == "background" else 100)
        post = []
        tks = []
        for i in range(post_waves):
            dt, prefills, hits, tk = serve_wave(
                gw, rng, t_boundary + 60 * (i + 1))
            post.append((dt, prefills, hits))
            tks.append(tk)
        fingerprints[mode] = (
            np.concatenate([np.stack([t.response.slate for t in tk])
                            for tk in tks]),
            np.concatenate([np.stack([t.response.scores for t in tk])
                            for tk in tks]))
        storm = next((i for i, (_, p, h) in enumerate(post)
                      if p == 0 and h == wave), len(post))
        st = gw.stats()["rollover"]
        pre_lat = np.array([d for d, _, _ in pre])
        post_lat = np.array([d for d, _, _ in post])
        mode_rows[mode] = {
            "boundary_clock_calls": len(tick_times),
            "boundary_call_max_ms": float(max(tick_times) * 1e3),
            "boundary_total_ms": float(sum(tick_times) * 1e3),
            "pre_wave_p99_ms": float(np.percentile(pre_lat, 99) * 1e3),
            "post_wave_p99_ms": float(np.percentile(post_lat, 99) * 1e3),
            "first_wave_prefills": int(post[0][1]),
            "first_wave_hit_rate": float(post[0][2] / wave),
            "miss_storm_waves": int(storm),
            "post_prefills_per_wave": [int(p) for _, p, _ in post],
            "rekeyed": int(st["rekeyed"]),
            "invalidated": int(st["invalidated"]),
            "retained": int(st["retained"]),
            "rekeyed_frac": float(
                st["rekeyed"] / max(st["rekeyed"] + st["invalidated"]
                                    + st["retained"], 1)),
        }
        r = mode_rows[mode]
        print(f"  {mode:>6s}: boundary max-call="
              f"{r['boundary_call_max_ms']:.1f}ms "
              f"first-wave prefills={r['first_wave_prefills']}/{wave} "
              f"hit={r['first_wave_hit_rate']*100:.0f}% "
              f"storm={r['miss_storm_waves']} waves "
              f"post p99={r['post_wave_p99_ms']:.1f}ms "
              f"rekeyed={r['rekeyed']}")

    # the handoff (and the off-thread build) is an optimization only:
    # identical responses in every mode
    for m in ("warm", "background"):
        np.testing.assert_array_equal(fingerprints["eager"][0],
                                      fingerprints[m][0])
        np.testing.assert_array_equal(fingerprints["eager"][1],
                                      fingerprints[m][1])
    e, w = mode_rows["eager"], mode_rows["warm"]
    results["serving"] = {
        "n_users": n_users, "wave_requests": wave,
        "changed_frac": changed_frac,
        "modes": mode_rows,
        "responses_bitwise_equal": True,
        "first_wave_prefill_reduction": (
            e["first_wave_prefills"] / max(w["first_wave_prefills"], 1)),
        "miss_storm_reduction_waves": (e["miss_storm_waves"]
                                       - w["miss_storm_waves"]),
    }
    print(f"  post-rollover first-wave prefills {e['first_wave_prefills']} "
          f"-> {w['first_wave_prefills']} "
          f"({results['serving']['first_wave_prefill_reduction']:.1f}x "
          f"fewer); responses bitwise equal across modes")

    default_name = ("BENCH_rollover_smoke.json" if smoke
                    else "BENCH_rollover.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "rollover", "smoke": smoke,
                   "config": {"arch": cfg.name, "max_batch": 16,
                              "prefill_len": 256, "inject_len": 16,
                              "feature_len": feature_len,
                              "slate_len": 4},
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_online(smoke: bool = False, out_path: str = None):
    """Online trainer + hot-swapped delta weight patches, end to end.

    Three measurements:

    **cadence** — patch install frequency vs serving cost. Replays the
    same seeded request/event waves through gateways that install a
    delta patch (trainable = embedding slice) never / every 8 waves /
    every 2 waves, under both install policies (``purge`` drops
    version-stale cache entries, ``rewarm`` re-prefills them between
    panes on a budget). Reports throughput, hit rate, patches applied,
    and the **install stall** — the worst single ``install_patch()``
    slice the serving thread paid (the hot-swap is O(patch): this
    number must stay in single-digit milliseconds, and the schema check
    gates the committed artifact at 5 ms).

    **swap** — the bitwise contract: after an install, the gateway's
    responses must equal a COLD gateway built directly from the
    trainer's weights, slate for slate, bit for bit.

    **drift** — why online weights matter at all: on a stream whose
    item distribution shifts mid-run, the online trainer's loss
    recovers after the drift while a frozen model's loss stays
    elevated (the frozen run is the same trainer machinery at lr=0, so
    both consume byte-identical batches).
    """
    print("\n== online (incremental trainer + hot-swapped patches) ==")
    from repro.configs.base import ModelConfig
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.models.model import init_params
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.scheduler import Gateway, ServerConfig
    from repro.training import OnlineTrainer, OnlineTrainerConfig
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import TrainConfig

    n_items = 1000
    feature_len = 48
    n_users = 256 if smoke else 512
    ev_per_user = 16 if smoke else 24
    n_waves = 8 if smoke else 16
    wave = 32
    cfg = ModelConfig(
        name="itfi-ranker-online", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True)
    scfg = ServingConfig(max_batch=16, prefill_len=64, inject_len=8,
                         cache_capacity=512)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=100_000),
                       remat=False, param_dtype=jnp.float32)
    ocfg = OnlineTrainerConfig(batch_size=8, seq_len=32,
                               trainable=("embed",))

    def build(policy="purge"):
        """Fresh engine (weights get patched) + seeded platform +
        trainer over the gateway's own event log."""
        rng = np.random.RandomState(0)
        n = n_users * ev_per_user
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=feature_len))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        us = rng.randint(0, n_users, n).astype(np.int64)
        its = rng.randint(0, n_items, n).astype(np.int64)
        tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=feature_len), store, rts)
        eng = ServingEngine(cfg, init_params(
            cfg, jax.random.PRNGKey(0), dtype=jnp.float32), scfg)
        gw = Gateway(eng, inj, ServerConfig(
            slate_len=4, cache_entries=1024, patch_policy=policy,
            rewarm_budget=64))
        tr = OnlineTrainer(cfg, eng.params, store.log, cfg=ocfg,
                           train_cfg=tcfg)
        return gw, tr

    t00 = 5 * DAY + 100

    def serve_wave(gw, rng, now):
        q = rng.randint(0, n_users, wave)
        t0 = time.perf_counter()
        tk = gw.submit_many([Request(user=int(u), now=int(now))
                             for u in q])
        gw.flush(now)
        return time.perf_counter() - t0, tk

    def drive(gw, tr, every, policy):
        rng = np.random.RandomState(1)
        erng = np.random.RandomState(2)
        gw.warm(np.arange(n_users), t00)
        serve_wave(gw, np.random.RandomState(99), t00)  # compile, untimed
        tr.step()                                       # compile, untimed
        serve_s = 0.0
        installs = []
        for i in range(n_waves):
            now = t00 + 60 * (i + 1)
            # feedback trickle keeps the trainer's log suffix non-empty
            gw.observe_many(erng.randint(0, n_users, 16),
                            erng.randint(0, n_items, 16),
                            np.full(16, now - 30))
            dt, _ = serve_wave(gw, rng, now)
            serve_s += dt
            if every and (i + 1) % every == 0:
                tr.step()
                patch = tr.make_patch()
                t0 = time.perf_counter()
                gw.install_patch(patch)
                installs.append(time.perf_counter() - t0)
            gw.tick(now + 30)       # rewarm policy rebuilds here
        st = gw.stats()
        return {
            "name": (f"every{every}_{policy}" if every else "none"),
            "install_every_waves": int(every),
            "policy": policy,
            "patches_applied": int(st.patches_applied),
            "model_version": int(st.model_version),
            "rps": float(n_waves * wave / serve_s),
            "hit_rate": float(st.cache["hits"]
                              / max(st.cache["hits"]
                                    + st.cache["misses"], 1)),
            "patch_install_max_ms": float(st.patch_install_max_ms),
            "patch_install_mean_ms": float(
                np.mean(installs) * 1e3 if installs else 0.0),
        }

    results = {"cadence": []}
    for every, policy in ((0, "purge"), (8, "purge"), (2, "purge"),
                          (2, "rewarm")):
        gw, tr = build(policy)
        row = drive(gw, tr, every, policy)
        results["cadence"].append(row)
        print(f"  {row['name']:>13s}: rps={row['rps']:8.1f} "
              f"hit={row['hit_rate']*100:5.1f}% "
              f"patches={row['patches_applied']:2d} "
              f"install max={row['patch_install_max_ms']:.2f}ms "
              f"mean={row['patch_install_mean_ms']:.2f}ms")

    # ---- swap equivalence: hot-swapped == cold from patched weights ---
    gw, tr = build()
    rng = np.random.RandomState(7)
    q = rng.randint(0, n_users, wave)
    gw.warm(np.arange(n_users), t00)
    serve_wave(gw, np.random.RandomState(99), t00)
    tr.step()
    patch = tr.make_patch()
    t0 = time.perf_counter()
    gw.install_patch(patch)
    install_ms = (time.perf_counter() - t0) * 1e3
    t2 = t00 + 600
    tk = [gw.submit(Request(user=int(u), now=t2)) for u in q]
    gw.flush(t2)
    cold_eng = ServingEngine(cfg, tr.params, scfg)
    cold = Gateway(cold_eng, FeatureInjector(
        InjectionConfig(policy="inject", feature_len=feature_len),
        gw.injector.batch, gw.injector.realtime),
        ServerConfig(slate_len=4, cache_entries=1024))
    ck = [cold.submit(Request(user=int(u), now=t2)) for u in q]
    cold.flush(t2)
    slates = np.stack([t.response.slate for t in tk])
    scores = np.stack([t.response.scores for t in tk])
    np.testing.assert_array_equal(
        slates, np.stack([t.response.slate for t in ck]))
    np.testing.assert_array_equal(
        scores, np.stack([t.response.scores for t in ck]))
    results["swap"] = {
        "bitwise_equal": True,
        "patches_applied": int(gw.stats().patches_applied),
        "model_version": int(gw.stats().model_version),
        "install_ms": float(install_ms),
        "patch_leaves": int(patch.n_leaves),
        "patch_params": int(patch.n_params),
    }
    print(f"  swap: {patch.n_leaves} leaves / {patch.n_params} params "
          f"installed in {install_ms:.2f}ms; responses bitwise equal "
          f"to cold gateway from patched weights")

    # ---- drift: online adapts, frozen does not ------------------------
    from repro.core.event_log import EventLog
    chunks = 16 if smoke else 30
    drift_at = chunks // 2
    d_users = 32
    log = EventLog(n_users=d_users)
    mk = lambda lr: OnlineTrainer(
        cfg, init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32),
        log, cfg=OnlineTrainerConfig(batch_size=8, seq_len=16,
                                     min_new_events=32),
        train_cfg=TrainConfig(adamw=AdamWConfig(
            lr=lr, warmup_steps=2, total_steps=100_000),
            remat=False, param_dtype=jnp.float32))
    online, frozen = mk(3e-2), mk(0.0)   # same batches, lr=0 never moves
    t = 0
    online_loss, frozen_loss = [], []
    for c in range(chunks):
        base = 0 if c < drift_at else 500
        for _ in range(64):
            u = t % d_users
            log.append(u, base + u, 1000 + t)
            t += 1
        mo, mf = online.step(), frozen.step()
        online_loss.append(float(mo["loss"]))
        frozen_loss.append(float(mf["loss"]))
    post = slice(-(chunks - drift_at) // 2, None)  # settled post-drift
    o_post = float(np.mean(online_loss[post]))
    f_post = float(np.mean(frozen_loss[post]))
    results["drift"] = {
        "chunks": chunks, "drift_chunk": drift_at,
        "online_loss": online_loss, "frozen_loss": frozen_loss,
        "online_post_drift_loss": o_post,
        "frozen_post_drift_loss": f_post,
        "adaptation_ratio": f_post / max(o_post, 1e-9),
    }
    print(f"  drift @ chunk {drift_at}: post-drift loss online="
          f"{o_post:.3f} frozen={f_post:.3f} "
          f"({results['drift']['adaptation_ratio']:.1f}x)")

    default_name = ("BENCH_online_smoke.json" if smoke
                    else "BENCH_online.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "online", "smoke": smoke,
                   "config": {"arch": cfg.name, "max_batch": 16,
                              "prefill_len": 64, "inject_len": 8,
                              "feature_len": feature_len,
                              "slate_len": 4},
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_serving_sharded(smoke: bool = False, out_path: str = None):
    """Data-parallel InjectionServer over 1 → 2 → 8 simulated devices.

    Same model/feature plane as the ``serving`` suite. **Strong
    scaling**: every mesh runs the identical serving configuration —
    ``max_batch=64`` panes, identical request stream (256-request waves,
    hot-user locality, warmed cache, interleaved ingest) — and only the
    ("data","model") mesh underneath changes: (1,1)/(2,1)/(8,1) built
    from forced host devices, so each pane splits into 64/32/8 rows per
    device. rps is total requests over summed serve() wall time.

    Identical pane shapes also make the equivalence check exact: the
    widest mesh must serve the same slates as the 1-device mesh (serving
    params are replicated over data and the partitioned programs are
    collective-free).

    Two scaling numbers are recorded, because simulated devices share
    this host's CPU cores:

    * ``wallclock_scaling_1_to_8`` — raw same-config wall-clock ratio.
      All 8 simulated devices contend for the same few cores (CI runners
      have 2-4), and a single device's XLA programs already engage the
      shared intra-op thread pool, so this is hard-capped near 1 by
      construction — it measures the host's core budget, not the
      sharding design.
    * ``rps_scaling_1_to_8`` (headline) — **isolated-shard scaling**.
      The serving programs are verified collective-free (the bench
      compiles the dp=8 inject/slate programs and records the collective
      instruction count in the JSON — it must be 0), so one device's
      shard computation is completely independent of its peers; on real
      multi-chip hardware the wave's wall time is one shard's wall time.
      The bench therefore *measures* a single shard serving its
      1/8 slice of the wave on a dedicated device (same per-device rows
      as the dp=8 mesh, own feature-plane slice of host work) and
      reports wave_time(1 device, full wave) / wave_time(one isolated
      shard) — the same simulate-what-the-host-can't methodology as
      launch/dryrun.py's 512 fake devices.
    """
    print("\n== serving_sharded (data-parallel serving loop, CPU mesh) ==")
    from repro.configs.base import ModelConfig
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.launch.mesh import make_serving_mesh
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.loop import InjectionServer, ServerConfig

    assert len(jax.devices()) >= 8, \
        "serving_sharded needs the forced-host-device XLA flag (set at " \
        "module import when this suite is on the command line)"

    n_items = 4000
    feature_len = 240
    max_batch = 64
    n_users = 500 if smoke else 2_000
    ev_per_user = 32 if smoke else 128
    mesh_sizes = [1, 8] if smoke else [1, 2, 8]
    rounds = 2 if smoke else 8
    wave = 256  # requests per serve() call = 4 panes at max_batch=64

    cfg = ModelConfig(
        name="itfi-ranker-bench", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    def build(dp, mb=max_batch):
        eng = ServingEngine(cfg, params, ServingConfig(
            max_batch=mb, prefill_len=256, inject_len=16,
            cache_capacity=512), mesh=make_serving_mesh(dp, 1))
        rng = np.random.RandomState(0)
        n = n_users * ev_per_user
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=feature_len))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        us = rng.randint(0, n_users, n).astype(np.int64)
        its = rng.randint(0, n_items, n).astype(np.int64)
        tss = rng.randint(0, 5 * DAY, n).astype(np.int64)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=feature_len), store, rts)
        return InjectionServer(eng, inj, ServerConfig(
            slate_len=4, cache_entries=4096))

    def req_users(rng, size):
        hot = max(n_users // 10, 1)
        pick_hot = rng.rand(size) < 0.8
        return np.where(pick_hot, rng.randint(0, hot, size),
                        rng.randint(0, n_users, size))

    def workload(srv, wave_n=None):
        wave_n = wave_n or wave
        rng = np.random.RandomState(1)
        now = 5 * DAY + 100

        def ingest_wave():
            u = req_users(rng, 64)
            it = rng.randint(0, n_items, 64)
            t = np.full(64, now - 30)
            srv.injector.batch.extend(u, it, t)
            srv.injector.realtime.extend(u, it, t)

        srv.warm(np.arange(n_users), now)
        ingest_wave()
        srv.serve(req_users(rng, wave_n), now)  # compile everything untimed
        lat = []
        for _ in range(rounds):
            ingest_wave()
            q = req_users(rng, wave_n)
            t0 = time.perf_counter()
            srv.serve(q, now)
            lat.append(time.perf_counter() - t0)
            now += 60
        return np.asarray(lat)

    def run_one(dp, mb, tag, wave_n=None):
        srv = build(dp, mb)
        lat = workload(srv, wave_n)
        wave_n = wave_n or wave
        rps = rounds * wave_n / lat.sum()
        row = {
            "data": dp, "model": 1, "max_batch": mb,
            "wave_requests": wave_n, "rounds": rounds, "rps": float(rps),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "cache": srv.cache.stats(),
        }
        print(f"  {tag:>16s} {mb:9d} {wave_n:5d} {rps:8.1f} "
              f"{row['p50_ms']:6.1f}ms {row['p99_ms']:7.1f}ms "
              f"{row['cache']['bytes_per_shard']:12d}")
        return row

    results = {"meshes": []}
    print(f"  {'mesh':>16s} {'max_batch':>9s} {'wave':>5s} {'req/s':>8s} "
          f"{'p50':>8s} {'p99':>9s} {'bytes/shard':>12s}")
    for dp in mesh_sizes:
        results["meshes"].append(run_one(dp, max_batch, f"{dp}x1"))
    r0, rN = results["meshes"][0], results["meshes"][-1]
    results["wallclock_scaling_1_to_8"] = rN["rps"] / r0["rps"]

    # Isolated-shard scaling: one dp=8 shard = an independent program on
    # 1/8 of the pane (verified collective-free below), serving its 1/8
    # slice of the wave on a dedicated device. wave_time(1 device, full
    # wave) / wave_time(isolated shard) is the multi-chip scaling this
    # host's shared cores cannot express as raw wall-clock.
    shard_rows = max_batch // 8
    shard = run_one(1, shard_rows, f"shard (1/8 wave)", wave_n=wave // 8)
    results["isolated_shard"] = shard
    results["rps_scaling_1_to_8"] = (
        r0["p50_ms"] / shard["p50_ms"])
    results["rps_scaling_1_to_8_method"] = (
        "p50 wave wall-time ratio: full 256-request wave on one device "
        "vs one shard (1/8 of the pane rows, 1/8 of the wave) on a "
        "dedicated device. Valid because the partitioned programs carry "
        "zero collectives (recorded below). Assumes host-side "
        "feature/pane assembly scales with shards (per-shard frontends, "
        "user-hash routing); a single-controller deployment where one "
        "python host assembles every pane is bounded by "
        "wallclock_scaling_1_to_8 instead.")
    print(f"  wall-clock scaling 1->{rN['data']} (shared-core host): "
          f"{results['wallclock_scaling_1_to_8']:.2f}x")
    print(f"  isolated-shard scaling 1->8 (headline): "
          f"{results['rps_scaling_1_to_8']:.2f}x")

    # evidence for the isolation argument: the dp=8 partitioned serving
    # programs must contain ZERO collective ops
    import re as _re
    widest = build(mesh_sizes[-1])
    eng = widest.engine
    toks, valid = eng.pad_tokens(
        [[1, 2, 3]] * max_batch, eng.scfg.prefill_len)
    st = eng.prefill(toks, valid)
    stoks, svalid = eng.pad_tokens([[4]] * max_batch,
                                   eng.scfg.inject_len, align="left")
    fb = np.zeros((max_batch, cfg.vocab_padded), np.float32)
    s2 = eng.inject(st, stoks, svalid, fallback_logits=fb)
    eng.decode_slate(s2, s2["first_logits"], 4)
    fin = eng.finalize(s2)
    pat = _re.compile(r"all-reduce|all-gather|collective-permute|"
                      r"all-to-all|reduce-scatter")
    n_coll = 0
    for lowered in (
            eng._prefill.lower(eng.params, jnp.asarray(toks),
                               jnp.asarray(valid)),
            eng._slate_fns[4].lower(
                eng.params, fin["caches"], fin["pos"],
                eng._place(s2["first_logits"], eng._tok_ns))):
        n_coll += len(pat.findall(lowered.compile().as_text()))
    results["collective_ops_in_partitioned_programs"] = n_coll
    print(f"  collectives in dp={mesh_sizes[-1]} serving programs: "
          f"{n_coll} (isolation argument holds iff 0)")

    # equivalence: identical request wave on fresh 1-device vs widest mesh
    s1, s8 = build(1), build(mesh_sizes[-1])
    rng = np.random.RandomState(2)
    now = 5 * DAY + 100
    u, it = req_users(rng, 64), rng.randint(0, n_items, 64)
    for srv in (s1, s8):
        srv.injector.batch.extend(u, it, np.full(64, now - 30))
        srv.injector.realtime.extend(u, it, np.full(64, now - 30))
    q = req_users(rng, max_batch)
    a = s1.serve(q, now - 60)  # admit, then hit — exercises the cached path
    a = s1.serve(q, now)
    b = s8.serve(q, now - 60)
    b = s8.serve(q, now)
    diff = float(np.abs(a.scores - b.scores).max())
    results["equivalence"] = {
        "logits_max_abs_diff": diff,
        "logits_allclose": bool(diff < 2e-3),
        "slates_equal": bool((a.slate == b.slate).all()),
    }
    print(f"  1x1 vs {mesh_sizes[-1]}x1: slates_equal="
          f"{results['equivalence']['slates_equal']} "
          f"logits max|Δ|={diff:.2e}")

    default_name = ("BENCH_serving_sharded_smoke.json" if smoke
                    else "BENCH_serving_sharded.json")
    out_path = out_path or os.path.join(ROOT, default_name)
    with open(out_path, "w") as f:
        json.dump({"suite": "serving_sharded", "smoke": smoke,
                   "config": {"arch": cfg.name, "max_batch": max_batch,
                              "prefill_len": 256, "inject_len": 16,
                              "feature_len": feature_len,
                              "n_users": n_users, "slate_len": 4},
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


# ----------------------------------------------------------------------
def bench_roofline():
    print("\n== roofline (dry-run artifacts; baseline -> optimized §Perf) ==")
    files = sorted(glob.glob(os.path.join(ROOT, "experiments", "dryrun",
                                          "*.json")))
    if not files:
        print("  [skip] run python -m repro.launch.dryrun --all first")
        return
    print(f"  {'arch':21s} {'shape':11s} {'mesh':16s} {'pkGiB':>6s} "
          f"{'compute':>8s} {'memory base->opt':>19s} "
          f"{'collective base->opt':>21s}")
    tot = [0.0, 0.0, 0.0, 0.0]
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        t = r["roofline"]
        opt_f = f.replace(os.sep + "dryrun" + os.sep,
                          os.sep + "dryrun_opt" + os.sep)
        to = (json.load(open(opt_f))["roofline"]
              if os.path.exists(opt_f) else t)
        if r["mesh"] == "pod_16x16":
            tot[0] += t["memory_s"]; tot[1] += to["memory_s"]
            tot[2] += t["collective_s"]; tot[3] += to["collective_s"]
        print(f"  {r['arch']:21s} {r['shape']:11s} {r['mesh']:16s} "
              f"{r['memory']['peak_bytes_per_device']/2**30:6.2f} "
              f"{to['compute_s']:8.2e} "
              f"{t['memory_s']:9.2e}->{to['memory_s']:9.2e} "
              f"{t['collective_s']:10.2e}->{to['collective_s']:10.2e}")
    if tot[1] and tot[3]:
        print(f"  fleet (single-pod): memory {tot[0]:.0f}->{tot[1]:.0f}s "
              f"({tot[0]/tot[1]:.2f}x)  collective {tot[2]:.0f}->{tot[3]:.0f}s "
              f"({tot[2]/tot[3]:.2f}x)")


try:  # python -m benchmarks.run vs python benchmarks/run.py
    from benchmarks.ingest import bench_ingest
    from benchmarks.scenarios import bench_scenarios
except ImportError:
    from ingest import bench_ingest
    from scenarios import bench_scenarios

SECTIONS = {
    "ab_lift": bench_ab_lift,
    "latency_ablation": bench_latency_ablation,
    "injection_overhead": bench_injection_overhead,
    "serving_phases": bench_serving_phases,
    "kernel_micro": bench_kernel_micro,
    "roofline": bench_roofline,
    "feature_plane": bench_feature_plane,
    "serving": bench_serving,
    "serving_sharded": bench_serving_sharded,
    "scheduler": bench_scheduler,
    "rollover": bench_rollover,
    "online": bench_online,
    "scenarios": bench_scenarios,
    "ingest": bench_ingest,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS))
    ap.add_argument("--suite", default=None, choices=sorted(SECTIONS),
                    help="run a single suite (alias of --only)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (feature_plane/serving only)")
    ap.add_argument("--out", default=None,
                    help="output path for suites that write a BENCH json")
    args = ap.parse_args()
    pick = args.suite or args.only
    for name, fn in SECTIONS.items():
        if pick and name != pick:
            continue
        if name in ("feature_plane", "serving", "serving_sharded",
                    "scheduler", "rollover", "online", "scenarios",
                    "ingest"):
            if not pick:  # full-size suites take minutes — run them
                continue  # explicitly via --suite
            fn(smoke=args.smoke, out_path=args.out)
        else:
            fn()


if __name__ == "__main__":
    main()
