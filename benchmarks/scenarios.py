"""The ``scenarios`` suite: production traffic regimes vs SLO contracts.

Runs every named scenario from ``repro.serving.loadgen`` (diurnal /
flash_crowd / cold_start_storm / churn_heavy / mixed_fleet) end to end
through the Gateway, gates each on its declared SLO contract, proves
determinism by replaying one scenario and comparing trace + slate
fingerprints, and writes BENCH_scenarios[_smoke].json. When
``GITHUB_STEP_SUMMARY`` is set (CI), appends a markdown pass/fail table.

The committed artifact is the acceptance record for PR 7: steady-state
scenarios pass their contracts with **zero sheds** (the load-shedder
must never fire off-overload), while flash_crowd holds its p99
queue-delay budget *because* it sheds — ``min_shed`` asserts shedding
actually engaged and ``GatewayStats.shed`` accounts for every rejection.

Sim-time gates (queue delay, shed/miss rates, hit rates) are
deterministic, so the artifact's pass/fail is machine-independent; the
wall-clock budgets are deliberately loose (they catch a path suddenly
paying compile time, not regressions of microseconds).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _summary_lines(rows):
    """Markdown pass/fail table for the CI job summary."""
    out = ["### Scenario SLOs", "",
           "| scenario | arch | requests | shed | hit rate | queue p99 (s) "
           "| deadline misses | SLO |",
           "|---|---|---:|---:|---:|---:|---:|---|"]
    for r in rows:
        m = r["metrics"]
        out.append(
            f"| {r['name']} | {r['arch'] or '-'} | {m['requests']} "
            f"| {m['shed']} | {m['hit_rate']:.2f} "
            f"| {m['queue_delay']['p99']:.0f} | {m['deadline_misses']} "
            f"| {'PASS' if r['slo_pass'] else 'FAIL'} |")
    return out


def bench_scenarios(smoke: bool = False, out_path: str = None):
    """Run the five scenarios + the determinism replay; write the
    artifact. Returns the per-(scenario, arch) result rows."""
    from repro.serving.loadgen import (SCENARIO_NAMES, get_scenario,
                                       run_scenario)

    print("\n== scenarios (trace-driven load vs SLO contracts) ==")
    rows = []
    for name in SCENARIO_NAMES:
        spec = get_scenario(name, smoke=smoke)
        t0 = time.perf_counter()
        results = run_scenario(spec)
        dt = time.perf_counter() - t0
        for res in results:
            r = res.as_dict()
            r["slo"] = spec.slo.as_dict()
            r["wall_s"] = round(dt / len(results), 3)
            rows.append(r)
            m = res.metrics
            print(f"  {name:16s} {res.arch or '-':22s} "
                  f"req={m['requests']:5d} shed={m['shed']:4d} "
                  f"hit={m['hit_rate']:.2f} "
                  f"qd p50/p99={m['queue_delay']['p50']:.0f}/"
                  f"{m['queue_delay']['p99']:.0f}s "
                  f"miss={m['deadline_misses']:3d} "
                  f"{'PASS' if res.slo_pass else 'FAIL'}")
            for g in res.gates:
                if not g["pass"]:
                    print(f"    FAILED gate {g['gate']}: "
                          f"budget={g['budget']} actual={g['actual']}")

    # determinism: the same spec must reproduce the identical op stream
    # AND the identical served bytes (churn_heavy exercises the rollover
    # path, the strongest determinism claim)
    spec = get_scenario("churn_heavy", smoke=smoke)
    a = run_scenario(spec, warmup=False)[0]
    b = run_scenario(spec, warmup=False)[0]
    determinism = {
        "scenario": "churn_heavy",
        "trace_fingerprints": [a.trace_fingerprint, b.trace_fingerprint],
        "slate_fingerprints": [a.slate_fingerprint, b.slate_fingerprint],
        "reproducible": (a.trace_fingerprint == b.trace_fingerprint
                         and a.slate_fingerprint == b.slate_fingerprint),
    }
    print(f"  determinism(churn_heavy): trace {a.trace_fingerprint} "
          f"slates {a.slate_fingerprint} "
          f"{'REPRODUCED' if determinism['reproducible'] else 'DIVERGED'}")
    assert determinism["reproducible"], \
        "same seed must reproduce identical trace and slates"

    n_fail = sum(not r["slo_pass"] for r in rows)
    print(f"  {len(rows)} scenario runs, {n_fail} SLO failures")

    if out_path is None:
        out_path = "BENCH_scenarios_smoke.json" if smoke \
            else "BENCH_scenarios.json"
    with open(out_path, "w") as f:
        json.dump({"suite": "scenarios", "smoke": smoke,
                   "config": {"scenarios": list(SCENARIO_NAMES)},
                   "determinism": determinism,
                   "results": rows}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("\n".join(_summary_lines(rows)) + "\n")
    return rows


if __name__ == "__main__":
    bench_scenarios(smoke="--smoke" in sys.argv)
