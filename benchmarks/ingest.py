"""The ``ingest`` suite: tiered sliding-window EventLog under sustained
production ingest.

Three measurements, each asserted in-suite (the committed artifact is an
acceptance record, not just numbers):

**bounded** — sustained-rate ingest across many window rollovers with
compaction at every boundary. Samples the retained footprint
(``bytes_hot + bytes_warm``) at each rollover and asserts the
steady-state trajectory is FLAT — no monotonic growth once retention
fills — while an unbounded log over the same stream grows linearly.
This is the memory-leak claim of the tiered refactor.

**oracle** — the exactness contract, differentially: the same seeded
stream (including post-compaction late arrivals that take the demotion
path) through a tiered log and an unbounded oracle; every window-aligned
in-retention ``materialize``, ``users_with_events``, position-anchored
``changed_users``, and trainer-style ``events_since`` must be bitwise
identical. Asserted; recorded as ``oracle_bitwise``.

**churn_compact** — the production scenario: churn_heavy's regime with
the tiered log live (sync compaction on gateway ticks, >= 3 rollovers
mid-trace) and a slice of arrivals pinned to the model-free ``decay``
policy arm, so panes mix engine-served and decay-served rows. Must hold
churn_heavy's SLO contract and reproduce bit-identical slates on replay.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _bounded(smoke: bool) -> dict:
    from repro.core.event_log import EventLog

    window = 200
    retention = 4
    segment_k = 32
    n_users = 256
    per_window = 500 if smoke else 4000
    rollovers = 8 if smoke else 12
    hot_budget = per_window * 2
    rng = np.random.RandomState(0)
    log = EventLog(n_users, window=window, retention_windows=retention,
                   segment_k=segment_k, hot_budget=hot_budget)
    oracle = EventLog(n_users)       # the leak this PR removes
    samples = []
    t0 = time.perf_counter()
    for r in range(rollovers):
        base = r * window
        us = rng.randint(0, n_users, per_window)
        its = rng.randint(0, 1000, per_window)
        tss = base + np.sort(rng.randint(0, window, per_window))
        log.extend(us, its, tss)
        oracle.extend(us, its, tss)
        log.compact(base + window)
        st = log.ingest_stats()
        samples.append(int(st["bytes_hot"] + st["bytes_warm"]))
    wall = time.perf_counter() - t0
    events = rollovers * per_window
    unbounded = int(oracle.ingest_stats()["bytes_hot"])

    # the gate: once retention fills (after `retention` rollovers) the
    # footprint must be flat across the remaining (>= 3) rollovers —
    # neither monotone growth nor creep past a tight band
    tail = samples[retention:]
    assert len(tail) >= 3, "need >= 3 steady-state rollovers to gate"
    assert not all(b > a for a, b in zip(tail, tail[1:])), \
        f"retained bytes grew monotonically in steady state: {tail}"
    assert max(tail) <= min(tail) * 1.3, \
        f"steady-state footprint not flat: {tail}"
    assert samples[-1] < unbounded, \
        "tiered log retained more than the unbounded log"
    st = log.ingest_stats()
    print(f"  bounded: {events} events / {rollovers} rollovers "
          f"retained={samples[-1]/1024:.0f}KiB "
          f"unbounded={unbounded/1024:.0f}KiB "
          f"({unbounded/max(samples[-1],1):.1f}x) "
          f"rate={events/wall/1e6:.2f}M ev/s")
    return {
        "rollovers": rollovers, "events": events,
        "window": window, "retention_windows": retention,
        "segment_k": segment_k, "hot_budget": hot_budget,
        "bytes_total_per_rollover": samples,
        "unbounded_bytes": unbounded,
        "bytes_ratio_vs_unbounded": round(samples[-1] / unbounded, 4),
        "ingest_rate_events_per_s": round(events / wall, 1),
        "steady_state_bounded": True,      # the asserts above
        "counters": {k: int(v) for k, v in st.items()},
    }


def _oracle(smoke: bool) -> dict:
    from repro.core.event_log import EventLog

    window = 100
    n_windows = 8
    k = 16
    n_users = 64
    per_window = 150 if smoke else 600
    rng = np.random.RandomState(1)
    # retention deeper than the stream: every query is in-retention,
    # i.e. inside the regime where the contract promises bitwise
    log = EventLog(n_users, window=window, retention_windows=16,
                   segment_k=24)
    oracle = EventLog(n_users)
    late_events = compactions = 0
    for w in range(n_windows):
        base = w * window
        us = rng.randint(0, n_users, per_window)
        its = rng.randint(0, 500, per_window)
        tss = base + np.sort(rng.randint(0, window, per_window))
        log.extend(us, its, tss)
        oracle.extend(us, its, tss)
        log.compact(base + window)
        compactions += 1
        # late arrivals below the fresh horizon: the demotion path
        for _ in range(4):
            u = int(rng.randint(n_users))
            i = int(rng.randint(500))
            t = int(rng.randint(0, base + window))
            log.append(u, i, t)
            oracle.append(u, i, t)
            late_events += 1
    assert log.counters["demoted"] > 0, "late events never took demotion"
    assert log.counters["dropped_late"] == 0 and \
        log.counters["evicted"] == 0

    users = np.arange(n_users)
    hi_t = n_windows * window
    queries = 0
    ok = True
    for a in range(n_windows + 1):
        for b in range(a + 1, n_windows + 2):   # b past the horizon too
            lo, hi = a * window, b * window
            got = log.materialize(users, lo, hi, k)
            want = oracle.materialize(users, lo, hi, k)
            ok &= all(np.array_equal(g, w) for g, w in zip(got, want))
            ok &= np.array_equal(log.users_with_events(lo, hi),
                                 oracle.users_with_events(lo, hi))
            queries += 1
    # position-anchored scans and the trainer consume primitive
    for start in (0, log.n_events // 3, log.n_events - 5):
        ok &= np.array_equal(
            log.users_with_events(0, hi_t, start=start),
            oracle.users_with_events(0, hi_t, start=start))
        got = log.view().events_since(start)
        want = oracle.view().events_since(start)
        ok &= all(np.array_equal(g, w) for g, w in zip(got, want))
        ok &= np.array_equal(
            log.changed_users(hi_t - window, hi_t, 2 * window,
                              since=start),
            oracle.changed_users(hi_t - window, hi_t, 2 * window,
                                 since=start))
        queries += 3
    assert ok, "tiered log diverged from the unbounded oracle"
    print(f"  oracle: {log.n_events} events ({late_events} late, "
          f"{log.counters['demoted']} demoted) x {queries} queries "
          f"across {compactions} compactions: bitwise")
    return {"events": int(log.n_events), "late_events": late_events,
            "demoted": int(log.counters["demoted"]),
            "compactions": compactions, "queries": queries,
            "oracle_bitwise": bool(ok)}


def _churn_compact(smoke: bool) -> dict:
    from repro.serving.loadgen import get_scenario, run_scenario

    spec = get_scenario("churn_compact", smoke=smoke)
    t0 = time.perf_counter()
    a = run_scenario(spec)[0]
    b = run_scenario(spec, warmup=False)[0]
    wall = time.perf_counter() - t0
    deterministic = (a.trace_fingerprint == b.trace_fingerprint
                     and a.slate_fingerprint == b.slate_fingerprint)
    ing = a.gateway_stats["ingest"]
    decay_served = int(a.metrics["paths"].get("decay", 0))
    assert a.slo_pass, [g for g in a.gates if not g["pass"]]
    assert deterministic, "replay diverged with compaction live"
    assert ing["compactions"] >= 3, ing
    assert decay_served > 0, "no decay-arm rows in the mixed panes"
    m = a.metrics
    print(f"  churn_compact: req={m['requests']} "
          f"decay={decay_served} compactions={ing['compactions']} "
          f"qd p99={m['queue_delay']['p99']:.0f}s "
          f"{'PASS' if a.slo_pass else 'FAIL'} "
          f"{'REPRODUCED' if deterministic else 'DIVERGED'} "
          f"({wall:.0f}s)")
    return {"slo_pass": bool(a.slo_pass), "deterministic": deterministic,
            "decay_requests": decay_served,
            "compactions": int(ing["compactions"]),
            "trace_fingerprint": a.trace_fingerprint,
            "slate_fingerprints": [a.slate_fingerprint,
                                   b.slate_fingerprint],
            "metrics": m, "ingest": ing,
            "gates": a.gates, "wall_s": round(wall, 1)}


def bench_ingest(smoke: bool = False, out_path: str = None):
    print("\n== ingest (tiered sliding-window log: bounded memory, "
          "oracle exactness, compaction under load) ==")
    results = {"bounded": _bounded(smoke), "oracle": _oracle(smoke)}
    results["churn_compact"] = _churn_compact(smoke)
    if out_path is None:
        out_path = ("BENCH_ingest_smoke.json" if smoke
                    else "BENCH_ingest.json")
    with open(out_path, "w") as f:
        json.dump({"suite": "ingest", "smoke": smoke,
                   "config": {
                       "window": results["bounded"]["window"],
                       "retention_windows":
                           results["bounded"]["retention_windows"],
                       "segment_k": results["bounded"]["segment_k"],
                       "hot_budget": results["bounded"]["hot_budget"],
                       "events_per_window":
                           results["bounded"]["events"]
                           // results["bounded"]["rollovers"],
                       "rollovers": results["bounded"]["rollovers"]},
                   "results": results}, f, indent=2)
    print(f"  wrote {os.path.abspath(out_path)}")
    return results


if __name__ == "__main__":
    bench_ingest(smoke="--smoke" in sys.argv)
