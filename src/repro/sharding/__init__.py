from repro.sharding.rules import (  # noqa: F401
    ShardingRules, batch_pspec, cache_pspecs, data_axes, param_pspecs)
