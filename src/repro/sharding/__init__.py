from repro.sharding.rules import (  # noqa: F401
    ServingShardings, ShardingRules, batch_pspec, cache_pspecs, data_axes,
    param_pspecs, seq_cache_pspecs, serving_pspecs)
