"""Sharding rules: ModelConfig × mesh → PartitionSpec pytrees.

2-D "FSDP × TP" layout à la MaxText (DESIGN.md §5):

* ``data`` axis (plus the outer ``pod`` axis on multi-pod meshes) shards the
  batch and, FSDP-style, the d_model dimension of weight matrices.
* ``model`` axis is tensor parallelism: attention heads (or head_dim when
  the head count doesn't divide), MLP d_ff, MoE experts (or expert d_ff),
  SSM heads, and the embedding's d_model.

Every rule degrades to replication when a dimension doesn't divide the
axis size — jit rejects uneven shardings, so divisibility is checked here,
not discovered at compile time.

The functions return **PartitionSpec pytrees** matching the abstract pytrees
from ``models.model.param_shapes`` / ``cache_shapes``; launch code wraps
them in NamedSharding(mesh, spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The (super-)data axes: ("pod","data") on multi-pod, else ("data",)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved layout decisions for one (config, mesh) pair."""
    cfg: ModelConfig
    dp: Tuple[str, ...]        # data axes (batch / FSDP)
    tp: str                    # model axis
    dp_size: int
    tp_size: int
    # resolved choices
    attn_heads_on_tp: bool     # heads vs head_dim on the model axis
    moe_experts_on_tp: bool    # expert axis vs expert-d_ff on the model axis

    @classmethod
    def make(cls, cfg: ModelConfig, mesh: Mesh,
             decode: bool = False) -> "ShardingRules":
        dp = data_axes(mesh)
        tp = "model" if "model" in mesh.axis_names else None
        tpn = axis_size(mesh, tp) if tp else 1
        # Decode perf iteration (EXPERIMENTS.md §Perf): attention layout
        # must MATCH the KV-cache layout or XLA re-gathers the whole cache
        # per layer per token (observed 8 GiB wire/step on llama decode).
        # Cache shards kv-heads when they divide tp, else head_dim — so q/o
        # follow the same rule in decode mode (scores psum over tp is tiny:
        # (B,H,1,W) vs the (B,W,kv,hd) cache).
        heads_on_tp = (_div(cfg.n_kv_heads, tpn) if decode
                       else _div(cfg.n_heads, tpn))
        return cls(
            cfg=cfg, dp=dp, tp=tp, dp_size=axis_size(mesh, dp), tp_size=tpn,
            attn_heads_on_tp=heads_on_tp,
            moe_experts_on_tp=(cfg.moe is not None
                               and _div(cfg.moe.n_experts, tpn)),
        )

    # -- helpers ---------------------------------------------------------
    def fsdp(self, dim: int):
        """Shard a d_model-like dim over the data axes when it divides."""
        return self.dp if _div(dim, self.dp_size) else None

    def tpa(self, dim: int):
        return self.tp if _div(dim, self.tp_size) else None


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------

def _attn_specs(r: ShardingRules, stacked: bool):
    cfg = r.cfg
    lead = (None,) if stacked else ()
    hd, nq, nkv, d = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    if r.attn_heads_on_tp:
        q_spec = P(*lead, r.fsdp(d), r.tp, None)
        o_spec = P(*lead, r.tp, None, r.fsdp(d))
        bq = P(*lead, r.tp, None)
    else:  # shard head_dim instead (granite 24H, llava 56H)
        q_spec = P(*lead, r.fsdp(d), None, r.tpa(hd))
        o_spec = P(*lead, None, r.tpa(hd), r.fsdp(d))
        bq = P(*lead, None, r.tpa(hd))
    # KV heads (GQA kv=8) rarely divide tp=16. The compute path repeats KV
    # to flat heads (attention.py), so KV projections stay REPLICATED over
    # tp (small: d×nkv×hd) — the repeat then slices locally per shard.
    kv_spec = (P(*lead, r.fsdp(d), r.tp, None) if _div(nkv, r.tp_size)
               else P(*lead, r.fsdp(d), None, None))
    bkv = (P(*lead, r.tp, None) if _div(nkv, r.tp_size)
           else P(*lead, None, None))
    specs = {"wq": q_spec, "wk": kv_spec, "wv": kv_spec, "wo": o_spec}
    if cfg.qkv_bias:
        specs.update({"bq": bq, "bk": bkv, "bv": bkv})
    return specs


def _ssm_specs(r: ShardingRules, stacked: bool):
    cfg = r.cfg
    lead = (None,) if stacked else ()
    d, din, nh = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads
    din_tp = r.tpa(din)
    nh_tp = r.tpa(nh)
    return {
        "wz": P(*lead, r.fsdp(d), din_tp),
        "wx": P(*lead, r.fsdp(d), din_tp),
        "wB": P(*lead, r.fsdp(d), None),   # B/C shared across heads
        "wC": P(*lead, r.fsdp(d), None),
        "wdt": P(*lead, r.fsdp(d), nh_tp),
        "conv_x": P(*lead, None, din_tp),
        "conv_B": P(*lead, None, None),
        "conv_C": P(*lead, None, None),
        "conv_bias_x": P(*lead, din_tp),
        "conv_bias_B": P(*lead, None),
        "conv_bias_C": P(*lead, None),
        "A_log": P(*lead, nh_tp),
        "D": P(*lead, nh_tp),
        "dt_bias": P(*lead, nh_tp),
        "norm_scale": P(*lead, din_tp),
        "out_proj": P(*lead, din_tp, r.fsdp(d)),
    }


def _moe_specs(r: ShardingRules, stacked: bool):
    cfg = r.cfg
    lead = (None,) if stacked else ()
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    if r.moe_experts_on_tp:  # expert parallelism over the model axis
        up = P(*lead, r.tp, r.fsdp(d), None)
        down = P(*lead, r.tp, None, r.fsdp(d))
    else:  # TP inside each expert (mixtral 8e, granite 40e on tp=16)
        up = P(*lead, None, r.fsdp(d), r.tpa(f))
        down = P(*lead, None, r.tpa(f), r.fsdp(d))
    return {"router": P(*lead, r.fsdp(d), None),
            "gate": up, "up": up, "down": down}


def _mlp_specs(r: ShardingRules, stacked: bool):
    cfg = r.cfg
    lead = (None,) if stacked else ()
    d, f = cfg.d_model, cfg.d_ff
    return {"gate": P(*lead, r.fsdp(d), r.tpa(f)),
            "up": P(*lead, r.fsdp(d), r.tpa(f)),
            "down": P(*lead, r.tpa(f), r.fsdp(d))}


def param_pspecs(cfg: ModelConfig, mesh: Mesh, decode: bool = False) -> Any:
    """PartitionSpec pytree matching ``models.model.init_params``."""
    from repro.models.model import pattern_sig
    r = ShardingRules.make(cfg, mesh, decode=decode)
    vp, d = cfg.vocab_padded, cfg.d_model
    # Embedding storage is d-sharded (vocab replicated): the token gather
    # stays LOCAL — XLA's partitioner handles gathers on a sharded operand
    # dim badly (verifier failure observed). The LM-head matmul wants the
    # opposite (vocab-sharded so logits shard over tp); untied heads are
    # stored that way, tied tables are resharded in-step (cheap all-to-all,
    # see models.model._logits head_sharding).
    embed = P(None, r.tpa(d))
    head = P(r.tpa(vp), None)

    blocks = {}
    for p, (kind, mlp_kind) in enumerate(pattern_sig(cfg)):
        lp: dict = {"norm1": {"scale": P(None, None)}}
        if kind == "attn":
            lp["attn"] = _attn_specs(r, stacked=True)
        else:
            lp["ssm"] = _ssm_specs(r, stacked=True)
        if mlp_kind != "none":
            lp["norm2"] = {"scale": P(None, None)}
        if mlp_kind == "dense":
            lp["mlp"] = _mlp_specs(r, stacked=True)
        elif mlp_kind == "moe":
            lp["moe"] = _moe_specs(r, stacked=True)
        blocks[f"pos{p}"] = lp

    specs = {"embed": {"table": embed}, "blocks": blocks,
             "final_norm": {"scale": P(None)}}
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"table": head}
    return specs


def head_pspec(cfg: ModelConfig, mesh: Mesh) -> P:
    """The in-step resharding target for the logits matmul table."""
    r = ShardingRules.make(cfg, mesh)
    return P(r.tpa(cfg.vocab_padded), None)


# ----------------------------------------------------------------------
# Activations / caches / optimizer
# ----------------------------------------------------------------------

def batch_pspec(mesh: Mesh, global_batch: int) -> P:
    """Tokens/labels (B, S): batch over the data axes when it divides."""
    dp = data_axes(mesh)
    return P(dp if _div(global_batch, axis_size(mesh, dp)) else None, None)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int,
                 decode: bool = True) -> Any:
    """Decode-cache PartitionSpec pytree matching ``init_cache``.

    Leading dim of every leaf is the layer-repeat R. Attention K/V are
    (R, B, W, nkv, hd): batch over data when divisible, otherwise the
    **sequence/capacity dim W** shards over data (long_500k, batch=1).
    SSM states (R, B, nh, hp, ds): batch over data, heads over model.
    """
    from repro.models.model import pattern_sig
    r = ShardingRules.make(cfg, mesh, decode=decode)
    b_on_dp = _div(batch, r.dp_size)
    bspec = r.dp if b_on_dp else None
    wspec = None if b_on_dp else r.dp  # capacity shards when batch can't
    hd_tp = r.tpa(cfg.head_dim_) if not _div(cfg.n_kv_heads, r.tp_size) else None
    kv_tp = r.tp if _div(cfg.n_kv_heads, r.tp_size) else None

    out = {}
    for p, (kind, _) in enumerate(pattern_sig(cfg)):
        if kind == "attn":
            kv = P(None, bspec, wspec, kv_tp, hd_tp)
            out[f"pos{p}"] = {"k": kv, "v": kv,
                              "valid": P(None, bspec, wspec)}
        else:
            nh_tp = r.tpa(cfg.n_ssm_heads)
            din_tp = r.tpa(cfg.d_inner)
            out[f"pos{p}"] = {
                "conv_x": P(None, bspec, None, din_tp),
                "conv_B": P(None, bspec, None, None),
                "conv_C": P(None, bspec, None, None),
                "state": P(None, bspec, nh_tp, None, None),
            }
    return out


def seq_cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int) -> Any:
    """PartitionSpec pytree for **sequence-form** caches — the pytree
    ``prefill``/``extend`` return (and ``extend`` consumes).

    Structurally like :func:`cache_pspecs` but attention layers carry only
    ``{"k", "v"}`` of shape (R, B, S, nkv, hd): the pad mask travels
    separately as the engine state's ``valid`` (B, S), so there is no
    per-layer ``valid`` leaf before ``finalize`` builds the ring cache.
    Batch (axis 1 of every leaf) shards over the data axes; heads/d_inner
    shard over the model axis exactly as in the ring layout so ``finalize``
    is a local reshape, not a resharding collective.
    """
    from repro.models.model import pattern_sig
    r = ShardingRules.make(cfg, mesh, decode=True)
    bspec = r.dp if _div(batch, r.dp_size) else None
    hd_tp = r.tpa(cfg.head_dim_) if not _div(cfg.n_kv_heads, r.tp_size) else None
    kv_tp = r.tp if _div(cfg.n_kv_heads, r.tp_size) else None

    out = {}
    for p, (kind, _) in enumerate(pattern_sig(cfg)):
        if kind == "attn":
            kv = P(None, bspec, None, kv_tp, hd_tp)
            out[f"pos{p}"] = {"k": kv, "v": kv}
        else:
            out[f"pos{p}"] = {
                "conv_x": P(None, bspec, None, r.tpa(cfg.d_inner)),
                "conv_B": P(None, bspec, None, None),
                "conv_C": P(None, bspec, None, None),
                "state": P(None, bspec, r.tpa(cfg.n_ssm_heads), None, None),
            }
    return out


@dataclasses.dataclass(frozen=True)
class SlotPoolShardings:
    """PartitionSpecs for the paged prefill-state pool (serving/pool.py)."""
    caches: Any          # pytree of P matching seq-form caches, slot axis 1
    valid: P             # (n_slots, S)
    rows: P              # (n_slots,)
    logits: P            # (n_slots, Vp)


def slot_pool_pspecs(cfg: ModelConfig, mesh: Mesh) -> SlotPoolShardings:
    """Sharding bundle for the device-resident slot pool.

    The pool's **slot axis** (axis 1 of every cache leaf, axis 0 of the
    valid/next_pos/last_logits planes) is deliberately REPLICATED over
    the data axes, never sharded: pane assembly is a one-hot einsum that
    *contracts over slots*, and a slot-sharded operand would turn every
    gather into a cross-shard partial-sum (all-reduce). With the pool
    replicated and the gathered pane batch-sharded, GSPMD partitions the
    contraction by output rows — each data shard reads its pane rows
    from its local pool copy with **zero collectives** (asserted from
    HLO by tools/slot_pool_check.py). Model-axis (TP) dims shard exactly
    as :func:`seq_cache_pspecs`, so gathered panes land in the layout
    ``inject``/``finalize`` consume without resharding.
    """
    from repro.models.model import pattern_sig
    r = ShardingRules.make(cfg, mesh, decode=True)
    hd_tp = r.tpa(cfg.head_dim_) if not _div(cfg.n_kv_heads, r.tp_size) else None
    kv_tp = r.tp if _div(cfg.n_kv_heads, r.tp_size) else None
    out = {}
    for p, (kind, _) in enumerate(pattern_sig(cfg)):
        if kind == "attn":
            kv = P(None, None, None, kv_tp, hd_tp)
            out[f"pos{p}"] = {"k": kv, "v": kv}
        else:
            out[f"pos{p}"] = {
                "conv_x": P(None, None, None, r.tpa(cfg.d_inner)),
                "conv_B": P(None, None, None, None),
                "conv_C": P(None, None, None, None),
                "state": P(None, None, r.tpa(cfg.n_ssm_heads), None, None),
            }
    return SlotPoolShardings(caches=out, valid=P(None, None),
                             rows=P(None), logits=P(None, None))


@dataclasses.dataclass(frozen=True)
class ServingShardings:
    """Every PartitionSpec the serving engine needs, resolved once.

    ``params`` uses decode-mode rules (attention layout must match the KV
    cache layout — see ShardingRules.make) with the FSDP data-axis factor
    **stripped**: serving replicates weights across data-parallel replicas
    and shards only the request batch + caches over ``data``. FSDP is a
    training-memory trick — on the serving hot path it would re-gather
    every weight matrix per layer per token, which is exactly the decode
    pathology the decode-mode rules exist to avoid. TP sharding (the
    ``model`` axis) is kept as-is. ``tokens``/``rows`` shard the request
    batch over the data axes; ``seq_caches``/``ring_caches`` are the
    prefill/inject and decode cache layouts respectively.
    """
    params: Any          # pytree of P matching init_params
    tokens: P            # (B, S) token/valid planes
    rows: P              # (B,) per-row scalars (next_pos / pos)
    logits: P            # (B, S, Vp) and (B, Vp) prefixes
    seq_caches: Any      # pytree of P matching prefill/extend caches
    ring_caches: Any     # pytree of P matching init_cache/finalize
    data_shards: int     # number of data-parallel shards


def serving_pspecs(cfg: ModelConfig, mesh: Mesh, max_batch: int,
                   ) -> ServingShardings:
    """Resolve the full serving-path sharding bundle for one engine.

    Raises ``ValueError`` when ``max_batch`` does not divide the data-axis
    size — a pane that shards unevenly would either recompile per shape or
    fail inside jit, so it is rejected at engine construction instead.
    """
    dp = data_axes(mesh)
    dpn = axis_size(mesh, dp)
    if max_batch % max(dpn, 1) != 0:
        raise ValueError(
            f"max_batch={max_batch} must be a multiple of the data-axis "
            f"size {dpn} (mesh {dict(mesh.shape)}); panes shard evenly or "
            f"not at all")

    def strip_dp(spec: P) -> P:
        """Replace any data-axis factor in a weight spec with replication."""
        def keep(ax):
            axes = ax if isinstance(ax, tuple) else (ax,)
            return None if any(a in dp for a in axes) else ax
        return P(*[keep(ax) for ax in spec])

    return ServingShardings(
        params=jax.tree.map(strip_dp, param_pspecs(cfg, mesh, decode=True),
                            is_leaf=lambda x: isinstance(x, P)),
        tokens=batch_pspec(mesh, max_batch),
        rows=P(dp if _div(max_batch, dpn) else None),
        logits=P(dp if _div(max_batch, dpn) else None),
        seq_caches=seq_cache_pspecs(cfg, mesh, max_batch),
        ring_caches=cache_pspecs(cfg, mesh, max_batch),
        data_shards=dpn,
    )


def opt_pspecs(param_specs: Any) -> Any:
    """Optimizer state mirrors the parameter sharding (ZeRO-for-free)."""
    from repro.training.optimizer import OptState
    return OptState(step=P(), master=param_specs,
                    m=param_specs, v=param_specs)
