from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, get_config, list_configs, reduced,
    register, pad_vocab,
)
from repro.configs.shapes import InputShape, SHAPES, get_shape  # noqa: F401
