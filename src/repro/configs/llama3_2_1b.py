"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import LLAMA32_1B as CONFIG  # noqa: F401
