"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import GRANITE_MOE_3B as CONFIG  # noqa: F401
