"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import PAPER_RANKER as CONFIG  # noqa: F401
