"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import MIXTRAL_8X22B as CONFIG  # noqa: F401
