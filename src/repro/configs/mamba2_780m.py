"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import MAMBA2_780M as CONFIG  # noqa: F401
