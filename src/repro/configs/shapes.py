"""Assigned input shapes and their step kinds."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]
