"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import COMMAND_R_PLUS as CONFIG  # noqa: F401
