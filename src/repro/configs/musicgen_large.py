"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import MUSICGEN_LARGE as CONFIG  # noqa: F401
