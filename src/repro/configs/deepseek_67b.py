"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import DEEPSEEK_67B as CONFIG  # noqa: F401
