"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import CODEQWEN_7B as CONFIG  # noqa: F401
