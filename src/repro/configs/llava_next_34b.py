"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import LLAVA_NEXT_34B as CONFIG  # noqa: F401
