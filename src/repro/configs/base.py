"""Unified model/config system.

Every assigned architecture (plus the paper's own ranker) is expressed as a
``ModelConfig``. The config is a frozen dataclass so it can be closed over by
jit'd functions and hashed into compilation caches.

Layer-type schedule
-------------------
``layer_kinds()`` returns, per layer, one of ``"attn"`` / ``"ssm"`` — the
sequence-mixing block — and ``mlp_kinds()`` one of ``"dense"`` / ``"moe"``.
This single mechanism expresses dense transformers, MoE transformers, pure
SSMs (mamba2) and the Jamba hybrid (attn:mamba 1:7, MoE every other layer).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # which layers get an MoE MLP: every `period` layers, offset `offset`.
    period: int = 1
    offset: int = 0
    # router options
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # tokens-per-expert buffer size = seq * top_k * capacity_factor / E.
    # Train default 1.25 (GShard-style dropping); set to n_experts/top_k
    # (or use ``no_drop()``) for drop-free eval/serving.
    capacity_factor: float = 1.25

    def no_drop(self) -> "MoEConfig":
        import dataclasses as _dc
        return _dc.replace(self, capacity_factor=float(self.n_experts) / self.top_k)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    # which layers are SSM: Jamba uses attn at (i % period == attn_offset),
    # SSM elsewhere; pure mamba2 has attn_period=0 (never attention).
    attn_period: int = 0
    attn_offset: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    sliding_window: int = 0  # 0 = full attention
    qkv_bias: bool = False  # qwen-style attention bias
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stubs (vlm/audio): number of prefix embedding
    # positions supplied externally as precomputed patch/frame embeddings.
    frontend: str = "none"  # none | vision | audio
    # citation for the architecture source
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:
        s = self.ssm or SSMConfig()
        return s.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        s = self.ssm or SSMConfig()
        return self.d_inner // s.head_dim

    # ------------------------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer sequence-mixing block kind ("attn" | "ssm")."""
        kinds = []
        for i in range(self.n_layers):
            if self.ssm is None:
                kinds.append("attn")
            elif self.ssm.attn_period == 0:
                kinds.append("ssm")
            else:
                kinds.append(
                    "attn" if i % self.ssm.attn_period == self.ssm.attn_offset else "ssm"
                )
        return tuple(kinds)

    def mlp_kinds(self) -> Tuple[str, ...]:
        """Per-layer MLP kind ("dense" | "moe" | "none")."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("none")  # mamba2 blocks have no separate MLP
            elif self.moe is not None and i % self.moe.period == self.moe.offset:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (true vocab, not padded)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        lk, mk = self.layer_kinds(), self.mlp_kinds()
        for kind, mlp in zip(lk, mk):
            total += 2 * d  # two norms (scale only)
            if kind == "attn":
                total += d * self.n_heads * hd  # q
                total += 2 * d * self.n_kv_heads * hd  # k, v
                total += self.n_heads * hd * d  # o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # ssm (mamba2)
                s = self.ssm or SSMConfig()
                din, nh = self.d_inner, self.n_ssm_heads
                total += d * (2 * din + 2 * s.d_state + nh)  # in_proj (z,x,B,C,dt)
                total += s.conv_width * (din + 2 * s.d_state)  # conv
                total += nh * 2  # A_log, D
                total += din  # gate norm scale
                total += din * d  # out_proj
            if mlp == "dense":
                total += 3 * d * self.d_ff  # gate, up, down (swiglu)
            elif mlp == "moe":
                m = self.moe
                total += d * m.n_experts  # router
                total += m.n_experts * 3 * d * self.d_ff
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(1 for k in self.mlp_kinds() if k == "moe")
        inactive = n_moe_layers * (m.n_experts - m.top_k) * 3 * self.d_model * self.d_ff
        return total - inactive

    def validate(self) -> None:
        assert self.d_model % 16 == 0, f"{self.name}: d_model must divide TP=16"
        assert self.vocab_padded % 256 == 0
        if self.layer_kinds().count("attn"):
            assert self.n_heads * self.head_dim_ >= 1
            assert self.n_heads % self.n_kv_heads == 0, "GQA group must be integral"
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.n_experts


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_loaded() -> None:
    # import the per-arch modules for their registration side effects
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (2L, d_model<=512, <=4e)."""
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2))
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(
            cfg.ssm, d_state=32, head_dim=32, chunk_size=32,
            attn_period=min(cfg.ssm.attn_period, n_layers) if cfg.ssm.attn_period else 0,
            attn_offset=min(cfg.ssm.attn_offset, n_layers - 1))
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv, head_dim=d_model // n_heads,
        d_ff=max(64, min(cfg.d_ff, 2 * d_model)), vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        moe=moe, ssm=ssm)
