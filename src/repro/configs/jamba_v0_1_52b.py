"""Config module for --arch selection (see archs.py for the definition)."""
from repro.configs.archs import JAMBA_52B as CONFIG  # noqa: F401
