"""The 10 assigned architectures + the paper's own ranker config.

Every entry cites its source (paper arXiv id / HF model card) and follows the
assigned hyperparameters exactly. Individual ``src/repro/configs/<id>.py``
modules re-export each config for ``--arch <id>`` selection.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

# ---------------------------------------------------------------- ssm
MAMBA2_780M = register(ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=1, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256,
                  attn_period=0),
    tie_embeddings=True,
    source="SSD / state-space duality [arXiv:2405.21060]",
))

# ---------------------------------------------------------------- moe
GRANITE_MOE_3B = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, rope_theta=10000.0,
    moe=MoEConfig(n_experts=40, top_k=8, period=1),
    source="granite 3.0 MoE [hf:ibm-granite/granite-3.0-1b-a400m-base]",
))

MIXTRAL_8X22B = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, rope_theta=1000000.0, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, period=1),
    source="Mixtral of Experts [arXiv:2401.04088]",
))

# ---------------------------------------------------------------- dense
LLAMA32_1B = register(ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, rope_theta=500000.0, tie_embeddings=True,
    source="small llama3 [hf:meta-llama/Llama-3.2-1B]",
))

CODEQWEN_7B = register(ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab_size=92416, rope_theta=1000000.0, qkv_bias=True,
    source="qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B]",
))

COMMAND_R_PLUS = register(ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab_size=256000, rope_theta=75000000.0,
    source="GQA no-bias [hf:CohereForAI/c4ai-command-r-v01]",
))

DEEPSEEK_67B = register(ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=102400, rope_theta=10000.0,
    source="llama-arch [arXiv:2401.02954]",
))

# ---------------------------------------------------------------- audio
MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, frontend="audio",
    source="decoder-only over EnCodec tokens [arXiv:2306.05284]; "
           "RoPE substituted for learned positions (DESIGN.md §7)",
))

# ---------------------------------------------------------------- vlm
LLAVA_NEXT_34B = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, rope_theta=5000000.0, frontend="vision",
    source="anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf]",
))

# ---------------------------------------------------------------- hybrid
JAMBA_52B = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536,
    # attn:mamba 1:7 interleave — one attention layer per 8, at offset 3
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256,
                  attn_period=8, attn_offset=3),
    # MoE every other layer, 16 experts top-2
    moe=MoEConfig(n_experts=16, top_k=2, period=2, offset=1),
    source="Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887]",
))

# ---------------------------------------------------------------- paper
# The paper's own production ranker is unspecified; we use a SASRec-class
# sequential ranker over the item vocabulary — small enough to train for a
# few hundred steps on CPU in examples/ and the A/B harness.
PAPER_RANKER = register(ModelConfig(
    name="itfi-ranker", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
    vocab_size=5120, rope_theta=10000.0, tie_embeddings=True,
    source="paper §III ranking model (SASRec-class sequential ranker)",
))

ASSIGNED = (
    "mamba2-780m", "granite-moe-3b-a800m", "llama3.2-1b", "mixtral-8x22b",
    "musicgen-large", "codeqwen1.5-7b", "command-r-plus-104b",
    "llava-next-34b", "jamba-v0.1-52b", "deepseek-67b",
)
