"""Batch feature store — the paper's "daily job" (§III-A).

Materializes per-user fixed-length watch-history features from the event
log on a fixed cadence (default: midnight). Between snapshots the features
are served *statically* — exactly the staleness the paper's injection
closes.

Features are model-ready padded arrays:

    items (U, K) int32   — watch history, right-aligned ascending time
    ts    (U, K) int32   — event timestamps (same layout)
    valid (U, K) int32   — 1 where a real event occupies the slot

``K = feature_len``. Snapshots are versioned by timestamp; the store
materializes the newest ``snapshot_retention`` generations (default 8 —
``None`` keeps all, the seed behavior) and recomputes older registered
generations from the log on demand, so time-travel reads keep working
without production-scale memory growth.

The event log is the columnar ``EventLog`` (core/event_log.py):
``run_snapshot`` and ``lookup_at_cutoff`` are single vectorized windowed
gathers — no Python-level per-user loop anywhere on the hot path. The
retired loop implementation lives in ``core/_reference.py`` and the two
are differentially tested to be bit-for-bit identical.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.event_log import EventLog

DAY = 86400


@dataclasses.dataclass(frozen=True)
class FeatureStoreConfig:
    n_users: int
    feature_len: int = 64
    snapshot_period: int = DAY      # "daily" job cadence
    snapshot_offset: int = 0        # job runs at midnight by default
    window: int = 30 * DAY          # history lookback of the daily job
    # keep at most this many materialized generations (None = keep all).
    # Each generation is (n_users, K)x3 int32, so unbounded retention is
    # a memory leak at production scale and a cold store's catch-up would
    # burst-materialize every boundary since the first event; evicted or
    # skipped generations stay registered and are recomputed from the log
    # on the (rare) time-travel read that still wants them. Caveat: a
    # recompute reads the log as of NOW, so events that arrived late (old
    # ts, appended after the generation ran) are included where the frozen
    # arrays would not have had them.
    snapshot_retention: Optional[int] = 8


class BatchFeatureStore:
    """Append-only event log + periodic snapshot materialization."""

    def __init__(self, cfg: FeatureStoreConfig):
        self.cfg = cfg
        self._log = EventLog(cfg.n_users)
        # snapshot_ts -> (items, ts, valid) arrays
        self._snapshots: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._snapshot_times: List[int] = []

    # ------------------------------------------------------------------
    # Ingest (the offline log collector — sees everything, eventually)
    # ------------------------------------------------------------------
    def append(self, user: int, item: int, ts: int) -> None:
        self._log.append(user, item, ts)

    def extend(self, users, items, ts) -> None:
        """Columnar bulk ingest (parallel arrays)."""
        self._log.extend(users, items, ts)

    def append_events(self, events) -> None:
        for ev in events:
            self._log.append(ev.user, ev.item, ev.ts)

    # ------------------------------------------------------------------
    # The daily job
    # ------------------------------------------------------------------
    def run_snapshot(self, snapshot_ts: int) -> None:
        """Materialize features from all events with ts < snapshot_ts."""
        c = self.cfg
        users = np.arange(c.n_users, dtype=np.int64)
        feats = self._log.materialize(
            users, snapshot_ts - c.window, snapshot_ts, c.feature_len)
        self._snapshots[snapshot_ts] = feats
        self._register_time(snapshot_ts)
        if c.snapshot_retention is not None:
            while len(self._snapshots) > c.snapshot_retention:
                self._snapshots.pop(min(self._snapshots))

    def _register_time(self, snapshot_ts: int) -> None:
        bisect.insort(self._snapshot_times, snapshot_ts)

    def maybe_run_due_snapshots(self, now: int) -> None:
        """Run every snapshot whose scheduled time has passed (idempotent).

        Catch-up is complete: after a gap of several periods, each missed
        boundary is materialized in order. With no prior snapshot, catch-up
        starts at the first period boundary after the earliest logged event
        (earlier snapshots would be all-zero; if the log is empty only the
        most recent boundary runs, registering an empty generation).
        With ``snapshot_retention`` set, boundaries that would be evicted
        immediately are registered without building their arrays.
        """
        c = self.cfg
        latest_due = ((now - c.snapshot_offset) // c.snapshot_period) \
            * c.snapshot_period + c.snapshot_offset
        if self._snapshot_times:
            start = self._snapshot_times[-1] + c.snapshot_period
        elif len(self._log):
            first = self._log.min_ts()
            start = ((first - c.snapshot_offset) // c.snapshot_period + 1) \
                * c.snapshot_period + c.snapshot_offset
        else:
            start = latest_due
        while start < 0:  # stay on the offset grid (defensive: ts >= 0)
            start += c.snapshot_period
        for due in range(start, latest_due + 1, c.snapshot_period):
            if c.snapshot_retention is not None and due <= latest_due \
                    - c.snapshot_retention * c.snapshot_period:
                self._register_time(due)
            else:
                self.run_snapshot(due)

    # ------------------------------------------------------------------
    # Serving reads
    # ------------------------------------------------------------------
    def latest_snapshot_ts(self, now: int) -> Optional[int]:
        i = bisect.bisect_right(self._snapshot_times, now) - 1
        return self._snapshot_times[i] if i >= 0 else None

    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch features as served at wall-time ``now`` (latest snapshot
        at or before now). Zero features if no snapshot exists yet."""
        snap = self.latest_snapshot_ts(now)
        k = self.cfg.feature_len
        if snap is None:
            z = np.zeros((len(users), k), np.int32)
            return z, z.copy(), z.copy()
        if snap not in self._snapshots:  # evicted generation: recompute
            return self.lookup_at_cutoff(users, snap)
        items, ts_arr, valid = self._snapshots[snap]
        return items[users], ts_arr[users], valid[users]

    def lookup_at_cutoff(self, users: np.ndarray, cutoff: int,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Features computed directly with an arbitrary cutoff (used by the
        training-data builder and the latency ablation — it emulates a
        feature pipeline whose refresh latency places the cutoff at
        ``cutoff`` rather than last midnight)."""
        c = self.cfg
        return self._log.materialize(
            np.asarray(users), cutoff - c.window, cutoff, c.feature_len)

    # ------------------------------------------------------------------
    def user_events(self, user: int) -> List[Tuple[int, int]]:
        return self._log.user_events(user)
