"""Batch feature store — the paper's "daily job" (§III-A).

Materializes per-user fixed-length watch-history features from the event
log on a fixed cadence (default: midnight). Between snapshots the features
are served *statically* — exactly the staleness the paper's injection
closes.

Features are model-ready padded arrays:

    items (U, K) int32   — watch history, right-aligned ascending time
    ts    (U, K) int32   — event timestamps (same layout)
    valid (U, K) int32   — 1 where a real event occupies the slot

``K = feature_len``. Snapshots are versioned by timestamp; the store
materializes the newest ``snapshot_retention`` generations (default 8 —
``None`` keeps all, the seed behavior) and recomputes older registered
generations from the log on demand, so time-travel reads keep working
without production-scale memory growth.

The event log is the columnar ``EventLog`` (core/event_log.py):
``run_snapshot`` and ``lookup_at_cutoff`` are single vectorized windowed
gathers — no Python-level per-user loop anywhere on the hot path. The
retired loop implementation lives in ``core/_reference.py`` and the two
are differentially tested to be bit-for-bit identical.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.event_log import EventLog

DAY = 86400

Features = Tuple[np.ndarray, np.ndarray, np.ndarray]  # items, ts, valid


def _row_diff(prev_feats: Features, new_feats: Features, users: np.ndarray,
              chunk: int = 65536) -> np.ndarray:
    """Rows among ``users`` whose (items, ts, valid) triples differ
    bitwise between two frozen feature planes. Chunked so the compare
    never allocates a population-scale temporary — the same exact-diff
    primitive the background builder runs off-thread and the synchronous
    certification path runs inside the rollover clock call."""
    pi, pt, pv = prev_feats
    ni, nt, nv = new_feats
    users = np.asarray(users, np.int64)
    diffs = []
    for s in range(0, len(users), chunk):
        h = users[s:s + chunk]
        d = ((ni[h] != pi[h]) | (nt[h] != pt[h])
             | (nv[h] != pv[h])).any(axis=1)
        diffs.append(h[d])
    return np.concatenate(diffs) if diffs else users


@dataclasses.dataclass(frozen=True)
class FeatureStoreConfig:
    n_users: int
    feature_len: int = 64
    snapshot_period: int = DAY      # "daily" job cadence
    snapshot_offset: int = 0        # job runs at midnight by default
    window: int = 30 * DAY          # history lookback of the daily job
    # keep at most this many materialized generations (None = keep all).
    # Each generation is (n_users, K)x3 int32, so unbounded retention is
    # a memory leak at production scale and a cold store's catch-up would
    # burst-materialize every boundary since the first event; evicted or
    # skipped generations stay registered and are recomputed from the log
    # on the (rare) time-travel read that still wants them. Caveat: a
    # recompute reads the log as of NOW, so events that arrived late (old
    # ts, appended after the generation ran) are included where the frozen
    # arrays would not have had them.
    snapshot_retention: Optional[int] = 8
    # EventLog tiering (None = legacy unbounded append-only log). With
    # ``log_window`` set the store's log becomes the tiered sliding-
    # window store: hot tail + per-window compacted segments + eviction
    # past ``log_window * log_retention_windows``. ``log_segment_k``
    # defaults to ``feature_len`` — the compaction keep-depth must be at
    # least the materialize depth for the bitwise-exactness contract
    # (docs/event_log.md). ``log_hot_budget`` caps hot-tail capacity in
    # events. Whoever owns the clock (the Gateway's tick) must drive
    # ``log.compact``.
    log_window: Optional[int] = None
    log_retention_windows: int = 8
    log_segment_k: Optional[int] = None
    log_hot_budget: Optional[int] = None


class BatchFeatureStore:
    """Append-only event log + periodic snapshot materialization."""

    def __init__(self, cfg: FeatureStoreConfig):
        self.cfg = cfg
        self._log = EventLog(
            cfg.n_users, window=cfg.log_window,
            retention_windows=cfg.log_retention_windows,
            segment_k=(cfg.log_segment_k if cfg.log_segment_k is not None
                       else cfg.feature_len),
            hot_budget=cfg.log_hot_budget)
        # snapshot_ts -> (items, ts, valid) arrays
        self._snapshots: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._snapshot_times: List[int] = []
        # log length when each frozen generation was installed — the
        # "appended since" anchor incremental builds use to catch
        # late-arriving events (old ts, appended after the build)
        self._snapshot_log_n: Dict[int, int] = {}
        # snapshot_ts -> (prev_snapshot_ts, exact changed-user array):
        # rows that are bitwise different from the previous frozen
        # generation. This is the warm-handoff authority (a cached
        # prefill state keyed to the previous generation is still valid
        # for every user NOT in this set). The array may be None —
        # "adjacent and frozen, diff not yet computed": a synchronous
        # full build defers the full-plane row compare to the first
        # changed_users_between call so a handoff-disabled deployment
        # never pays it (incremental builds compute it eagerly from the
        # delta hint, which is cheap).
        self._changed_vs_prev: Dict[int, Tuple[int, Optional[np.ndarray]]] = {}

    # ------------------------------------------------------------------
    # Ingest (the offline log collector — sees everything, eventually)
    # ------------------------------------------------------------------
    def append(self, user: int, item: int, ts: int) -> None:
        self._log.append(user, item, ts)

    def extend(self, users, items, ts) -> None:
        """Columnar bulk ingest (parallel arrays)."""
        self._log.extend(users, items, ts)

    def append_events(self, events) -> None:
        for ev in events:
            self._log.append(ev.user, ev.item, ev.ts)

    # ------------------------------------------------------------------
    # The daily job
    # ------------------------------------------------------------------
    def run_snapshot(self, snapshot_ts: int) -> None:
        """Materialize features from all events with ts < snapshot_ts.

        This is the full-build oracle: one monolithic materialization of
        every user. The incremental path (:class:`SnapshotBuilder`, via
        ``begin_snapshot``) produces bit-for-bit identical arrays while
        only recomputing the changed-user delta.
        """
        c = self.cfg
        users = np.arange(c.n_users, dtype=np.int64)
        feats = self._log.materialize(
            users, snapshot_ts - c.window, snapshot_ts, c.feature_len)
        self._install(snapshot_ts, feats)

    def begin_snapshot(self, snapshot_ts: int) -> "SnapshotBuilder":
        """Start an incremental build of the ``snapshot_ts`` generation.

        Returns a :class:`SnapshotBuilder` whose budget-bounded ``step()``
        the caller drives (e.g. ``Gateway.tick`` between panes); the
        generation registers only when the build completes, so serving
        keeps reading the previous generation with no stall."""
        return SnapshotBuilder(self, snapshot_ts)

    def begin_snapshot_background(
            self, snapshot_ts: int,
            step_hook: Optional[Callable[[], None]] = None,
            chunk: Optional[int] = None) -> "BackgroundSnapshotBuilder":
        """Start an off-thread build of the ``snapshot_ts`` generation.

        Returns a :class:`BackgroundSnapshotBuilder` whose worker thread
        does the copy-forward and delta materialization against a frozen
        ``EventLog.view()``; the caller drives ``poll()`` (O(1) while the
        worker runs) and the generation installs atomically on the
        *calling* thread once the worker finishes. Bit-for-bit equal to
        ``run_snapshot`` at install time, same as the synchronous
        builder. ``step_hook`` (tests) is invoked by the worker after
        every chunk; ``chunk`` overrides the worker chunk size."""
        return BackgroundSnapshotBuilder(self, snapshot_ts,
                                         step_hook=step_hook, chunk=chunk)

    def _install(self, snapshot_ts: int, feats: Features,
                 delta_hint: Optional[np.ndarray] = None,
                 changed_rows: Optional[np.ndarray] = None) -> None:
        """Register a fully-materialized generation: record the changed-
        row delta vs the previous frozen generation (the warm-handoff
        authority), stamp the log length, insert into the timeline, evict
        past retention.

        ``delta_hint`` (from an incremental build) restricts the row
        compare to the rows that were rematerialized — every other row is
        a copy-forward of the previous generation and bitwise equal by
        construction — and the diff is computed eagerly. Without a hint
        (synchronous full build) only an adjacency marker is recorded and
        the full-plane compare is deferred to the first
        ``changed_users_between`` call. ``changed_rows`` supersedes both:
        a caller-certified changed set (exact or a conservative superset
        — the ``changed_users_between`` contract allows extra members)
        recorded verbatim, used by the background builder which computes
        the row diff off-thread so install itself stays O(changed)."""
        if snapshot_ts in self._snapshot_times:
            # idempotent re-run (e.g. run_snapshot called twice): replace
            # arrays and drop every delta record the re-materialization
            # un-certifies — this generation's own record AND any
            # successor's record that named it as predecessor (the old
            # diff was computed against the arrays being replaced)
            self._snapshots[snapshot_ts] = feats
            self._snapshot_log_n[snapshot_ts] = self._log.n_events
            self._changed_vs_prev.pop(snapshot_ts, None)
            for ts, rec in list(self._changed_vs_prev.items()):
                if rec[0] == snapshot_ts:
                    self._changed_vs_prev.pop(ts)
            return
        prev = self.latest_snapshot_ts(snapshot_ts - 1)
        if prev is not None and prev in self._snapshots:
            if changed_rows is not None:
                changed = np.asarray(changed_rows, np.int64)
            elif delta_hint is None:
                # synchronous full build: defer the full-plane row
                # compare to the first changed_users_between call (it is
                # ~0.75 GB of traversal at 1M users — the legacy
                # boundary stall must not grow for deployments that
                # never read the record)
                changed = None
            else:
                changed = _row_diff(self._snapshots[prev], feats,
                                    delta_hint)
            self._changed_vs_prev[snapshot_ts] = (prev, changed)
        self._snapshots[snapshot_ts] = feats
        self._snapshot_log_n[snapshot_ts] = self._log.n_events
        self._register_time(snapshot_ts)
        if self.cfg.snapshot_retention is not None:
            while len(self._snapshots) > self.cfg.snapshot_retention:
                evicted = min(self._snapshots)
                self._snapshots.pop(evicted)
                self._snapshot_log_n.pop(evicted, None)
                self._changed_vs_prev.pop(evicted, None)

    def changed_users_between(self, gen_a: int, gen_b: int,
                              ) -> Optional[np.ndarray]:
        """The exact set of users whose feature rows differ bitwise
        between generations ``gen_a`` and ``gen_b``, or ``None`` when no
        such set can be certified. A user absent from the returned set
        has bitwise-identical rows at both generations — the property
        the warm handoff's rekey rests on. (The contract tolerates
        supersets — extra members only cost unnecessary invalidations —
        but every certification path now row-diffs down to the exact
        set, including the synchronous-build path, which used to hand
        back the raw log-scan superset.)

        Certification requires (1) a recorded adjacency: ``gen_b`` was
        installed with ``gen_a`` as its immediate predecessor (a
        multi-generation gap returns ``None`` — compose it yourself if
        you must), and (2) **both generations still frozen**: an evicted
        generation recomputes from the log *as of now* on lookup, so
        state derived from it after eviction (e.g. a prefill cached
        during a legacy clock rewind) is not necessarily a function of
        the frozen rows the record compared — the warm handoff must not
        rekey across it."""
        rec = self._changed_vs_prev.get(gen_b)
        if rec is None or rec[0] != gen_a:
            return None
        if gen_a not in self._snapshots or gen_b not in self._snapshots:
            return None
        if rec[1] is None:
            # synchronous build: no exact delta was recorded. Scan the
            # log for the conservative superset (entering / aging-out /
            # appended-since-gen_a's-build — the same criterion the
            # incremental builder's copy-forward proof rests on), then
            # row-diff just those rows between the two frozen planes —
            # the background worker's exact-diff primitive. One columnar
            # pass plus an O(superset) compare, still far cheaper than a
            # full-plane compare, and the result is EXACT: a sync
            # rollover invalidates no more users than an incremental one
            if gen_a not in self._snapshot_log_n:
                return None
            superset = self._log.changed_users(
                gen_a, gen_b, self.cfg.window,
                since=self._snapshot_log_n[gen_a])
            changed = _row_diff(self._snapshots[gen_a],
                                self._snapshots[gen_b], superset)
            self._changed_vs_prev[gen_b] = (gen_a, changed)
            return changed
        return rec[1]

    def _register_time(self, snapshot_ts: int) -> None:
        bisect.insort(self._snapshot_times, snapshot_ts)

    def latest_due_boundary(self, now: int) -> int:
        """The newest snapshot boundary at or before ``now`` on the
        period/offset grid — the generation a fully caught-up store
        serves at ``now``."""
        c = self.cfg
        return ((now - c.snapshot_offset) // c.snapshot_period) \
            * c.snapshot_period + c.snapshot_offset

    def maybe_run_due_snapshots(self, now: int) -> None:
        """Run every snapshot whose scheduled time has passed (idempotent).

        Catch-up is complete: after a gap of several periods, each missed
        boundary is materialized in order. With no prior snapshot, catch-up
        starts at the first period boundary after the earliest logged event
        (earlier snapshots would be all-zero; if the log is empty only the
        most recent boundary runs, registering an empty generation).
        With ``snapshot_retention`` set, boundaries that would be evicted
        immediately are registered without building their arrays.
        """
        c = self.cfg
        latest_due = self.latest_due_boundary(now)
        if self._snapshot_times:
            start = self._snapshot_times[-1] + c.snapshot_period
        elif len(self._log):
            first = self._log.min_ts()
            start = ((first - c.snapshot_offset) // c.snapshot_period + 1) \
                * c.snapshot_period + c.snapshot_offset
        else:
            start = latest_due
        while start < 0:  # stay on the offset grid (defensive: ts >= 0)
            start += c.snapshot_period
        for due in range(start, latest_due + 1, c.snapshot_period):
            if c.snapshot_retention is not None and due <= latest_due \
                    - c.snapshot_retention * c.snapshot_period:
                self._register_time(due)
            else:
                self.run_snapshot(due)

    @property
    def log(self) -> EventLog:
        """The underlying append-only event log. Exposed read-only by
        convention: external consumers (the online trainer) take
        lock-free frozen ``view()`` captures; all writes still go
        through the store's ingest methods."""
        return self._log

    # ------------------------------------------------------------------
    # Serving reads
    # ------------------------------------------------------------------
    def latest_snapshot_ts(self, now: int) -> Optional[int]:
        i = bisect.bisect_right(self._snapshot_times, now) - 1
        return self._snapshot_times[i] if i >= 0 else None

    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch features as served at wall-time ``now`` (latest snapshot
        at or before now). Zero features if no snapshot exists yet."""
        snap = self.latest_snapshot_ts(now)
        k = self.cfg.feature_len
        if snap is None:
            z = np.zeros((len(users), k), np.int32)
            return z, z.copy(), z.copy()
        if snap not in self._snapshots:  # evicted generation: recompute
            return self.lookup_at_cutoff(users, snap)
        items, ts_arr, valid = self._snapshots[snap]
        return items[users], ts_arr[users], valid[users]

    def snapshot_rows(self, gen: int, users: np.ndarray,
                      ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]]:
        """Feature rows of a specific **frozen** generation, or ``None``
        when ``gen`` is not materialized (evicted generations recompute
        from the live log, which is exactly what the delta-re-warm
        prefix check must not trust). Rows come straight out of the
        frozen arrays, so they are bitwise what serving read at that
        generation."""
        if gen not in self._snapshots:
            return None
        items, ts_arr, valid = self._snapshots[gen]
        return items[users], ts_arr[users], valid[users]

    def lookup_at_cutoff(self, users: np.ndarray, cutoff: int,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Features computed directly with an arbitrary cutoff (used by the
        training-data builder and the latency ablation — it emulates a
        feature pipeline whose refresh latency places the cutoff at
        ``cutoff`` rather than last midnight)."""
        c = self.cfg
        return self._log.materialize(
            np.asarray(users), cutoff - c.window, cutoff, c.feature_len)

    # ------------------------------------------------------------------
    def user_events(self, user: int) -> List[Tuple[int, int]]:
        return self._log.user_events(user)


# ----------------------------------------------------------------------
# Incremental snapshot builds
# ----------------------------------------------------------------------

class SnapshotBuilder:
    """Amortized, delta-only materialization of one snapshot generation.

    ``run_snapshot`` re-materializes the full ``(n_users, feature_len)``
    plane in one synchronous call (~1-3 s at 1M users on the benchmark
    host) — a stall the serving loop cannot hide when the daily boundary
    falls inside a ``submit``/``tick``. The builder splits that work:

    * **delta only** — the changed-user set between the previous frozen
      generation and ``snapshot_ts`` (``EventLog.changed_users``: events
      entering ``[prev, ts)``, events aging out of the lookback window,
      late arrivals appended since the previous build) is rematerialized;
      every other row is **copy-forwarded** from the previous
      generation's frozen arrays.
    * **budget-bounded** — ``step(budget)`` advances the build by at
      most ``budget`` rows per call (copy-forward slabs first, then
      delta materializations) and returns the remaining count, so a
      caller (``Gateway.tick``) can interleave build slices between
      serving panes. Even the copy-forward is chunked: the previous
      generation is ~0.75 GB at 1M users, a creation-time stall if
      copied monolithically.
    * **bit-for-bit** — the finished arrays are identical to what
      ``run_snapshot(snapshot_ts)`` would produce *at completion time*:
      a finish-time fixup rematerializes any user whose in-window events
      were appended mid-build, and the copy-forward rows are provably
      equal (a non-changed user's window event set is identical at both
      cutoffs). Differentially tested in tests/test_rollover.py,
      including the aging-out and mid-build-append cases.

    The generation registers (and serving's ``generation(now)`` rolls)
    only when the last step installs the arrays — until then every read
    keeps serving the previous generation, which is exactly the paper's
    "served statically throughout the day" semantics extended to the
    build window. Falls back to a full build (delta = every user) when
    there is no previous frozen generation to delta against.
    """

    def __init__(self, store: BatchFeatureStore, snapshot_ts: int):
        if snapshot_ts in store._snapshot_times:
            raise ValueError(
                f"generation {snapshot_ts} is already registered")
        self.store = store
        self.snapshot_ts = int(snapshot_ts)
        c = store.cfg
        self._n0 = store._log.n_events  # log length at build start
        prev = store.latest_snapshot_ts(snapshot_ts - 1)
        self.prev = prev
        self.full_build = (prev is None or prev not in store._snapshots
                           or prev not in store._snapshot_log_n)
        shape = (c.n_users, c.feature_len)
        if self.full_build:
            self._todo = np.arange(c.n_users, dtype=np.int64)
            self._items = np.zeros(shape, np.int32)
            self._ts = np.zeros(shape, np.int32)
            self._valid = np.zeros(shape, np.int32)
            self._copy_n = 0          # nothing to copy-forward
        else:
            self._todo = store._log.changed_users(
                prev, snapshot_ts, c.window,
                since=store._snapshot_log_n[prev])
            # copy-forward happens CHUNKED inside step(), not here: at
            # 1M users the previous generation is ~0.75 GB of arrays,
            # and one monolithic .copy() would be a creation-time stall
            # as bad as the build this class exists to amortize
            self._items = np.empty(shape, np.int32)
            self._ts = np.empty(shape, np.int32)
            self._valid = np.empty(shape, np.int32)
            self._copy_n = c.n_users  # rows to copy-forward (all rows;
            #                           delta fills overwrite changed)
        self._copy_pos = 0
        self._pos = 0
        self.done = False
        self.steps = 0
        self.step_time_s = 0.0
        self.late_fixups = 0

    # ------------------------------------------------------------------
    @property
    def n_changed(self) -> int:
        """Users this build rematerializes (== n_users for a full build)."""
        return len(self._todo)

    @property
    def remaining(self) -> int:
        """Rows of work left: copy-forward rows + delta users."""
        if self.done:
            return 0
        return (self._copy_n - self._copy_pos) + (len(self._todo)
                                                  - self._pos)

    # ------------------------------------------------------------------
    def _fill(self, users: np.ndarray) -> None:
        c = self.store.cfg
        it, t, v = self.store._log.materialize(
            users, self.snapshot_ts - c.window, self.snapshot_ts,
            c.feature_len)
        self._items[users] = it
        self._ts[users] = t
        self._valid[users] = v

    def step(self, budget: int) -> int:
        """One budget-bounded slice of the build: first copy-forward up
        to ``budget`` contiguous rows from the previous generation, then
        (once the copy is done) materialize up to ``budget`` changed
        users per call; install the generation when both phases are
        exhausted. Returns the rows of work remaining (0 once
        installed)."""
        if self.done:
            return 0
        t0 = time.perf_counter()
        budget = max(int(budget), 1)
        if self._copy_pos < self._copy_n:
            a = self._copy_pos
            b = min(a + budget, self._copy_n)
            pi, pt, pv = self.store._snapshots[self.prev]
            self._items[a:b] = pi[a:b]
            self._ts[a:b] = pt[a:b]
            self._valid[a:b] = pv[a:b]
            self._copy_pos = b
        else:
            chunk = self._todo[self._pos:self._pos + budget]
            if len(chunk):
                self._fill(chunk)
                self._pos += len(chunk)
        if self._copy_pos >= self._copy_n and self._pos >= len(self._todo):
            self._finish()
        self.steps += 1
        self.step_time_s += time.perf_counter() - t0
        return self.remaining

    def run(self) -> None:
        """Drain the whole build in one call (the synchronous oracle
        path, minus the delta savings)."""
        while not self.done:
            self.step(max(self.remaining, 1))

    def _finish(self) -> None:
        c = self.store.cfg
        # fixup: users whose in-window events were appended while the
        # build was in flight (any ts inside the new window — including
        # late arrivals with old timestamps) — rematerialize them so the
        # installed arrays equal run_snapshot() as of *now*
        late = self.store._log.users_with_events(
            self.snapshot_ts - c.window, self.snapshot_ts, start=self._n0)
        if len(late):
            self._fill(late)
            self.late_fixups = len(late)
        hint = None if self.full_build else np.union1d(self._todo, late)
        self.store._install(self.snapshot_ts,
                            (self._items, self._ts, self._valid),
                            delta_hint=hint)
        self.done = True


class BackgroundSnapshotBuilder:
    """Off-thread incremental build with an atomic on-thread install.

    The synchronous :class:`SnapshotBuilder` amortizes the build into
    budget-bounded ``step()`` slices, but every slice still runs *on the
    serving thread*: heavy traffic starves the build and the worst slice
    (59 ms at 1M users in BENCH_rollover.json) stalls whichever clock
    call pays it. This class moves the whole build onto a dedicated
    daemon thread and shrinks the serving thread's involvement to O(1)
    ``poll()`` calls plus one O(changed) finalize:

    * **double-buffered feature plane** — the worker owns a private
      ``(n_users, feature_len)×3`` buffer (the same copy-forward layout
      as the synchronous builder; at 1M users that is ~0.75 GB held
      *alongside* the live generation for the build's duration — the
      memory cost of backgrounding). Serving keeps reading the previous
      generation's arrays untouched until install.
    * **narrow-lock delta reads** — the worker never touches the owning
      log's mutable indexes: it captures an immutable
      ``EventLog.view()`` (O(1), taken under the log's write lock) and
      computes the changed-user set, chunked copy-forward, and delta
      fills against that frozen prefix. NumPy releases the GIL for the
      bulk array work, so the copy genuinely overlaps serving.
    * **install handshake** — the worker only builds; it never installs.
      All log *writes* and the finalize live on the calling (serving)
      thread: ``poll()`` notices the worker finished, rematerializes
      users whose in-window events were appended mid-build (the same
      finish-time fixup as the synchronous builder, against the full
      live log — exact because appends are single-threaded on the
      caller's side), and registers the generation via the store's
      single atomic ``_install`` point. Until that moment
      ``generation(now)`` keeps returning the previous generation.
    * **pre-certified handoff delta** — the worker also row-diffs its
      rematerialized rows against the previous generation off-thread, so
      install passes an exact-∪-late ``changed_rows`` set and the
      serving thread never pays the diff (or the deferred log-scan) that
      would otherwise ride the rollover clock call.

    Worker exceptions are sticky: re-raised from ``poll()``/``join()``.
    ``step_hook`` (tests only) runs on the worker after every chunk —
    a barrier there gives deterministic interleaving.
    """

    CHUNK = 65536  # worker chunk: bounds each slice of copy/fill work

    def __init__(self, store: BatchFeatureStore, snapshot_ts: int,
                 step_hook: Optional[Callable[[], None]] = None,
                 chunk: Optional[int] = None):
        if snapshot_ts in store._snapshot_times:
            raise ValueError(
                f"generation {snapshot_ts} is already registered")
        self.store = store
        self.snapshot_ts = int(snapshot_ts)
        self._chunk = max(int(chunk), 1) if chunk else self.CHUNK
        self._step_hook = step_hook
        c = store.cfg
        # captured on the calling thread so the worker never reads the
        # store's mutable dicts: log anchor, predecessor arrays, since
        self._n0 = store._log.n_events
        prev = store.latest_snapshot_ts(snapshot_ts - 1)
        self.prev = prev
        self.full_build = (prev is None or prev not in store._snapshots
                           or prev not in store._snapshot_log_n)
        self._prev_feats = (None if self.full_build
                            else store._snapshots[prev])
        self._since = (0 if self.full_build
                       else store._snapshot_log_n[prev])
        shape = (c.n_users, c.feature_len)
        alloc = np.zeros if self.full_build else np.empty
        self._items = alloc(shape, np.int32)
        self._ts = alloc(shape, np.int32)
        self._valid = alloc(shape, np.int32)
        # worker progress (plain ints/arrays: GIL-atomic rebinds; read
        # cross-thread only as a progress estimate)
        self._todo: Optional[np.ndarray] = None
        self._changed_exact: Optional[np.ndarray] = None
        self._copy_n = 0 if self.full_build else c.n_users
        self._copy_pos = 0
        self._pos = 0
        self.done = False
        self.steps = 0                 # worker chunks processed
        self.step_time_s = 0.0         # worker busy time + finalize
        self.late_fixups = 0
        self._error: Optional[BaseException] = None
        self._built = threading.Event()
        self._thread = threading.Thread(
            target=self._work, name=f"snapshot-build-{snapshot_ts}",
            daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    @property
    def n_changed(self) -> int:
        """Users the build rematerializes (estimate 0 until the worker's
        delta scan lands; exact afterwards)."""
        todo = self._todo
        return len(todo) if todo is not None else 0

    @property
    def remaining(self) -> int:
        """Rows of build work left (progress estimate while the worker
        runs; 0 only once the generation is installed)."""
        if self.done:
            return 0
        todo = self._todo
        todo_left = (len(todo) - self._pos if todo is not None
                     else self.store.cfg.n_users)
        return max((self._copy_n - self._copy_pos) + todo_left, 1)

    # ------------------------------------------------------------------
    # worker side: build only — never writes the log, never installs
    # ------------------------------------------------------------------
    def _work(self) -> None:
        try:
            t0 = time.perf_counter()
            view = self.store._log.view()
            c = self.store.cfg
            lo = self.snapshot_ts - c.window
            if self.full_build:
                todo = np.arange(c.n_users, dtype=np.int64)
            else:
                todo = view.changed_users(self.prev, self.snapshot_ts,
                                          c.window, since=self._since)
            self._todo = todo
            self._tick(t0)
            # chunked copy-forward of the previous generation
            while self._copy_pos < self._copy_n:
                t0 = time.perf_counter()
                a = self._copy_pos
                b = min(a + self._chunk, self._copy_n)
                pi, pt, pv = self._prev_feats
                self._items[a:b] = pi[a:b]
                self._ts[a:b] = pt[a:b]
                self._valid[a:b] = pv[a:b]
                self._copy_pos = b
                self._tick(t0)
            # chunked delta fills against the frozen view
            while self._pos < len(todo):
                t0 = time.perf_counter()
                chunk = todo[self._pos:self._pos + self._chunk]
                it, t, v = view.materialize(chunk, lo, self.snapshot_ts,
                                            c.feature_len)
                self._items[chunk] = it
                self._ts[chunk] = t
                self._valid[chunk] = v
                self._pos += len(chunk)
                self._tick(t0)
            # pre-certify the handoff delta: row-diff the rematerialized
            # rows against the previous generation, off-thread
            if not self.full_build and len(todo):
                t0 = time.perf_counter()
                self._changed_exact = _row_diff(
                    self._prev_feats, (self._items, self._ts, self._valid),
                    todo, chunk=self._chunk)
                self._tick(t0)
            elif not self.full_build:
                self._changed_exact = todo
        except BaseException as e:  # sticky: re-raised from poll/join
            self._error = e
        finally:
            self._built.set()

    def _tick(self, t0: float) -> None:
        self.step_time_s += time.perf_counter() - t0
        self.steps += 1
        if self._step_hook is not None:
            self._step_hook()

    # ------------------------------------------------------------------
    # caller side: O(1) poll, O(changed) finalize, atomic install
    # ------------------------------------------------------------------
    def poll(self) -> int:
        """Non-blocking advance: returns remaining work (>0 while the
        worker runs). When the worker has finished, runs the finish-time
        fixup and installs the generation — after which ``done`` is True
        and 0 is returned. Re-raises a worker exception, stickily."""
        if self.done:
            return 0
        if self._error is not None:
            raise RuntimeError(
                f"background build of generation {self.snapshot_ts} "
                f"failed") from self._error
        if not self._built.is_set():
            return self.remaining
        self._finalize()
        return 0

    def join(self, timeout: Optional[float] = None) -> int:
        """Block until the worker finishes (or ``timeout`` elapses),
        then finalize+install on this thread. Returns remaining work
        (0 once installed)."""
        self._built.wait(timeout)
        return self.poll()

    def _finalize(self) -> None:
        t0 = time.perf_counter()
        c = self.store.cfg
        # finish-time fixup, same contract as SnapshotBuilder._finish:
        # any user whose in-window events were appended after build
        # start is rematerialized from the LIVE log — exact, because
        # appends only happen on this thread
        late = self.store._log.users_with_events(
            self.snapshot_ts - c.window, self.snapshot_ts, start=self._n0)
        if len(late):
            it, t, v = self.store._log.materialize(
                late, self.snapshot_ts - c.window, self.snapshot_ts,
                c.feature_len)
            self._items[late] = it
            self._ts[late] = t
            self._valid[late] = v
            self.late_fixups = len(late)
        changed = (None if self.full_build
                   else np.union1d(self._changed_exact, late))
        self.store._install(self.snapshot_ts,
                            (self._items, self._ts, self._valid),
                            changed_rows=changed)
        self.done = True
        self.step_time_s += time.perf_counter() - t0
