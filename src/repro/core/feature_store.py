"""Batch feature store — the paper's "daily job" (§III-A).

Materializes per-user fixed-length watch-history features from the event log
on a fixed cadence (default: midnight). Between snapshots the features are
served *statically* — exactly the staleness the paper's injection closes.

Features are model-ready padded arrays:

    items (U, K) int32   — watch history, right-aligned ascending time
    ts    (U, K) int32   — event timestamps (same layout)
    valid (U, K) int32   — 1 where a real event occupies the slot

``K = feature_len``. The store keeps every snapshot it has produced
(versioned by snapshot timestamp) so the latency ablation can serve
arbitrarily stale feature generations.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

DAY = 86400


@dataclasses.dataclass(frozen=True)
class FeatureStoreConfig:
    n_users: int
    feature_len: int = 64
    snapshot_period: int = DAY      # "daily" job cadence
    snapshot_offset: int = 0        # job runs at midnight by default
    window: int = 30 * DAY          # history lookback of the daily job


class BatchFeatureStore:
    """Append-only event log + periodic snapshot materialization."""

    def __init__(self, cfg: FeatureStoreConfig):
        self.cfg = cfg
        # per-user chronological event log: lists of (ts, item)
        self._log: List[List[Tuple[int, int]]] = [[] for _ in range(cfg.n_users)]
        # snapshot_ts -> (items, ts, valid) arrays
        self._snapshots: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._snapshot_times: List[int] = []

    # ------------------------------------------------------------------
    # Ingest (the offline log collector — sees everything, eventually)
    # ------------------------------------------------------------------
    def append(self, user: int, item: int, ts: int) -> None:
        self._log[user].append((ts, item))

    def append_events(self, events) -> None:
        for ev in events:
            self.append(ev.user, ev.item, ev.ts)

    # ------------------------------------------------------------------
    # The daily job
    # ------------------------------------------------------------------
    def run_snapshot(self, snapshot_ts: int) -> None:
        """Materialize features from all events with ts < snapshot_ts."""
        c = self.cfg
        k = c.feature_len
        items = np.zeros((c.n_users, k), np.int32)
        ts_arr = np.zeros((c.n_users, k), np.int32)
        valid = np.zeros((c.n_users, k), np.int32)
        lo = snapshot_ts - c.window
        for u in range(c.n_users):
            evs = [e for e in self._log[u] if lo <= e[0] < snapshot_ts]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[u, k - n:] = [e[1] for e in evs]
                ts_arr[u, k - n:] = [e[0] for e in evs]
                valid[u, k - n:] = 1
        self._snapshots[snapshot_ts] = (items, ts_arr, valid)
        bisect.insort(self._snapshot_times, snapshot_ts)

    def maybe_run_due_snapshots(self, now: int) -> None:
        """Run any snapshot whose scheduled time has passed (idempotent)."""
        c = self.cfg
        t = ((now - c.snapshot_offset) // c.snapshot_period) * c.snapshot_period \
            + c.snapshot_offset
        while t > (self._snapshot_times[-1] if self._snapshot_times else -1):
            due = (self._snapshot_times[-1] + c.snapshot_period
                   if self._snapshot_times else t)
            if due > now:
                break
            self.run_snapshot(due)

    # ------------------------------------------------------------------
    # Serving reads
    # ------------------------------------------------------------------
    def latest_snapshot_ts(self, now: int) -> Optional[int]:
        i = bisect.bisect_right(self._snapshot_times, now) - 1
        return self._snapshot_times[i] if i >= 0 else None

    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch features as served at wall-time ``now`` (latest snapshot
        at or before now). Zero features if no snapshot exists yet."""
        snap = self.latest_snapshot_ts(now)
        k = self.cfg.feature_len
        if snap is None:
            z = np.zeros((len(users), k), np.int32)
            return z, z.copy(), z.copy()
        items, ts_arr, valid = self._snapshots[snap]
        return items[users], ts_arr[users], valid[users]

    def lookup_at_cutoff(self, users: np.ndarray, cutoff: int,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Features computed directly with an arbitrary cutoff (used by the
        training-data builder and the latency ablation — it emulates a
        feature pipeline whose refresh latency places the cutoff at
        ``cutoff`` rather than last midnight)."""
        c = self.cfg
        k = c.feature_len
        items = np.zeros((len(users), k), np.int32)
        ts_arr = np.zeros((len(users), k), np.int32)
        valid = np.zeros((len(users), k), np.int32)
        lo = cutoff - c.window
        for j, u in enumerate(users):
            evs = [e for e in self._log[u] if lo <= e[0] < cutoff]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[j, k - n:] = [e[1] for e in evs]
                ts_arr[j, k - n:] = [e[0] for e in evs]
                valid[j, k - n:] = 1
        return items, ts_arr, valid

    # ------------------------------------------------------------------
    def user_events(self, user: int) -> List[Tuple[int, int]]:
        return sorted(self._log[user])
