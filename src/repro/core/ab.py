"""A/B experiment harness — reproduces the paper's §IV result structure.

Phases (DESIGN.md §1):

  0. **bootstrap** — a popularity policy serves for ``bootstrap_days``,
     producing generation-0 logs (no model yet).
  1. **generation 1** — a ranker trained on gen-0 logs (batch cutoff) is
     deployed with *batch* features for ``gen1_days``. Its logs carry the
     feedback loop: watches are drawn from this model's slates.
  2. **generation 2** — two rankers are trained on the full log:
       * M_batch  — midnight cutoff (the paper's untouched batch model),
       * M_cons   — fresh cutoff with the explicit recent-segment features
         (the paper's "consistent" variant).
  3. **the experiment** — paired arms over ``ab_days`` with common random
     numbers (identical session schedules, intent drift and choice noise;
     only the slates differ):
       * control    — M_batch + batch features (24 h refresh)
       * treatment  — M_batch + inference-time injection   ← the paper
       * consistent — M_cons  + train/serve-consistent fresh features
     plus optional latency-ablation arms (feature staleness λ).

Reproduction targets (§IV): treatment lift significant & positive;
consistent ≈ control (no measurable gain). Magnitudes are sim-specific.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
from repro.core.injection import FeatureInjector, InjectionConfig
from repro.core.metrics import paired_user_test, summarize_arm, two_proportion_z
from repro.core.pipeline import PipelineConfig, RecommenderPlatform
from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
from repro.data.loader import LoaderConfig, batches, build_examples
from repro.data.synthetic import (World, WorldConfig, bootstrap_serve_fn,
                                  events_to_arrays, simulate_day)
from repro.models.model import init_params
from repro.serving.api import Request, hash_arm
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import TrainConfig, train

DAY = 86400

# The paper's §IV arms that share one set of model parameters (M_batch)
# and differ only in the serving-time feature policy — exactly the pair
# a request-level deployment serves from ONE fleet via per-request
# policies (mixed-policy panes) instead of one server per arm.
ARM_POLICIES = {"control": "batch", "treatment": "inject"}

# Extended experiment including the model-free recency baseline (policy
# "decay", Interest Clock arXiv 2404.19357). A separate mapping — NOT a
# mutation of ARM_POLICIES — because hash_arm buckets users by the arm
# tuple: the default two-arm assignment must stay stable across PRs.
DECAY_ARM_POLICIES = {"control": "batch", "treatment": "inject",
                      "decay": "decay"}


def request_arm(user: int, salt: int = 0,
                arms: Optional[Dict[str, str]] = None) -> str:
    """Deterministic per-request arm assignment (user-randomized, as in
    the paper; stable across processes via :func:`hash_arm`). ``arms``
    selects an alternative arm->policy mapping (e.g.
    :data:`DECAY_ARM_POLICIES`); different mappings are different
    experiments and bucket users independently."""
    return hash_arm(int(user), tuple(arms or ARM_POLICIES), salt)


def arm_requests(users, now: int, salt: int = 0,
                 arms: Optional[Dict[str, str]] = None) -> List[Request]:
    """Label a wave of arrivals with their experiment arm: each request
    carries its arm's serving policy and the arm name as ``tag``, ready
    for ``Gateway.submit_many`` — control and treatment rows then
    coexist in the same fixed-shape panes, and the per-arm split is
    recovered from ``response.telemetry.tag``."""
    arms = arms or ARM_POLICIES
    out = []
    for u in np.asarray(users).ravel():
        arm = request_arm(int(u), salt, arms)
        out.append(Request(user=int(u), now=int(now),
                           policy=arms[arm], tag=arm))
    return out


def default_sim_model(n_items: int) -> ModelConfig:
    """CPU-budget ranker for the simulation (the registered ``itfi-ranker``
    config is the production-shaped version used by examples/dry-run)."""
    return ModelConfig(
        name="itfi-ranker-sim", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=n_items + 256,
        rope_theta=10000.0, tie_embeddings=True,
        source="paper §III ranking model, simulation-scale")


@dataclasses.dataclass(frozen=True)
class ABConfig:
    world: WorldConfig = WorldConfig(n_users=800, n_items=4000,
                                     sessions_per_day=2.0)
    bootstrap_days: int = 4
    gen1_days: int = 4
    ab_days: int = 6
    feature_len: int = 48
    rt_buffer_len: int = 16
    rt_ingest_latency: int = 30
    # training
    train_epochs: int = 2
    train_batch: int = 128
    max_examples: int = 30000
    lr: float = 1e-3
    seed: int = 0
    # extra arms: feature staleness in seconds for the latency ablation
    latency_arms: Sequence[int] = ()


@dataclasses.dataclass
class ArmResult:
    name: str
    day_metrics: List[Dict]
    user_impressions: np.ndarray
    user_watches: np.ndarray

    @property
    def ctr(self) -> float:
        imp = sum(m["impressions"] for m in self.day_metrics)
        w = sum(m["slate_watches"] for m in self.day_metrics)
        return w / max(imp, 1)


# ----------------------------------------------------------------------
# Training
# ----------------------------------------------------------------------

def train_ranker(events, model_cfg: ModelConfig, ab: ABConfig, cutoff: str,
                 log=print) -> Dict:
    lcfg = LoaderConfig(n_items=ab.world.n_items, feature_len=ab.feature_len,
                        seed=ab.seed)
    ex = build_examples(events_to_arrays(events), lcfg, cutoff)
    n = len(ex["labels"])
    if n > ab.max_examples:
        keep = np.random.RandomState(ab.seed).choice(n, ab.max_examples, False)
        ex = {k: v[keep] for k, v in ex.items()}
    if log:
        log(f"[train:{cutoff}] {len(ex['labels'])} examples")
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=ab.lr, warmup_steps=50,
                          total_steps=ab.train_epochs * max(n, 1) // ab.train_batch,
                          weight_decay=0.01),
        remat=False, q_chunk=ab.feature_len)
    params = init_params(model_cfg, jax.random.PRNGKey(ab.seed),
                         dtype=jnp.float32)
    opt = init_opt_state(params)
    out = train(model_cfg, tcfg, params, opt,
                batches(ex, ab.train_batch, ab.train_epochs, ab.seed),
                log_every=100, log=log)
    return out["params"]


# ----------------------------------------------------------------------
# Platform assembly
# ----------------------------------------------------------------------

def make_platform(ab: ABConfig, model_cfg: ModelConfig, params, world: World,
                  history_events, *, policy: str, mode: str = "plain",
                  staleness: Optional[int] = None, merge_impl: str = "xla",
                  ) -> RecommenderPlatform:
    w = ab.world
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=w.n_users, feature_len=ab.feature_len))
    cols = events_to_arrays(history_events)
    store.extend(cols["user"], cols["item"], cols["ts"])
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=w.n_users, buffer_len=ab.rt_buffer_len,
        ingest_latency=ab.rt_ingest_latency))
    # warm the realtime buffers with the trailing history (bounded retention
    # makes anything older invisible anyway)
    rts.extend(cols["user"], cols["item"], cols["ts"])
    inj = FeatureInjector(
        InjectionConfig(policy=policy, feature_len=ab.feature_len,
                        merge_impl=merge_impl, staleness=staleness),
        store, rts)
    pcfg = PipelineConfig(n_items=w.n_items, slate_size=w.slate_size,
                          serve_batch=256)
    return RecommenderPlatform(pcfg, model_cfg, params, inj,
                               world.popularity, mode=mode)


def run_arm(name: str, ab: ABConfig, platform: RecommenderPlatform,
            world: World, day_range, log=print) -> ArmResult:
    w = ab.world
    ui = np.zeros(w.n_users, np.int64)
    uw = np.zeros(w.n_users, np.int64)
    dm = []
    for day in day_range:
        t0 = time.time()
        _, m = simulate_day(world, day, platform.serve, platform.observe,
                            seed=ab.seed, serve_batch=platform.pcfg.serve_batch)
        ui += m.pop("user_impressions")
        uw += m.pop("user_watches")
        dm.append(m)
        if log:
            log(f"[{name}] day {day}: ctr={m['ctr']:.4f} "
                f"imp={m['impressions']} ({time.time() - t0:.1f}s)")
    return ArmResult(name, dm, ui, uw)


# ----------------------------------------------------------------------
# The full experiment
# ----------------------------------------------------------------------

def run_experiment(ab: ABConfig, *, model_cfg: Optional[ModelConfig] = None,
                   merge_impl: str = "xla", log=print) -> Dict:
    model_cfg = model_cfg or default_sim_model(ab.world.n_items)
    world = World(ab.world)
    all_events = []

    # ---- phase 0: bootstrap logs -------------------------------------
    serve0 = bootstrap_serve_fn(world, ab.seed)
    for day in range(ab.bootstrap_days):
        evs, m = simulate_day(world, day, serve0, lambda e: None, seed=ab.seed)
        all_events += evs
        if log:
            log(f"[bootstrap] day {day}: ctr={m['ctr']:.4f}")

    # ---- phase 1: generation-1 model, batch serving (feedback loop) ---
    m1 = train_ranker(all_events, model_cfg, ab, "midnight", log=log)
    plat1 = make_platform(ab, model_cfg, m1, world, all_events,
                          policy="batch")
    # the platform-side observe hook (shared with Gateway.observe's event
    # type): the harness's log collector registers instead of
    # monkey-patching the observe method
    plat1.on_observe.append(all_events.append)
    g1 = range(ab.bootstrap_days, ab.bootstrap_days + ab.gen1_days)
    run_arm("gen1", ab, plat1, world, g1, log=log)

    # ---- phase 2: generation-2 models ---------------------------------
    m2_batch = train_ranker(all_events, model_cfg, ab, "midnight", log=log)
    m2_cons = train_ranker(all_events, model_cfg, ab, "fresh", log=log)

    # ---- phase 3: paired A/B ------------------------------------------
    start = ab.bootstrap_days + ab.gen1_days
    ab_range = range(start, start + ab.ab_days)
    world_snapshot = copy.deepcopy(world)

    arms: Dict[str, RecommenderPlatform] = {
        "control": make_platform(ab, model_cfg, m2_batch, world, all_events,
                                 policy="batch"),
        "treatment": make_platform(ab, model_cfg, m2_batch, world, all_events,
                                   policy="inject", merge_impl=merge_impl),
        "consistent": make_platform(ab, model_cfg, m2_cons, world, all_events,
                                    policy="inject", mode="consistent"),
    }
    for lam in ab.latency_arms:
        arms[f"stale_{lam}s"] = make_platform(
            ab, model_cfg, m2_batch, world, all_events, policy="batch",
            staleness=lam)

    results: Dict[str, ArmResult] = {}
    for name, plat in arms.items():
        w_arm = copy.deepcopy(world_snapshot)
        results[name] = run_arm(name, ab, plat, w_arm, ab_range, log=log)

    # ---- analysis ------------------------------------------------------
    ctrl = results["control"]
    report = {"arms": {}, "tests": {}}
    for name, res in results.items():
        report["arms"][name] = summarize_arm(name, res.day_metrics)
        if name != "control":
            report["tests"][f"{name}_vs_control"] = paired_user_test(
                res.user_watches, res.user_impressions,
                ctrl.user_watches, ctrl.user_impressions, seed=ab.seed)
            imp_t = sum(m["impressions"] for m in res.day_metrics)
            w_t = sum(m["slate_watches"] for m in res.day_metrics)
            imp_c = sum(m["impressions"] for m in ctrl.day_metrics)
            w_c = sum(m["slate_watches"] for m in ctrl.day_metrics)
            z, p = two_proportion_z(w_t, imp_t, w_c, imp_c)
            report["tests"][f"{name}_vs_control"].update(
                {"z_pooled": z, "p_pooled": p})
    report["results"] = results
    return report
