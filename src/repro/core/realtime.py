"""Real-time feature service — the paper's streaming job (§III-B, Fig. 2).

"A dedicated real-time feature service ... a continuous streaming job that
continuously consumes user behavior events and transforms them into
model-ready real-time watch history features with minimal delay."

The production version is a Kafka/Flink-style consumer; here it is an
in-process service with the same *semantics* (DESIGN.md §7.2):

* **ingest latency** — an event becomes visible ``ingest_latency`` seconds
  after it happened (stream propagation + processing delay);
* **bounded retention** — only a short window is kept (``retention``
  seconds, ``buffer_len`` events/user): "the real-time feature service ...
  can only maintain a short time range";
* **at-least-once** — duplicate deliveries are tolerated (the downstream
  merge deduplicates by item, so redelivery is harmless — property-tested).

Reads return fixed-shape padded arrays ready for the ``history_merge``
kernel: no dynamic shapes cross the host→device boundary.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RealtimeConfig:
    n_users: int
    buffer_len: int = 16          # per-user ring buffer (events)
    ingest_latency: int = 30      # seconds from event to visibility
    retention: int = 86400        # short window the service maintains


class RealtimeFeatureService:
    """Per-user ring buffers over a simulated event stream."""

    def __init__(self, cfg: RealtimeConfig):
        self.cfg = cfg
        self._buf: List[Deque[Tuple[int, int]]] = [
            deque(maxlen=cfg.buffer_len) for _ in range(cfg.n_users)]
        self.events_ingested = 0

    # ------------------------------------------------------------------
    def ingest(self, user: int, item: int, ts: int) -> None:
        """Consume one stream event (idempotent under redelivery given the
        downstream dedup; buffer keeps duplicates — cheap, bounded)."""
        self._buf[user].append((ts, item))
        self.events_ingested += 1

    def observe(self, ev) -> None:
        self.ingest(ev.user, ev.item, ev.ts)

    # ------------------------------------------------------------------
    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Model-ready recent-history features visible at wall-time ``now``.

        Visibility: ts + ingest_latency <= now and ts >= now - retention.
        Returns (items, ts, valid) each (len(users), buffer_len) int32,
        right-aligned ascending time.
        """
        c = self.cfg
        k = c.buffer_len
        items = np.zeros((len(users), k), np.int32)
        ts_arr = np.zeros((len(users), k), np.int32)
        valid = np.zeros((len(users), k), np.int32)
        hi = now - c.ingest_latency
        lo = now - c.retention
        for j, u in enumerate(users):
            evs = [e for e in self._buf[u] if lo <= e[0] <= hi]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[j, k - n:] = [e[1] for e in evs]
                ts_arr[j, k - n:] = [e[0] for e in evs]
                valid[j, k - n:] = 1
        return items, ts_arr, valid
