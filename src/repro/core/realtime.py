"""Real-time feature service — the paper's streaming job (§III-B, Fig. 2).

"A dedicated real-time feature service ... a continuous streaming job that
continuously consumes user behavior events and transforms them into
model-ready real-time watch history features with minimal delay."

The production version is a Kafka/Flink-style consumer; here it is an
in-process service with the same *semantics* (DESIGN.md §7.2):

* **ingest latency** — an event becomes visible ``ingest_latency`` seconds
  after it happened (stream propagation + processing delay);
* **bounded retention** — only a short window is kept (``retention``
  seconds, ``buffer_len`` events/user): "the real-time feature service ...
  can only maintain a short time range";
* **at-least-once** — duplicate deliveries are tolerated (the downstream
  merge deduplicates by item, so redelivery is harmless — property-tested).

Reads return fixed-shape padded arrays ready for the ``history_merge``
kernel: no dynamic shapes cross the host→device boundary.

Storage is a pair of columnar ``(n_users, buffer_len)`` ring arrays with a
per-user write cursor — the array-native form of the seed's per-user
deques: O(1) ingest, memory bounded by construction, and ``lookup`` is a
single vectorized gather + row-wise sort (no index to rebuild, so the
serving loop's interleaved observe/lookup pattern stays O(batch)). The
retired loop implementation lives in ``core/_reference.py`` and matches
bit-for-bit (differentially tested).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.event_log import sort_window_right_align


@dataclasses.dataclass(frozen=True)
class RealtimeConfig:
    n_users: int
    buffer_len: int = 16          # per-user ring buffer (events)
    ingest_latency: int = 30      # seconds from event to visibility
    retention: int = 86400        # short window the service maintains


class RealtimeFeatureService:
    """Columnar ring buffers over a simulated event stream."""

    def __init__(self, cfg: RealtimeConfig):
        self.cfg = cfg
        u, k = cfg.n_users, cfg.buffer_len
        self._items = np.zeros((u, k), np.int64)
        self._ts = np.zeros((u, k), np.int64)
        self._count = np.zeros(u, np.int64)   # total ever ingested per user
        self.events_ingested = 0

    # ------------------------------------------------------------------
    def ingest(self, user: int, item: int, ts: int) -> None:
        """Consume one stream event (idempotent under redelivery given the
        downstream dedup; buffer keeps duplicates — cheap, bounded)."""
        if not 0 <= user < self.cfg.n_users:
            raise IndexError(
                f"user {user} out of range [0, {self.cfg.n_users})")
        slot = self._count[user] % self.cfg.buffer_len
        self._items[user, slot] = item
        self._ts[user, slot] = ts
        self._count[user] += 1
        self.events_ingested += 1

    def extend(self, users, items, ts) -> None:
        """Columnar bulk ingest (parallel arrays, arrival order kept)."""
        users = np.asarray(users, np.int64).ravel()
        m = len(users)
        if m == 0:
            return
        if users.min() < 0 or users.max() >= self.cfg.n_users:
            raise IndexError(
                f"user ids out of range [0, {self.cfg.n_users})")
        items = np.asarray(items, np.int64).ravel()
        ts = np.asarray(ts, np.int64).ravel()
        k = self.cfg.buffer_len
        order = np.argsort(users, kind="stable")  # groups, arrival order
        us = users[order]
        starts = np.flatnonzero(np.r_[True, us[1:] != us[:-1]])
        sizes = np.diff(np.r_[starts, m])
        group = np.repeat(np.arange(len(starts)), sizes)
        j = np.arange(m) - starts[group]          # within-user sequence
        # events more than k from their user's batch end are overwritten
        # before they could ever be read — skip writing them
        keep = j >= (sizes[group] - k)
        slots = (self._count[us] + j) % k
        self._items[us[keep], slots[keep]] = items[order[keep]]
        self._ts[us[keep], slots[keep]] = ts[order[keep]]
        self._count[us[starts]] += sizes
        self.events_ingested += m

    def observe(self, ev) -> None:
        self.ingest(ev.user, ev.item, ev.ts)

    # ------------------------------------------------------------------
    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Model-ready recent-history features visible at wall-time ``now``.

        Visibility: ts + ingest_latency <= now and ts >= now - retention.
        Returns (items, ts, valid) each (len(users), buffer_len) int32,
        right-aligned ascending time.
        """
        c = self.cfg
        users = np.asarray(users, np.int64).ravel()
        k = c.buffer_len
        pane_i = self._items[users]
        pane_t = self._ts[users]
        filled = np.arange(k)[None, :] < self._count[users][:, None]
        vis = filled & (pane_t >= now - c.retention) \
            & (pane_t <= now - c.ingest_latency)
        return sort_window_right_align(pane_i, pane_t, vis, k)
