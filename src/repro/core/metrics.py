"""Engagement metrics + significance tests for the A/B harness.

The paper reports "a statistically significant 0.47% lift in key user
engagement metrics". Our observable analogues (DESIGN.md §7.1/7.3):

  * slate CTR      — attributed watches / impressions (primary)
  * watches/user   — engagement volume
  * session hit    — sessions with >= 1 attributed watch

Arms are simulated under common random numbers (the simulator keys user
choice RNG by (user, day, session, round)), so the paired per-user delta is
the right unit: we report the paired bootstrap CI and a paired t-test on
per-user CTR, plus the pooled two-proportion z-test for reference.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ArmStats:
    name: str
    impressions: int = 0
    watches: int = 0
    # per-user tallies for paired tests
    user_impressions: np.ndarray = None
    user_watches: np.ndarray = None

    @property
    def ctr(self) -> float:
        return self.watches / max(self.impressions, 1)


def two_proportion_z(x1: int, n1: int, x2: int, n2: int) -> Tuple[float, float]:
    """Pooled two-proportion z-test. Returns (z, two-sided p)."""
    p1, p2 = x1 / max(n1, 1), x2 / max(n2, 1)
    p = (x1 + x2) / max(n1 + n2, 1)
    se = math.sqrt(max(p * (1 - p) * (1 / max(n1, 1) + 1 / max(n2, 1)), 1e-18))
    z = (p1 - p2) / se
    pval = 2 * (1 - _phi(abs(z)))
    return z, pval


def _phi(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def paired_user_test(treat_w, treat_i, ctrl_w, ctrl_i,
                     n_boot: int = 2000, seed: int = 0) -> Dict[str, float]:
    """Paired per-user lift with bootstrap CI + t-test.

    Inputs are per-user watch and impression counts (same user index in both
    arms — common random numbers). Users with no impressions in either arm
    are dropped. Lift is the relative change of pooled CTR; the bootstrap
    resamples users.
    """
    mask = (treat_i > 0) & (ctrl_i > 0)
    tw, ti = treat_w[mask].astype(np.float64), treat_i[mask].astype(np.float64)
    cw, ci = ctrl_w[mask].astype(np.float64), ctrl_i[mask].astype(np.float64)
    n = mask.sum()
    ctr_t = tw.sum() / max(ti.sum(), 1)
    ctr_c = cw.sum() / max(ci.sum(), 1)
    lift = (ctr_t - ctr_c) / max(ctr_c, 1e-12)

    # paired t on per-user CTR deltas
    du = tw / np.maximum(ti, 1) - cw / np.maximum(ci, 1)
    t = du.mean() / max(du.std(ddof=1) / math.sqrt(max(n, 2)), 1e-18)
    p_t = 2 * (1 - _phi(abs(t)))  # normal approx (n is large)

    rng = np.random.RandomState(seed)
    boots = np.empty(n_boot)
    for b in range(n_boot):
        idx = rng.randint(0, n, n)
        bt = tw[idx].sum() / max(ti[idx].sum(), 1)
        bc = cw[idx].sum() / max(ci[idx].sum(), 1)
        boots[b] = (bt - bc) / max(bc, 1e-12)
    lo, hi = np.percentile(boots, [2.5, 97.5])
    return {"lift": float(lift), "ctr_treat": float(ctr_t),
            "ctr_ctrl": float(ctr_c), "t": float(t), "p_t": float(p_t),
            "ci_lo": float(lo), "ci_hi": float(hi), "n_users": int(n),
            "significant": bool(p_t < 0.05 and (lo > 0) == (hi > 0))}


def summarize_arm(name: str, day_metrics: Sequence[Dict]) -> Dict[str, float]:
    imp = sum(m["impressions"] for m in day_metrics)
    w = sum(m["slate_watches"] for m in day_metrics)
    return {"arm": name, "impressions": imp, "watches": w,
            "ctr": w / max(imp, 1)}
