"""Two-stage recommendation pipeline (paper §III, Fig. 1/2).

Stage 1 — candidate retrieval:
  * **primary recaller**: recency-weighted mean of the user's watch-history
    item embeddings, scored against all item embeddings ("retrieve a set of
    similar or relevant items"). Because it reads the *injected* features in
    the treatment arm, it "is enhanced to incorporate the user's recent
    watch history" exactly as §III-B-1 describes — with zero code changes.
  * **auxiliary popularity recaller** ("used to diversify the candidate
    pool") — unchanged across arms, as in the paper.

Stage 2 — ranking: the batch-trained sequential ranker (a decoder-only
model over item-id tokens, ``configs/itfi_ranker``) consumes the same
feature history and scores the candidate union; top ``slate_size`` wins.
Already-watched history items are excluded from the slate.

Item-id ↔ token mapping: item i ↦ token i+1; token 0 is padding.

The whole serve path for a request batch is ONE jit'd call
(``_serve_jit``): feature tokens in, slate item-ids out — the shape every
arm shares, so A/B timing is apples-to-apples.
"""
from __future__ import annotations

import dataclasses
import functools
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.injection import FeatureInjector
from repro.models.model import forward

NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_items: int
    slate_size: int = 10
    n_candidates: int = 128        # retrieval fan-in to the ranker
    recall_primary: int = 96       # primary recaller quota
    recall_popular: int = 32       # popularity recaller quota
    recency_halflife: int = 8      # events; recency weight 0.5**(age/halflife)
    serve_batch: int = 256         # static request-batch shape (padded)


def items_to_tokens(items: np.ndarray, valid: np.ndarray) -> np.ndarray:
    """item ids -> model tokens (shift by 1; pad slots -> token 0)."""
    return np.where(valid > 0, items + 1, 0).astype(np.int32)


# ----------------------------------------------------------------------
# The jit'd serve core
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "pcfg"))
def _serve_core(params, tokens, valid, pop_prior, *, cfg: ModelConfig,
                pcfg: PipelineConfig):
    """tokens/valid (B,K); pop_prior (V_items,) log-popularity.

    Returns (slate_items (B, slate), cand_items (B, C)) as item ids.
    """
    b, k = tokens.shape
    n_items = pcfg.n_items
    table = params["embed"]["table"]  # (Vp, d)

    # ---- stage 1: retrieval ------------------------------------------
    # recency-weighted mean embedding of history tokens
    age = (k - 1 - jnp.arange(k, dtype=jnp.float32))[None, :]  # (1,K)
    w = jnp.where(valid > 0, 0.5 ** (age / pcfg.recency_halflife), 0.0)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    hist_emb = jnp.einsum("bk,bkd->bd", w.astype(table.dtype), table[tokens])
    item_emb = table[1:n_items + 1]  # (V_items, d)
    sim = jnp.einsum("bd,vd->bv", hist_emb, item_emb).astype(jnp.float32)

    # exclude already-watched items from retrieval & ranking
    # (+2: slot 0 = pad token, last slot absorbs the SEP token harmlessly)
    watched = jnp.zeros((b, n_items + 2), bool)
    watched = watched.at[jnp.arange(b)[:, None], tokens].set(valid > 0)
    watched = watched[:, 1:n_items + 1]  # item-id indexed
    sim = jnp.where(watched, NEG_INF, sim)

    _, prim = jax.lax.top_k(sim, pcfg.recall_primary)          # (B, M1)
    pop = jnp.where(watched, NEG_INF, pop_prior[None, :])
    _, popc = jax.lax.top_k(pop, pcfg.recall_popular)          # (B, M2)
    cand = jnp.concatenate([prim, popc], axis=1)               # item idx 0-based

    # ---- stage 2: ranking --------------------------------------------
    logits, _ = forward(params, cfg, tokens, valid=(valid > 0))
    last = logits[:, -1, :]  # (B, Vp) next-item distribution
    cand_tok = cand + 1
    cand_scores = jnp.take_along_axis(last, cand_tok, axis=1)  # (B, C)
    # dedup candidates (popularity quota may collide with primary):
    # mask any candidate equal to an earlier candidate in the row.
    c = cand.shape[1]
    eq_earlier = (cand[:, :, None] == cand[:, None, :]) & (
        jnp.arange(c)[None, :, None] > jnp.arange(c)[None, None, :])
    dup = eq_earlier.any(-1)
    cand_scores = jnp.where(dup, NEG_INF, cand_scores)
    _, top_idx = jax.lax.top_k(cand_scores, pcfg.slate_size)
    slate = jnp.take_along_axis(cand, top_idx, axis=1)
    return slate, cand


# ----------------------------------------------------------------------
# The platform: injector + pipeline + model = one A/B arm
# ----------------------------------------------------------------------

class RecommenderPlatform:
    """Callable platform for the simulator: serve(users, tss) -> slates."""

    def __init__(self, pcfg: PipelineConfig, model_cfg: ModelConfig, params,
                 injector: FeatureInjector, popularity: np.ndarray,
                 run_batch_jobs: bool = True, mode: str = "plain"):
        self.pcfg = pcfg
        self.model_cfg = model_cfg
        self.params = params
        self.injector = injector
        self.pop_prior = jnp.asarray(
            np.log(popularity * len(popularity) + 1e-9), jnp.float32)
        self.run_batch_jobs = run_batch_jobs
        self.mode = mode  # "plain" | "consistent" (paper §IV variant)
        self.serve_calls = 0
        # registered observers: called with every event AFTER the stores
        # ingest it. This is the platform-side half of the unified
        # ingestion hook (Gateway.observe shares the same event duck type:
        # anything with .user/.item/.ts) — experiment harnesses register
        # log collectors here instead of monkey-patching observe().
        self.on_observe: list = []

    # -- event plumbing -------------------------------------------------
    def observe(self, ev) -> None:
        """Platform-side event hooks: offline log + realtime stream,
        then any registered ``on_observe`` callbacks."""
        self.injector.batch.append(ev.user, ev.item, ev.ts)
        if self.injector.realtime is not None:
            self.injector.realtime.ingest(ev.user, ev.item, ev.ts)
        for cb in self.on_observe:
            cb(ev)

    # -- serving ---------------------------------------------------------
    def serve(self, users: np.ndarray, tss: np.ndarray) -> np.ndarray:
        now = int(tss.max())
        if self.run_batch_jobs:
            self.injector.batch.maybe_run_due_snapshots(now)
        if self.mode == "consistent":
            # paper §IV variant: explicit auxiliary recent-watch features,
            # identical construction at training and inference.
            from repro.data.loader import serve_tokens_consistent
            bf = self.injector.batch.lookup(users, now)
            rf = self.injector.realtime.lookup(users, now)
            tokens, valid = serve_tokens_consistent(
                bf, rf, self.pcfg.n_items, self.injector.cfg.feature_len)
            valid = valid.astype(np.int32)
        else:
            items, ts_arr, valid = self.injector.features(users, now)
            tokens = items_to_tokens(items, valid)

        n = len(users)
        bpad = self.pcfg.serve_batch
        if n < bpad:  # pad to the static batch shape
            tokens = np.pad(tokens, ((0, bpad - n), (0, 0)))
            valid = np.pad(valid, ((0, bpad - n), (0, 0)))
        slate, _ = _serve_core(self.params, jnp.asarray(tokens),
                               jnp.asarray(valid), self.pop_prior,
                               cfg=self.model_cfg, pcfg=self.pcfg)
        self.serve_calls += 1
        return np.asarray(slate[:n])
