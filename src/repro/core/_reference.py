"""Loop-based reference feature plane — the retired seed implementations.

These are the original per-user Python-list/deque implementations of the
batch feature store and the realtime feature service, kept verbatim as a
differential-testing oracle (tests/test_feature_plane_diff.py) and as the
baseline the ``feature_plane`` benchmark suite measures the vectorized
stores against. They are NOT on any production path — ``feature_store.py``
and ``realtime.py`` are the array-backed implementations.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.feature_store import FeatureStoreConfig
from repro.core.realtime import RealtimeConfig


class ReferenceBatchFeatureStore:
    """Per-user event lists + per-user snapshot loops (seed semantics)."""

    def __init__(self, cfg: FeatureStoreConfig):
        self.cfg = cfg
        self._log: List[List[Tuple[int, int]]] = [[] for _ in range(cfg.n_users)]
        self._snapshots: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._snapshot_times: List[int] = []

    def append(self, user: int, item: int, ts: int) -> None:
        self._log[user].append((ts, item))

    def append_events(self, events) -> None:
        for ev in events:
            self.append(ev.user, ev.item, ev.ts)

    def run_snapshot(self, snapshot_ts: int) -> None:
        c = self.cfg
        k = c.feature_len
        items = np.zeros((c.n_users, k), np.int32)
        ts_arr = np.zeros((c.n_users, k), np.int32)
        valid = np.zeros((c.n_users, k), np.int32)
        lo = snapshot_ts - c.window
        for u in range(c.n_users):
            evs = [e for e in self._log[u] if lo <= e[0] < snapshot_ts]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[u, k - n:] = [e[1] for e in evs]
                ts_arr[u, k - n:] = [e[0] for e in evs]
                valid[u, k - n:] = 1
        self._snapshots[snapshot_ts] = (items, ts_arr, valid)
        bisect.insort(self._snapshot_times, snapshot_ts)

    def latest_snapshot_ts(self, now: int) -> Optional[int]:
        i = bisect.bisect_right(self._snapshot_times, now) - 1
        return self._snapshot_times[i] if i >= 0 else None

    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        snap = self.latest_snapshot_ts(now)
        k = self.cfg.feature_len
        if snap is None:
            z = np.zeros((len(users), k), np.int32)
            return z, z.copy(), z.copy()
        items, ts_arr, valid = self._snapshots[snap]
        return items[users], ts_arr[users], valid[users]

    def lookup_at_cutoff(self, users: np.ndarray, cutoff: int,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        c = self.cfg
        k = c.feature_len
        items = np.zeros((len(users), k), np.int32)
        ts_arr = np.zeros((len(users), k), np.int32)
        valid = np.zeros((len(users), k), np.int32)
        lo = cutoff - c.window
        for j, u in enumerate(users):
            evs = [e for e in self._log[u] if lo <= e[0] < cutoff]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[j, k - n:] = [e[1] for e in evs]
                ts_arr[j, k - n:] = [e[0] for e in evs]
                valid[j, k - n:] = 1
        return items, ts_arr, valid

    def user_events(self, user: int) -> List[Tuple[int, int]]:
        return sorted(self._log[user])


class ReferenceRealtimeFeatureService:
    """Per-user deques over the simulated event stream (seed semantics)."""

    def __init__(self, cfg: RealtimeConfig):
        self.cfg = cfg
        self._buf: List[Deque[Tuple[int, int]]] = [
            deque(maxlen=cfg.buffer_len) for _ in range(cfg.n_users)]
        self.events_ingested = 0

    def ingest(self, user: int, item: int, ts: int) -> None:
        self._buf[user].append((ts, item))
        self.events_ingested += 1

    def observe(self, ev) -> None:
        self.ingest(ev.user, ev.item, ev.ts)

    def lookup(self, users: np.ndarray, now: int,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        c = self.cfg
        k = c.buffer_len
        items = np.zeros((len(users), k), np.int32)
        ts_arr = np.zeros((len(users), k), np.int32)
        valid = np.zeros((len(users), k), np.int32)
        hi = now - c.ingest_latency
        lo = now - c.retention
        for j, u in enumerate(users):
            evs = [e for e in self._buf[u] if lo <= e[0] <= hi]
            evs.sort()
            evs = evs[-k:]
            n = len(evs)
            if n:
                items[j, k - n:] = [e[1] for e in evs]
                ts_arr[j, k - n:] = [e[0] for e in evs]
                valid[j, k - n:] = 1
        return items, ts_arr, valid
