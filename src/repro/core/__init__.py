"""The paper's contribution: inference-time feature injection (ITFI).

  event_log      — columnar append-only event log (the feature-plane SoA)
  feature_store  — batch "daily job" feature snapshots (§III-A)
  realtime       — streaming real-time feature service (§III-B, Fig. 2)
  injection      — the merge + inject-as-if-batch operator (§III-B)
  pipeline       — two-stage recommend: retrieval -> ranking (§III)
  metrics        — engagement metrics + paired significance tests (§IV)
  ab             — the A/B experiment harness reproducing §IV
"""
from repro.core.event_log import EventLog  # noqa: F401
from repro.core.feature_store import (  # noqa: F401
    BatchFeatureStore, FeatureStoreConfig)
from repro.core.injection import FeatureInjector, InjectionConfig  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    PipelineConfig, RecommenderPlatform)
from repro.core.realtime import (  # noqa: F401
    RealtimeConfig, RealtimeFeatureService)
