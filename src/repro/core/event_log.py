"""Columnar append-only event log — the shared feature-plane backbone.

The per-user Python lists the seed used for both the batch store and the
realtime service cap simulations at toy user counts: every snapshot was a
Python loop over users, every lookup a list comprehension per row. This
module replaces them with a struct-of-arrays design:

* three flat columns (``user``, ``item``, ``ts``) with amortized-doubling
  growth — O(1) append, O(m) columnar extend;
* a per-user CSR-style index over a sorted **base** prefix (one
  ``np.lexsort`` by ``(user, ts, item)`` plus ``searchsorted`` row
  offsets), rebuilt lazily and only when the unsorted **pending** suffix
  outgrows a fraction of the base. Reads that race interleaved writes —
  the serving loop's ``observe``/``lookup`` pattern — sort just the small
  pending suffix and merge per queried row, so a lookup never pays a
  full-log re-sort.

The read primitive is ``materialize(users, lo, hi, k)``: per-user events
with ``lo <= ts < hi``, sorted by ``(ts, item)``, truncated to the
freshest ``k``, right-aligned into ``(m, k)`` padded arrays — the batch
store's snapshot/cutoff read. The realtime service keeps its own bounded
``(n_users, buffer_len)`` ring arrays (core/realtime.py) and shares
``sort_window_right_align`` below.

Both stores match the retired loop implementations
(``core/_reference.py``) bit-for-bit; see tests/test_feature_plane_diff.py.
"""
from __future__ import annotations

import threading
from typing import List, Tuple

import numpy as np

Features = Tuple[np.ndarray, np.ndarray, np.ndarray]  # items, ts, valid


def sort_window_right_align(items: np.ndarray, ts: np.ndarray,
                            vis: np.ndarray, k: int, ts_dtype=np.int32,
                            ) -> Features:
    """Row-wise: sort the visible ``(ts, item)`` pairs ascending, keep the
    freshest ``k`` per row, right-align into (m, k) padded arrays.

    items/ts (m, w) int64 scratch panes, vis (m, w) bool. The composite
    int64 sort key pushes invisible slots to the left; stable argsort
    preserves arrival order among exact duplicates.
    """
    m = items.shape[0]
    out_i = np.zeros((m, k), np.int32)
    out_t = np.zeros((m, k), ts_dtype)
    out_v = np.zeros((m, k), np.int32)
    if m == 0 or not vis.any():
        return out_i, out_t, out_v
    t0 = ts[vis].min()
    i0 = items[vis].min()
    iscale = int(items[vis].max()) - int(i0) + 1
    key = np.where(vis, (ts - t0) * iscale + (items - i0), -1)
    order = np.argsort(key, axis=1, kind="stable")
    ts = np.take_along_axis(ts, order, axis=1)
    items = np.take_along_axis(items, order, axis=1)
    w = items.shape[1]
    if k <= w:
        ts, items = ts[:, w - k:], items[:, w - k:]
    else:
        pad = ((0, 0), (k - w, 0))
        ts, items = np.pad(ts, pad), np.pad(items, pad)
    keep = np.minimum(vis.sum(axis=1), k)
    mask = np.arange(k)[None, :] >= (k - keep)[:, None]
    out_i[mask] = items[mask]
    out_t[mask] = ts[mask].astype(ts_dtype)
    out_v[mask] = 1
    return out_i, out_t, out_v


def _scatter_right_aligned(order, item_col, ts_col, a, counts, k,
                           items, ts_out, valid):
    """Scatter CSR ranges [a, a+counts) (already (ts, item)-sorted) into
    right-aligned (m, k) outputs. Pure gathers — no per-row loop."""
    total = int(counts.sum())
    if total == 0:
        return
    rows = np.repeat(np.arange(len(counts)), counts)
    offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    src = order[np.repeat(a, counts) + offs]
    cols = k - np.repeat(counts, counts) + offs
    items[rows, cols] = item_col[src]
    ts_out[rows, cols] = ts_col[src].astype(ts_out.dtype)
    valid[rows, cols] = 1


class _SortedIndex:
    """(user, ts, item)-sorted CSR over a column slice + composite key."""

    def __init__(self, users, items, ts):
        self.order = np.lexsort((items, ts, users))
        us = users[self.order]
        tss = ts[self.order]
        self.ts_min = int(ts.min()) if len(ts) else 0
        ts_max = int(ts.max()) if len(ts) else 0
        self.scale = ts_max - self.ts_min + 2
        self.key = us * self.scale + (tss - self.ts_min)

    def window(self, users, lo, hi, k):
        """Per queried user: CSR range of the freshest <=k events with
        lo <= ts < hi. Returns (a, counts) into ``self.order``."""
        qlo = users * self.scale + np.clip(lo - self.ts_min, 0,
                                           self.scale - 1)
        qhi = users * self.scale + np.clip(hi - self.ts_min, 0,
                                           self.scale - 1)
        a = np.searchsorted(self.key, qlo, side="left")
        b = np.searchsorted(self.key, qhi, side="left")
        a = np.maximum(a, b - k)
        return a, b - a


class EventLog:
    """Append-only columnar (user, item, ts) log with a lazy base index
    and a sort-free pending suffix merged at read time."""

    # full rebuild when pending > max(MIN_REBUILD, base/8)
    MIN_REBUILD = 4096

    def __init__(self, n_users: int, capacity: int = 1024):
        self.n_users = int(n_users)
        cap = max(int(capacity), 16)
        self._user = np.empty(cap, np.int64)
        self._item = np.empty(cap, np.int32)
        self._ts = np.empty(cap, np.int64)
        self._n = 0
        self._base_n = 0          # events covered by _base
        self._base: _SortedIndex = None
        self._tail: _SortedIndex = None
        self._tail_span = (0, 0)  # (base_n, n) the cached tail covers
        # narrow write lock: guards the (columns, _n) pair so a
        # concurrent ``view()`` never captures a half-written append.
        # Reads on the owning thread stay lock-free — the lock is only
        # taken for the O(1)/O(m) column writes and the O(1) capture.
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # writes
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return self._n

    @property
    def n_events(self) -> int:
        return self._n

    def _grow(self, need: int) -> None:
        cap = len(self._user)
        if self._n + need <= cap:
            return
        new = cap
        while new < self._n + need:
            new *= 2
        for name in ("_user", "_item", "_ts"):
            arr = getattr(self, name)
            out = np.empty(new, arr.dtype)
            out[:self._n] = arr[:self._n]
            setattr(self, name, out)

    def append(self, user: int, item: int, ts: int) -> None:
        if not 0 <= user < self.n_users:
            raise IndexError(f"user {user} out of range [0, {self.n_users})")
        with self._lock:
            self._grow(1)
            i = self._n
            self._user[i] = user
            self._item[i] = item
            self._ts[i] = ts
            self._n = i + 1

    def extend(self, users, items, ts) -> None:
        """Columnar bulk append (parallel arrays)."""
        users = np.asarray(users)
        m = len(users)
        if m == 0:
            return
        if users.min() < 0 or users.max() >= self.n_users:
            raise IndexError(
                f"user ids out of range [0, {self.n_users}): "
                f"[{users.min()}, {users.max()}]")
        with self._lock:
            self._grow(m)
            s = self._n
            self._user[s:s + m] = users
            self._item[s:s + m] = np.asarray(items)
            self._ts[s:s + m] = np.asarray(ts)
            self._n = s + m

    def view(self) -> "LogView":
        """Frozen consistent snapshot of the log for cross-thread reads.

        Captures the column references and the current event count under
        the write lock. The log is append-only and ``_grow`` copies into
        *fresh* arrays (it never resizes in place), so every position
        ``< n`` in the captured columns is immutable afterwards: the view
        is a stable consistent prefix no matter how many appends race it.
        O(1) — no data is copied.
        """
        with self._lock:
            # hand over the base index when it covers exactly the
            # captured prefix: _SortedIndex is immutable once built and
            # column prefixes survive _grow by content, so the view can
            # skip its own population-scale lexsort (which would hold
            # the GIL in long numpy sorts, stalling the capturing
            # thread's polls). A stale/partial base just means the view
            # sorts for itself on first materialize.
            base = self._base
            reuse = base if (base is not None
                             and len(base.order) == self._n) else None
            return LogView(self._user, self._item, self._ts, self._n,
                           self.n_users, index=reuse)

    # ------------------------------------------------------------------
    # index maintenance
    # ------------------------------------------------------------------
    def _rebuild(self) -> None:
        n = self._n
        self._base = _SortedIndex(self._user[:n], self._item[:n],
                                  self._ts[:n])
        self._base_n = n

    def _ensure_base(self, n_queried: int) -> None:
        pending = self._n - self._base_n
        if self._base is None or pending > max(self.MIN_REBUILD,
                                               self._base_n // 8):
            self._rebuild()
        elif pending and n_queried >= max(1024, pending):
            # population-scale read racing a small pending suffix (e.g.
            # run_snapshot right after a serve wave): the merge path's
            # query-sized scratch panes would dwarf one amortized rebuild
            self._rebuild()

    def _tail_index(self) -> _SortedIndex:
        """Sorted index over the pending suffix, cached between writes."""
        span = (self._base_n, self._n)
        if self._tail_span != span:
            p0, n = span
            self._tail = _SortedIndex(self._user[p0:n], self._item[p0:n],
                                      self._ts[p0:n])
            self._tail_span = span
        return self._tail

    def min_ts(self) -> int:
        if self._n == 0:
            raise ValueError("empty log has no min ts")
        return int(self._ts[:self._n].min())

    # ------------------------------------------------------------------
    # delta queries (the incremental-snapshot backbone)
    # ------------------------------------------------------------------
    def users_with_events(self, lo: int, hi: int, start: int = 0,
                          ) -> np.ndarray:
        """Sorted unique users with >=1 event with ``lo <= ts < hi``
        among the events appended at log positions ``>= start``.

        One vectorized columnar scan — no index required, so it works
        identically with or without a pending suffix. ``start`` lets a
        caller restrict the scan to events appended after a known point
        (e.g. "since the previous snapshot was built"), which is how
        late-arriving events with old timestamps are caught.
        """
        n = self._n
        start = max(int(start), 0)
        if start >= n or hi <= lo:
            return np.empty(0, np.int64)
        ts = self._ts[start:n]
        mask = (ts >= lo) & (ts < hi)
        if not mask.any():
            return np.empty(0, np.int64)
        return np.unique(self._user[start:n][mask])

    def changed_users(self, prev_cutoff: int, new_cutoff: int, window: int,
                      since: int = 0) -> np.ndarray:
        """Users whose ``[cutoff - window, cutoff)`` event set may differ
        between snapshot cutoffs ``prev_cutoff`` and ``new_cutoff``:

        * events *entering* by timestamp — ts in ``[prev, new)``;
        * events *aging out* of the lookback window — ts in
          ``[prev - window, new - window)``;
        * *late arrivals* — events appended at log positions ``>= since``
          (pass the log length when the previous snapshot was built) whose
          ts already lands inside the new window: the previous snapshot
          cannot contain them no matter what their timestamp says.

        The result is a **superset** of the truly-changed users (an
        entering event can still materialize to identical features if it
        falls outside the freshest-``feature_len`` cut), which is the safe
        direction: rematerializing an unchanged user is wasted work, not
        wrong output. A user absent from this set has a bitwise-identical
        event window at both cutoffs.
        """
        entering = self.users_with_events(prev_cutoff, new_cutoff)
        aging = self.users_with_events(prev_cutoff - window,
                                       new_cutoff - window)
        late = self.users_with_events(new_cutoff - window, new_cutoff,
                                      start=since)
        return np.union1d(np.union1d(entering, aging), late)

    def user_events(self, user: int) -> List[Tuple[int, int]]:
        """(ts, item) pairs for one user, sorted — debug/compat helper."""
        if self._base is None or self._base_n != self._n:
            self._rebuild()
        base = self._base
        a = np.searchsorted(base.key, np.int64(user) * base.scale)
        b = np.searchsorted(base.key, np.int64(user + 1) * base.scale)
        idx = base.order[a:b]
        return [(int(t), int(i)) for t, i in zip(self._ts[idx],
                                                 self._item[idx])]

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def materialize(self, users, lo: int, hi: int, k: int,
                    ts_dtype=np.int32) -> Features:
        """Freshest ``k`` events with ``lo <= ts < hi`` per requested user,
        right-aligned ascending ``(ts, item)`` into (len(users), k) arrays.
        """
        users = np.asarray(users, np.int64).ravel()
        m = len(users)
        items = np.zeros((m, k), np.int32)
        ts_out = np.zeros((m, k), ts_dtype)
        valid = np.zeros((m, k), np.int32)
        if m == 0 or self._n == 0 or hi <= lo:
            return items, ts_out, valid
        self._ensure_base(m)
        a, counts = self._base.window(users, lo, hi, k)
        if self._n == self._base_n:
            # fast path: everything indexed, one scatter
            _scatter_right_aligned(self._base.order, self._item, self._ts,
                                   a, counts, k, items, ts_out, valid)
            return items, ts_out, valid
        # merge path: sort only the small pending suffix (cached between
        # writes), combine per row
        p0 = self._base_n
        tail = self._tail_index()
        ta, tcounts = tail.window(users, lo, hi, k)
        # scratch pane: base block (<=k) | tail block (<=k), both already
        # (ts, item)-sorted; a row-wise merge-sort keeps exact semantics
        # (only the freshest k of each block can survive the union's cut)
        pane_i = np.zeros((m, 2 * k), np.int64)
        pane_t = np.zeros((m, 2 * k), np.int64)
        pane_v = np.zeros((m, 2 * k), bool)
        _scatter_right_aligned(self._base.order, self._item, self._ts,
                               a, counts, k, pane_i[:, :k], pane_t[:, :k],
                               pane_v[:, :k])
        _scatter_right_aligned(tail.order, self._item[p0:self._n],
                               self._ts[p0:self._n], ta, tcounts, k,
                               pane_i[:, k:], pane_t[:, k:], pane_v[:, k:])
        return sort_window_right_align(pane_i, pane_t, pane_v, k, ts_dtype)


class LogView:
    """Immutable snapshot of an :class:`EventLog` prefix, safe to read
    from another thread while the owning thread keeps appending.

    Captured by ``EventLog.view()``: column *references* plus the event
    count ``n`` at capture time. Because the log is append-only and
    growth reallocates (never resizes in place), positions ``< n`` never
    mutate — so the view needs no locking at all. It carries its own
    private :class:`_SortedIndex` (built lazily on first ``materialize``,
    or handed over by ``view()`` when the log's base index already covers
    exactly the captured prefix — index objects are immutable once built)
    instead of touching the owning log's cached index *slots*, which are
    not thread-safe.
    """

    def __init__(self, user, item, ts, n: int, n_users: int,
                 index: _SortedIndex = None):
        n = int(n)
        self._user = user[:n]
        self._item = item[:n]
        self._ts = ts[:n]
        self._n = n
        self.n_users = int(n_users)
        self._index: _SortedIndex = index

    def __len__(self) -> int:
        return self._n

    @property
    def n_events(self) -> int:
        return self._n

    # same delta-query semantics as EventLog, against the frozen prefix
    def users_with_events(self, lo: int, hi: int, start: int = 0,
                          ) -> np.ndarray:
        start = max(int(start), 0)
        if start >= self._n or hi <= lo:
            return np.empty(0, np.int64)
        ts = self._ts[start:]
        mask = (ts >= lo) & (ts < hi)
        if not mask.any():
            return np.empty(0, np.int64)
        return np.unique(self._user[start:][mask])

    def changed_users(self, prev_cutoff: int, new_cutoff: int, window: int,
                      since: int = 0) -> np.ndarray:
        entering = self.users_with_events(prev_cutoff, new_cutoff)
        aging = self.users_with_events(prev_cutoff - window,
                                       new_cutoff - window)
        late = self.users_with_events(new_cutoff - window, new_cutoff,
                                      start=since)
        return np.union1d(np.union1d(entering, aging), late)

    def events_since(self, start: int = 0,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(user, item, ts)`` column views of the events appended at
        log positions ``>= start`` within the captured prefix, in append
        order. Zero-copy (array slices of the frozen columns) — the
        online trainer's consume primitive: it remembers the position it
        has trained through and asks each fresh view only for the
        suffix."""
        start = min(max(int(start), 0), self._n)
        return (self._user[start:], self._item[start:], self._ts[start:])

    def materialize(self, users, lo: int, hi: int, k: int,
                    ts_dtype=np.int32) -> Features:
        """Identical output to ``EventLog.materialize`` restricted to the
        captured prefix. Always the fully-indexed fast path — the view is
        frozen, so there is never a pending suffix to merge."""
        users = np.asarray(users, np.int64).ravel()
        m = len(users)
        items = np.zeros((m, k), np.int32)
        ts_out = np.zeros((m, k), ts_dtype)
        valid = np.zeros((m, k), np.int32)
        if m == 0 or self._n == 0 or hi <= lo:
            return items, ts_out, valid
        if self._index is None:
            self._index = _SortedIndex(self._user, self._item, self._ts)
        a, counts = self._index.window(users, lo, hi, k)
        _scatter_right_aligned(self._index.order, self._item, self._ts,
                               a, counts, k, items, ts_out, valid)
        return items, ts_out, valid
