"""Columnar event log — the shared feature-plane backbone, now a tiered
sliding-window store with bounded memory.

The per-user Python lists the seed used for both the batch store and the
realtime service cap simulations at toy user counts: every snapshot was a
Python loop over users, every lookup a list comprehension per row. This
module replaces them with a struct-of-arrays design:

* three flat columns (``user``, ``item``, ``ts``) with amortized-doubling
  growth — O(1) append, O(m) columnar extend;
* a per-user CSR-style index over a sorted **base** prefix (one
  ``np.lexsort`` by ``(user, ts, item)`` plus ``searchsorted`` row
  offsets), rebuilt lazily and only when the unsorted **pending** suffix
  outgrows a fraction of the base. Reads that race interleaved writes —
  the serving loop's ``observe``/``lookup`` pattern — sort just the small
  pending suffix and merge per queried row, so a lookup never pays a
  full-log re-sort.

An append-only log is a memory leak at production ingest rates, so the
log optionally **tiers** (pass ``window=...``):

* **hot tail** — the columnar SoA above, holding every event at or past
  the compaction horizon (plus any suffix protected by ``keep_from``),
  with its capacity bounded by ``hot_budget``;
* **warm segments** — one immutable, window-compacted segment per
  elapsed time window of length ``window``: the freshest ``segment_k``
  events per user, ``(user, ts, item)``-sorted with their own CSR index
  and the *absolute append position* of every kept event;
* **cold eviction** — segments whose window falls entirely below
  ``horizon - retention_windows * window`` are dropped.

``compact(now)`` moves fully-elapsed windows out of the tail (the open
window never compacts, which is the natural late-arrival grace period).
An append whose ``ts`` is already below the horizon is **demoted**
straight into its window's segment — or, past the retention floor,
dropped; both are counted in ``counters``, never silently lost. A
``keep_from`` append position (the online trainer's cursor) pins the
not-yet-consumed suffix in the hot tail across compaction.

Positions are **absolute**: every append consumes one position for the
lifetime of the log, ``n_events`` counts positions (not retained rows),
and segments remember each kept event's position — so position-anchored
delta scans (``users_with_events(..., start=log_n_at_build)``, the
rollover late-arrival certification) and the trainer's
``events_since(cursor)`` survive compaction.

**Exactness contract** (see docs/event_log.md): a query window
``[lo, hi)`` is bitwise-identical to an unbounded log when ``lo`` is at
or above the retention floor, ``k <= segment_k``, and ``hi`` does not
split a compacted window (``hi`` above the horizon or window-aligned).
Queries that do split a compacted window are exact unless that window
trimmed events (a user held more than ``segment_k`` events in one
window); user-set scans then degrade to a recorded **superset** — the
safe direction for ``changed_users`` — via each segment's trim
bookkeeping.

Both stores match the retired loop implementations
(``core/_reference.py``) bit-for-bit; see tests/test_feature_plane_diff.py.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

Features = Tuple[np.ndarray, np.ndarray, np.ndarray]  # items, ts, valid


def sort_window_right_align(items: np.ndarray, ts: np.ndarray,
                            vis: np.ndarray, k: int, ts_dtype=np.int32,
                            ) -> Features:
    """Row-wise: sort the visible ``(ts, item)`` pairs ascending, keep the
    freshest ``k`` per row, right-align into (m, k) padded arrays.

    items/ts (m, w) int64 scratch panes, vis (m, w) bool. The composite
    int64 sort key pushes invisible slots to the left; stable argsort
    preserves arrival order among exact duplicates.
    """
    m = items.shape[0]
    out_i = np.zeros((m, k), np.int32)
    out_t = np.zeros((m, k), ts_dtype)
    out_v = np.zeros((m, k), np.int32)
    if m == 0 or not vis.any():
        return out_i, out_t, out_v
    t0 = ts[vis].min()
    i0 = items[vis].min()
    iscale = int(items[vis].max()) - int(i0) + 1
    key = np.where(vis, (ts - t0) * iscale + (items - i0), -1)
    order = np.argsort(key, axis=1, kind="stable")
    ts = np.take_along_axis(ts, order, axis=1)
    items = np.take_along_axis(items, order, axis=1)
    w = items.shape[1]
    if k <= w:
        ts, items = ts[:, w - k:], items[:, w - k:]
    else:
        pad = ((0, 0), (k - w, 0))
        ts, items = np.pad(ts, pad), np.pad(items, pad)
    keep = np.minimum(vis.sum(axis=1), k)
    mask = np.arange(k)[None, :] >= (k - keep)[:, None]
    out_i[mask] = items[mask]
    out_t[mask] = ts[mask].astype(ts_dtype)
    out_v[mask] = 1
    return out_i, out_t, out_v


def _scatter_right_aligned(order, item_col, ts_col, a, counts, k,
                           items, ts_out, valid):
    """Scatter CSR ranges [a, a+counts) (already (ts, item)-sorted) into
    right-aligned (m, k) outputs. Pure gathers — no per-row loop."""
    total = int(counts.sum())
    if total == 0:
        return
    rows = np.repeat(np.arange(len(counts)), counts)
    offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    src = order[np.repeat(a, counts) + offs]
    cols = k - np.repeat(counts, counts) + offs
    items[rows, cols] = item_col[src]
    ts_out[rows, cols] = ts_col[src].astype(ts_out.dtype)
    valid[rows, cols] = 1


class _SortedIndex:
    """(user, ts, item)-sorted CSR over a column slice + composite key."""

    def __init__(self, users, items, ts):
        self.order = np.lexsort((items, ts, users))
        us = users[self.order]
        tss = ts[self.order]
        self.ts_min = int(ts.min()) if len(ts) else 0
        ts_max = int(ts.max()) if len(ts) else 0
        self.scale = ts_max - self.ts_min + 2
        self.key = us * self.scale + (tss - self.ts_min)

    def window(self, users, lo, hi, k):
        """Per queried user: CSR range of the freshest <=k events with
        lo <= ts < hi. Returns (a, counts) into ``self.order``."""
        qlo = users * self.scale + np.clip(lo - self.ts_min, 0,
                                           self.scale - 1)
        qhi = users * self.scale + np.clip(hi - self.ts_min, 0,
                                           self.scale - 1)
        a = np.searchsorted(self.key, qlo, side="left")
        b = np.searchsorted(self.key, qhi, side="left")
        a = np.maximum(a, b - k)
        return a, b - a


# ----------------------------------------------------------------------
# warm tier: immutable window-compacted segments
# ----------------------------------------------------------------------

class _Segment:
    """One compacted time window ``[w0, w1)``: the freshest ``<=k``
    events per user, ``(user, ts, item)``-sorted, with each kept event's
    absolute append position. Immutable once built — merging late events
    rebuilds the segment (copy-on-write), so a captured reference stays
    consistent forever."""

    __slots__ = ("w0", "w1", "user", "item", "ts", "pos", "index", "n",
                 "nbytes", "ts_min", "max_pos", "trimmed", "trim_users",
                 "trim_ts_lo", "trim_ts_hi", "trim_pos_hi")

    def scan_users(self, lo: int, hi: int, start: int) -> List[np.ndarray]:
        """User arrays for ``users_with_events`` over this segment:
        exact presence from the kept rows, plus the trim superset when
        the query could have matched a trimmed (older-than-kept) event —
        i.e. the query right edge splits this window, or the scan is
        position-anchored past trimmed positions."""
        out: List[np.ndarray] = []
        m = (self.ts >= lo) & (self.ts < hi)
        if start > 0:
            m &= self.pos >= start
        if m.any():
            out.append(np.unique(self.user[m]))
        if self.trimmed and (hi < self.w1 or start > 0) \
                and lo <= self.trim_ts_hi and self.trim_ts_lo < hi \
                and start <= self.trim_pos_hi:
            out.append(self.trim_users)
        return out


def _build_segment(w0: int, w1: int, user, item, ts, pos, k: int,
                   prev: Optional[_Segment] = None) -> _Segment:
    """Compact candidate rows (append order) — merged with an existing
    segment's kept rows when ``prev`` is given — into a fresh segment:
    ``(user, ts, item)``-lexsort, keep the freshest ``k`` per user group,
    fold the cut rows into the trim bookkeeping."""
    if prev is not None:
        user = np.concatenate([prev.user, np.asarray(user, np.int64)])
        item = np.concatenate([prev.item, np.asarray(item, np.int32)])
        ts = np.concatenate([prev.ts, np.asarray(ts, np.int64)])
        pos = np.concatenate([prev.pos, np.asarray(pos, np.int64)])
    else:
        user = np.asarray(user, np.int64)
        item = np.asarray(item, np.int32)
        ts = np.asarray(ts, np.int64)
        pos = np.asarray(pos, np.int64)
    order = np.lexsort((item, ts, user))
    us, its = user[order], item[order]
    tss, ps = ts[order], pos[order]
    n = len(us)
    # freshest k per user group == last k rows of each (user,ts,item)
    # run; lexsort is stable so full-duplicate ties keep append order
    new_grp = np.empty(n, bool)
    new_grp[0] = True
    new_grp[1:] = us[1:] != us[:-1]
    starts = np.flatnonzero(new_grp)
    counts = np.diff(np.append(starts, n))
    gidx = np.cumsum(new_grp) - 1
    ends = (starts + counts)[gidx]
    keep = (ends - 1 - np.arange(n)) < k
    seg = _Segment()
    seg.w0, seg.w1 = int(w0), int(w1)
    seg.user, seg.item = us[keep], its[keep]
    seg.ts, seg.pos = tss[keep], ps[keep]
    seg.n = int(keep.sum())
    seg.index = _SortedIndex(seg.user, seg.item, seg.ts)
    seg.ts_min = int(seg.ts.min())
    seg.max_pos = int(seg.pos.max())
    cut = n - seg.n
    if cut:
        cut_ts, cut_pos = tss[~keep], ps[~keep]
        cut_users = np.unique(us[~keep])
        if prev is not None and prev.trimmed:
            seg.trim_users = np.union1d(prev.trim_users, cut_users)
            seg.trim_ts_lo = min(prev.trim_ts_lo, int(cut_ts.min()))
            seg.trim_ts_hi = max(prev.trim_ts_hi, int(cut_ts.max()))
            seg.trim_pos_hi = max(prev.trim_pos_hi, int(cut_pos.max()))
        else:
            seg.trim_users = cut_users
            seg.trim_ts_lo = int(cut_ts.min())
            seg.trim_ts_hi = int(cut_ts.max())
            seg.trim_pos_hi = int(cut_pos.max())
        seg.trimmed = (prev.trimmed if prev is not None else 0) + cut
    elif prev is not None and prev.trimmed:
        seg.trimmed = prev.trimmed
        seg.trim_users = prev.trim_users
        seg.trim_ts_lo, seg.trim_ts_hi = prev.trim_ts_lo, prev.trim_ts_hi
        seg.trim_pos_hi = prev.trim_pos_hi
    else:
        seg.trimmed = 0
        seg.trim_users = np.empty(0, np.int64)
        seg.trim_ts_lo = seg.trim_ts_hi = 0
        seg.trim_pos_hi = -1
    seg.nbytes = int(seg.user.nbytes + seg.item.nbytes + seg.ts.nbytes
                     + seg.pos.nbytes + seg.trim_users.nbytes)
    return seg


def _compose_blocks(blocks, users, lo, hi, k, ts_dtype,
                    items, ts_out, valid) -> Features:
    """Materialize across tier blocks: each block (a sorted index + its
    columns) contributes its own freshest-``k`` window slice to a scratch
    pane; one final row-wise merge keeps exact top-``k``-of-union
    semantics (blocks partition the events, so the union's freshest k is
    always inside the union of per-block freshest k). Pane layout is
    segments-ascending-then-tail, which matches append order for ties —
    and identical ``(ts, item)`` duplicates produce identical output bits
    regardless of which physical copy survives."""
    m = len(users)
    nb = len(blocks)
    pane_i = np.zeros((m, nb * k), np.int64)
    pane_t = np.zeros((m, nb * k), np.int64)
    pane_v = np.zeros((m, nb * k), bool)
    for j, (idx, item_col, ts_col) in enumerate(blocks):
        a, counts = idx.window(users, lo, hi, k)
        sl = slice(j * k, (j + 1) * k)
        _scatter_right_aligned(idx.order, item_col, ts_col, a, counts, k,
                               pane_i[:, sl], pane_t[:, sl], pane_v[:, sl])
    if not pane_v.any():
        return items, ts_out, valid
    return sort_window_right_align(pane_i, pane_t, pane_v, k, ts_dtype)


def _users_with_events(user, ts, pos, n, segments, lo, hi, start,
                       ) -> np.ndarray:
    """Shared composite scan: hot-tail columns (position-anchored via the
    pos column when tiered, by index otherwise) plus every overlapping
    warm segment."""
    parts: List[np.ndarray] = []
    if n:
        if pos is None:
            i0 = min(start, n)
        else:
            i0 = int(np.searchsorted(pos[:n], start))
        if i0 < n:
            w = ts[i0:n]
            m = (w >= lo) & (w < hi)
            if m.any():
                parts.append(np.unique(user[i0:n][m]))
    for seg in segments:
        if seg.w0 < hi and seg.w1 > lo:
            parts.extend(seg.scan_users(lo, hi, start))
    if not parts:
        return np.empty(0, np.int64)
    if len(parts) == 1:
        return parts[0]
    return np.unique(np.concatenate(parts))


# ----------------------------------------------------------------------
# compaction plan: capture -> build (pure, off-thread-safe) -> install
# ----------------------------------------------------------------------

def _compact_build(plan: Dict, segment_k: int) -> Dict:
    """Pure build phase of a compaction: from a captured tail prefix,
    produce the new segment map and the new hot-tail arrays. Touches no
    log state, so it can run on a worker thread (the captured column
    prefixes are immutable — growth reallocates, never resizes)."""
    n = plan["n"]
    window = plan["window"]
    horizon, floor = plan["horizon"], plan["floor"]
    user, item = plan["user"][:n], plan["item"][:n]
    ts, pos = plan["ts"][:n], plan["pos"][:n]
    keep = ts >= horizon
    if plan["keep_from"] is not None:
        # pin the trainer's unconsumed suffix in the hot tail: those
        # rows can neither be trimmed nor evicted before consumption
        keep |= pos >= plan["keep_from"]
    moved = ~keep
    evict = moved & (ts < floor)
    to_seg = moved & ~evict
    counters = {"compacted": int(to_seg.sum()), "evicted": int(evict.sum()),
                "trimmed": 0}
    segments: Dict[int, _Segment] = {}
    for w0, seg in plan["segments"].items():
        if seg.w1 <= floor:
            counters["evicted"] += seg.n
        else:
            segments[w0] = seg
    if to_seg.any():
        su, si = user[to_seg], item[to_seg]
        st, sp = ts[to_seg], pos[to_seg]
        wids = st // window
        for w in np.unique(wids):
            wm = wids == w
            w0 = int(w) * window
            prev = segments.get(w0)
            seg = _build_segment(w0, w0 + window, su[wm], si[wm], st[wm],
                                 sp[wm], segment_k, prev=prev)
            counters["trimmed"] += seg.trimmed - (prev.trimmed if prev
                                                  else 0)
            segments[w0] = seg
    kept = int(keep.sum())
    cap = 16
    while cap < kept:
        cap *= 2
    if plan["hot_budget"] is not None and cap > plan["hot_budget"]:
        cap = max(plan["hot_budget"], kept)
    nu = np.empty(cap, np.int64)
    ni = np.empty(cap, np.int32)
    nt = np.empty(cap, np.int64)
    npos = np.empty(cap, np.int64)
    nu[:kept] = user[keep]
    ni[:kept] = item[keep]
    nt[:kept] = ts[keep]
    npos[:kept] = pos[keep]
    return {"plan": plan, "segments": segments, "counters": counters,
            "user": nu, "item": ni, "ts": nt, "pos": npos, "kept": kept}


class EventLog:
    """Columnar (user, item, ts) log with a lazy base index, a sort-free
    pending suffix merged at read time, and (when ``window`` is set) the
    tiered sliding-window machinery described in the module docstring.
    Untiered (``window=None``) behavior is identical to the historical
    append-only log.

    Threading model: one writer thread (``append``/``extend``/
    ``compact``); any number of reader threads via ``view()``. The
    narrow ``_lock`` only makes captures tear-free — reads on the owning
    thread stay lock-free."""

    # full rebuild when pending > max(MIN_REBUILD, base/8)
    MIN_REBUILD = 4096

    def __init__(self, n_users: int, capacity: int = 1024,
                 window: Optional[int] = None, retention_windows: int = 8,
                 segment_k: int = 64, hot_budget: Optional[int] = None):
        self.n_users = int(n_users)
        self.window = int(window) if window else None
        self.retention_windows = int(retention_windows)
        self.segment_k = int(segment_k)
        self.hot_budget = int(hot_budget) if hot_budget else None
        cap = max(int(capacity), 16)
        if self.hot_budget is not None:
            cap = min(cap, max(self.hot_budget, 16))
        self._user = np.empty(cap, np.int64)
        self._item = np.empty(cap, np.int32)
        self._ts = np.empty(cap, np.int64)
        # absolute append position per hot row (tiered only)
        self._pos = np.empty(cap, np.int64) if self.window else None
        self._n = 0
        self._appended = 0        # positions consumed, ever
        self._segments: Dict[int, _Segment] = {}
        self._compact_horizon: Optional[int] = None
        self._retained_floor: Optional[int] = None
        self._compacting = False  # off-thread build in flight
        self._late_buffer: List[Tuple[int, int, int, int]] = []
        self.counters = {"demoted": 0, "dropped_late": 0, "trimmed": 0,
                         "evicted": 0, "compacted": 0, "compactions": 0,
                         "hot_overflow": 0}
        self._base_n = 0          # events covered by _base
        self._base: _SortedIndex = None
        self._tail: _SortedIndex = None
        self._tail_span = (0, 0)  # (base_n, n) the cached tail covers
        # narrow write lock: guards the (columns, _n) pair so a
        # concurrent ``view()`` never captures a half-written append.
        # Reads on the owning thread stay lock-free — the lock is only
        # taken for the O(1)/O(m) column writes and the O(1) capture.
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # writes
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        """Retained events (hot tail + warm segments)."""
        return self._n + sum(s.n for s in self._segments.values())

    @property
    def n_events(self) -> int:
        """Absolute append positions consumed — monotone across
        compaction, so snapshot anchors and trainer cursors stay valid
        after the tail is rewritten. Equals ``len(self)`` untiered."""
        return self._appended

    def _grow(self, need: int) -> None:
        cap = len(self._user)
        if self._n + need <= cap:
            return
        new = cap
        while new < self._n + need:
            new *= 2
        if self.hot_budget is not None and new > self.hot_budget:
            # bounded hot tail: never allocate doubling headroom past
            # the window budget; a burst that genuinely exceeds it still
            # lands (in-window events are never refused) but is counted
            new = max(self.hot_budget, self._n + need)
            if self._n + need > self.hot_budget:
                self.counters["hot_overflow"] += 1
        names = ["_user", "_item", "_ts"]
        if self._pos is not None:
            names.append("_pos")
        for name in names:
            arr = getattr(self, name)
            out = np.empty(new, arr.dtype)
            out[:self._n] = arr[:self._n]
            setattr(self, name, out)

    def _route_late_locked(self, user: int, item: int, ts: int,
                           pos: int) -> None:
        """Demote one late event (ts below the compaction horizon)
        straight into its window's segment, or drop it past retention.
        Caller holds ``_lock``. Copy-on-write on the segment map so
        captured views stay consistent."""
        if ts < self._retained_floor:
            self.counters["dropped_late"] += 1
            return
        w0 = (ts // self.window) * self.window
        prev = self._segments.get(w0)
        seg = _build_segment(
            w0, w0 + self.window, np.asarray([user], np.int64),
            np.asarray([item], np.int32), np.asarray([ts], np.int64),
            np.asarray([pos], np.int64), self.segment_k, prev=prev)
        self.counters["trimmed"] += seg.trimmed - (prev.trimmed if prev
                                                   else 0)
        self.counters["demoted"] += 1
        new = dict(self._segments)
        new[w0] = seg
        self._segments = new

    def append(self, user: int, item: int, ts: int) -> None:
        if not 0 <= user < self.n_users:
            raise IndexError(f"user {user} out of range [0, {self.n_users})")
        with self._lock:
            p = self._appended
            self._appended = p + 1
            if self._compact_horizon is not None \
                    and ts < self._compact_horizon:
                if self._compacting:
                    # an off-thread build owns the segment map right
                    # now; park the event, installed drains the buffer
                    self._late_buffer.append((int(user), int(item),
                                              int(ts), p))
                else:
                    self._route_late_locked(int(user), int(item),
                                            int(ts), p)
                return
            self._grow(1)
            i = self._n
            self._user[i] = user
            self._item[i] = item
            self._ts[i] = ts
            if self._pos is not None:
                self._pos[i] = p
            self._n = i + 1

    def extend(self, users, items, ts) -> None:
        """Columnar bulk append (parallel arrays)."""
        users = np.asarray(users)
        m = len(users)
        if m == 0:
            return
        if users.min() < 0 or users.max() >= self.n_users:
            raise IndexError(
                f"user ids out of range [0, {self.n_users}): "
                f"[{users.min()}, {users.max()}]")
        items = np.asarray(items)
        ts = np.asarray(ts)
        with self._lock:
            p0 = self._appended
            self._appended = p0 + m
            pos = np.arange(p0, p0 + m, dtype=np.int64)
            if self._compact_horizon is not None:
                late = np.asarray(ts) < self._compact_horizon
                if late.any():
                    for j in np.flatnonzero(late):
                        row = (int(users[j]), int(items[j]), int(ts[j]),
                               int(pos[j]))
                        if self._compacting:
                            self._late_buffer.append(row)
                        else:
                            self._route_late_locked(*row)
                    hot = ~late
                    users, items = users[hot], items[hot]
                    ts, pos = ts[hot], pos[hot]
                    m = len(users)
                    if m == 0:
                        return
            self._grow(m)
            s = self._n
            self._user[s:s + m] = users
            self._item[s:s + m] = items
            self._ts[s:s + m] = ts
            if self._pos is not None:
                self._pos[s:s + m] = pos
            self._n = s + m

    def view(self) -> "LogView":
        """Frozen consistent snapshot of the log for cross-thread reads.

        Captures the column references, the current event count, and
        (tiered) the segment map under the write lock. The log is
        append-only in place — ``_grow`` copies into *fresh* arrays and
        ``compact`` swaps in *fresh* tail arrays and a *fresh* segment
        map (segments themselves are immutable) — so everything captured
        is stable no matter how many appends or compactions race it.
        O(1)-ish — no event data is copied.
        """
        with self._lock:
            # hand over the base index when it covers exactly the
            # captured prefix: _SortedIndex is immutable once built and
            # column prefixes survive _grow by content, so the view can
            # skip its own population-scale lexsort (which would hold
            # the GIL in long numpy sorts, stalling the capturing
            # thread's polls). A stale/partial base just means the view
            # sorts for itself on first materialize.
            base = self._base
            reuse = base if (base is not None
                             and len(base.order) == self._n) else None
            segs = None
            if self.window is not None:
                segs = tuple(sorted(self._segments.values(),
                                    key=lambda s: s.w0))
            return LogView(self._user, self._item, self._ts, self._n,
                           self.n_users, index=reuse, pos=self._pos,
                           segments=segs, appended=self._appended)

    # ------------------------------------------------------------------
    # compaction (tiered only)
    # ------------------------------------------------------------------
    def compaction_due(self, now: int) -> bool:
        """Cheap tick-time poll: has a new window boundary elapsed since
        the last compaction?"""
        if self.window is None:
            return False
        horizon = (int(now) // self.window) * self.window
        return self._compact_horizon is None or horizon > self._compact_horizon

    def _compact_capture(self, now: int, keep_from: Optional[int]
                         ) -> Optional[Dict]:
        """Phase 1 (under lock): snapshot everything the pure build
        phase needs. Marks the log ``_compacting`` so concurrent late
        appends buffer instead of racing the segment-map build."""
        if self.window is None:
            return None
        with self._lock:
            horizon = (int(now) // self.window) * self.window
            if self._compact_horizon is not None \
                    and horizon <= self._compact_horizon:
                return None
            if self._compacting:
                return None
            self._compacting = True
            return {"window": self.window, "horizon": horizon,
                    "floor": horizon - self.retention_windows * self.window,
                    "user": self._user, "item": self._item, "ts": self._ts,
                    "pos": self._pos, "n": self._n,
                    "keep_from": None if keep_from is None
                    else int(keep_from),
                    "hot_budget": self.hot_budget,
                    "segments": self._segments}

    def _compact_abort(self) -> None:
        with self._lock:
            buffered = self._late_buffer
            self._late_buffer = []
            self._compacting = False
            for row in buffered:
                self._route_late_locked(*row)

    def _compact_install(self, built: Dict) -> Dict:
        """Phase 3 (under lock, owner thread): swap in the new tail and
        segment map, carry over any rows appended since the capture, and
        drain late events buffered while the build was in flight."""
        plan = built["plan"]
        with self._lock:
            nu, ni = built["user"], built["item"]
            nt, npos = built["ts"], built["pos"]
            kept = built["kept"]
            extra = self._n - plan["n"]
            if extra > 0:
                # owner-thread appends raced an off-thread build: they
                # live past the captured prefix in the old arrays
                need = kept + extra
                if need > len(nu):
                    def _bigger(a):
                        out = np.empty(need, a.dtype)
                        out[:kept] = a[:kept]
                        return out
                    nu, ni, nt, npos = (_bigger(a) for a in
                                        (nu, ni, nt, npos))
                sl = slice(plan["n"], self._n)
                nu[kept:need] = self._user[sl]
                ni[kept:need] = self._item[sl]
                nt[kept:need] = self._ts[sl]
                npos[kept:need] = self._pos[sl]
                kept = need
            self._user, self._item, self._ts, self._pos = nu, ni, nt, npos
            self._n = kept
            self._segments = built["segments"]
            self._compact_horizon = plan["horizon"]
            self._retained_floor = plan["floor"]
            for key, v in built["counters"].items():
                self.counters[key] += v
            self.counters["compactions"] += 1
            self._base = None
            self._base_n = 0
            self._tail = None
            self._tail_span = (0, 0)
            buffered = self._late_buffer
            self._late_buffer = []
            self._compacting = False
            for row in buffered:
                self._route_late_locked(*row)
        return dict(built["counters"], horizon=plan["horizon"],
                    segments=len(built["segments"]), hot=kept)

    def compact(self, now: int, keep_from: Optional[int] = None,
                step_hook=None) -> Dict:
        """Synchronous compaction: move fully-elapsed windows out of the
        hot tail into per-window segments, evict past retention. No-op
        (empty dict) untiered or when no new window boundary elapsed.
        ``keep_from`` pins append positions ``>= keep_from`` in the tail
        (the trainer's unconsumed suffix). ``step_hook(phase)`` fires at
        phase boundaries — the concurrency batteries' barrier point."""
        plan = self._compact_capture(now, keep_from)
        if plan is None:
            return {}
        try:
            if step_hook:
                step_hook("captured")
            built = _compact_build(plan, self.segment_k)
            if step_hook:
                step_hook("built")
        except BaseException:
            self._compact_abort()
            raise
        out = self._compact_install(built)
        if step_hook:
            step_hook("installed")
        return out

    # ------------------------------------------------------------------
    # index maintenance
    # ------------------------------------------------------------------
    def _rebuild(self) -> None:
        n = self._n
        self._base = _SortedIndex(self._user[:n], self._item[:n],
                                  self._ts[:n])
        self._base_n = n

    def _ensure_base(self, n_queried: int) -> None:
        pending = self._n - self._base_n
        if self._base is None or pending > max(self.MIN_REBUILD,
                                               self._base_n // 8):
            self._rebuild()
        elif pending and n_queried >= max(1024, pending):
            # population-scale read racing a small pending suffix (e.g.
            # run_snapshot right after a serve wave): the merge path's
            # query-sized scratch panes would dwarf one amortized rebuild
            self._rebuild()

    def _tail_index(self) -> _SortedIndex:
        """Sorted index over the pending suffix, cached between writes."""
        span = (self._base_n, self._n)
        if self._tail_span != span:
            p0, n = span
            self._tail = _SortedIndex(self._user[p0:n], self._item[p0:n],
                                      self._ts[p0:n])
            self._tail_span = span
        return self._tail

    def min_ts(self) -> int:
        vals = [seg.ts_min for seg in self._segments.values()]
        if self._n:
            vals.append(int(self._ts[:self._n].min()))
        if not vals:
            raise ValueError("empty log has no min ts")
        return min(vals)

    def _overlapping(self, lo: int, hi: int) -> List[_Segment]:
        if not self._segments:
            return []
        return sorted((s for s in self._segments.values()
                       if s.w0 < hi and s.w1 > lo),
                      key=lambda s: s.w0)

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def ingest_stats(self) -> Dict:
        """Memory + routing counters for GatewayStats: ``bytes_hot`` is
        the tail's allocated footprint, ``bytes_warm`` the segment sum.
        Conservation: ``appended == events_hot + events_warm + trimmed +
        dropped_late + evicted``."""
        segs = list(self._segments.values())
        bytes_hot = (self._user.nbytes + self._item.nbytes
                     + self._ts.nbytes
                     + (self._pos.nbytes if self._pos is not None else 0))
        return dict(self.counters,
                    window=self.window or 0,
                    retention_windows=self.retention_windows,
                    appended=int(self._appended),
                    events_hot=int(self._n),
                    events_warm=int(sum(s.n for s in segs)),
                    segments=len(segs),
                    bytes_hot=int(bytes_hot),
                    bytes_warm=int(sum(s.nbytes for s in segs)))

    # ------------------------------------------------------------------
    # delta queries (the incremental-snapshot backbone)
    # ------------------------------------------------------------------
    def users_with_events(self, lo: int, hi: int, start: int = 0,
                          ) -> np.ndarray:
        """Sorted unique users with >=1 event with ``lo <= ts < hi``
        among the events appended at log positions ``>= start``.

        One vectorized columnar scan over the hot tail — position-
        anchored through the pos column when tiered — plus every
        overlapping warm segment (kept rows scanned by position exactly;
        trimmed rows contribute their recorded superset, see
        ``_Segment.scan_users``). ``start`` lets a caller restrict the
        scan to events appended after a known point (e.g. "since the
        previous snapshot was built"), which is how late-arriving events
        with old timestamps are caught — including ones already demoted
        into a segment.
        """
        start = max(int(start), 0)
        if hi <= lo:
            return np.empty(0, np.int64)
        return _users_with_events(self._user, self._ts, self._pos,
                                  self._n, self._overlapping(lo, hi),
                                  lo, hi, start)

    def changed_users(self, prev_cutoff: int, new_cutoff: int, window: int,
                      since: int = 0) -> np.ndarray:
        """Users whose ``[cutoff - window, cutoff)`` event set may differ
        between snapshot cutoffs ``prev_cutoff`` and ``new_cutoff``:

        * events *entering* by timestamp — ts in ``[prev, new)``;
        * events *aging out* of the lookback window — ts in
          ``[prev - window, new - window)``;
        * *late arrivals* — events appended at log positions ``>= since``
          (pass the log length when the previous snapshot was built) whose
          ts already lands inside the new window: the previous snapshot
          cannot contain them no matter what their timestamp says.

        The result is a **superset** of the truly-changed users (an
        entering event can still materialize to identical features if it
        falls outside the freshest-``feature_len`` cut), which is the safe
        direction: rematerializing an unchanged user is wasted work, not
        wrong output. A user absent from this set has a bitwise-identical
        event window at both cutoffs.
        """
        entering = self.users_with_events(prev_cutoff, new_cutoff)
        aging = self.users_with_events(prev_cutoff - window,
                                       new_cutoff - window)
        late = self.users_with_events(new_cutoff - window, new_cutoff,
                                      start=since)
        return np.union1d(np.union1d(entering, aging), late)

    def user_events(self, user: int) -> List[Tuple[int, int]]:
        """(ts, item) pairs for one user, sorted — debug/compat helper."""
        pairs: List[Tuple[int, int]] = []
        for seg in sorted(self._segments.values(), key=lambda s: s.w0):
            idx = seg.index
            a = np.searchsorted(idx.key, np.int64(user) * idx.scale)
            b = np.searchsorted(idx.key, np.int64(user + 1) * idx.scale)
            rows = idx.order[a:b]
            pairs.extend((int(t), int(i)) for t, i in zip(seg.ts[rows],
                                                          seg.item[rows]))
        if self._n:
            if self._base is None or self._base_n != self._n:
                self._rebuild()
            base = self._base
            a = np.searchsorted(base.key, np.int64(user) * base.scale)
            b = np.searchsorted(base.key, np.int64(user + 1) * base.scale)
            idx = base.order[a:b]
            pairs.extend((int(t), int(i)) for t, i in zip(self._ts[idx],
                                                          self._item[idx]))
        pairs.sort()
        return pairs

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def materialize(self, users, lo: int, hi: int, k: int,
                    ts_dtype=np.int32) -> Features:
        """Freshest ``k`` events with ``lo <= ts < hi`` per requested user,
        right-aligned ascending ``(ts, item)`` into (len(users), k) arrays.
        Composes warm segments with the hot tail when the query window
        reaches below the compaction horizon (exactness contract in the
        module docstring).
        """
        users = np.asarray(users, np.int64).ravel()
        m = len(users)
        items = np.zeros((m, k), np.int32)
        ts_out = np.zeros((m, k), ts_dtype)
        valid = np.zeros((m, k), np.int32)
        if m == 0 or hi <= lo:
            return items, ts_out, valid
        segs = self._overlapping(lo, hi) if self.window is not None else []
        if not segs:
            if self._n == 0:
                return items, ts_out, valid
            self._ensure_base(m)
            a, counts = self._base.window(users, lo, hi, k)
            if self._n == self._base_n:
                # fast path: everything indexed, one scatter
                _scatter_right_aligned(self._base.order, self._item,
                                       self._ts, a, counts, k, items,
                                       ts_out, valid)
                return items, ts_out, valid
            # merge path: sort only the small pending suffix (cached
            # between writes), combine per row
            p0 = self._base_n
            tail = self._tail_index()
            ta, tcounts = tail.window(users, lo, hi, k)
            # scratch pane: base block (<=k) | tail block (<=k), both
            # already (ts, item)-sorted; a row-wise merge-sort keeps
            # exact semantics (only the freshest k of each block can
            # survive the union's cut)
            pane_i = np.zeros((m, 2 * k), np.int64)
            pane_t = np.zeros((m, 2 * k), np.int64)
            pane_v = np.zeros((m, 2 * k), bool)
            _scatter_right_aligned(self._base.order, self._item, self._ts,
                                   a, counts, k, pane_i[:, :k],
                                   pane_t[:, :k], pane_v[:, :k])
            _scatter_right_aligned(tail.order, self._item[p0:self._n],
                                   self._ts[p0:self._n], ta, tcounts, k,
                                   pane_i[:, k:], pane_t[:, k:],
                                   pane_v[:, k:])
            return sort_window_right_align(pane_i, pane_t, pane_v, k,
                                           ts_dtype)
        blocks = [(s.index, s.item, s.ts) for s in segs]
        if self._n:
            self._ensure_base(m)
            blocks.append((self._base, self._item, self._ts))
            if self._n != self._base_n:
                p0 = self._base_n
                tail = self._tail_index()
                blocks.append((tail, self._item[p0:self._n],
                               self._ts[p0:self._n]))
        return _compose_blocks(blocks, users, lo, hi, k, ts_dtype,
                               items, ts_out, valid)


class BackgroundCompactor:
    """Off-thread compaction driver, mirroring the
    ``BackgroundSnapshotBuilder`` worker pattern: ``start(now)`` captures
    the plan under the log's lock and hands the pure build phase to a
    daemon worker; the owner thread calls ``poll()`` from its tick loop
    until the built plan is ready, then installs it atomically (one
    lock-held pointer swap). Worker errors are sticky and re-raised on
    the owner thread at the next ``poll()``."""

    def __init__(self, log: EventLog):
        self.log = log
        self._thread: Optional[threading.Thread] = None
        self._done = threading.Event()
        self._built: Optional[Dict] = None
        self._error: Optional[BaseException] = None
        self._step_hook = None

    @property
    def active(self) -> bool:
        return self._thread is not None

    def start(self, now: int, keep_from: Optional[int] = None,
              step_hook=None) -> bool:
        """Begin an off-thread compaction; False when nothing is due or
        one is already in flight."""
        if self._thread is not None:
            return False
        plan = self.log._compact_capture(now, keep_from)
        if plan is None:
            return False
        self._done.clear()
        self._built = None
        self._error = None
        self._step_hook = step_hook
        self._thread = threading.Thread(
            target=self._work, args=(plan,), daemon=True,
            name="event-log-compactor")
        self._thread.start()
        return True

    def _work(self, plan: Dict) -> None:
        try:
            if self._step_hook:
                self._step_hook("captured")
            self._built = _compact_build(plan, self.log.segment_k)
            if self._step_hook:
                self._step_hook("built")
        except BaseException as e:  # sticky — surfaces at next poll
            self._error = e
        finally:
            self._done.set()

    def poll(self) -> Optional[Dict]:
        """Non-blocking: install the finished build (returns its summary
        dict) or return None while the worker is still running / idle."""
        if self._thread is None or not self._done.is_set():
            return None
        self._thread.join()
        self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            self.log._compact_abort()
            raise RuntimeError("background compaction failed") from err
        built, self._built = self._built, None
        out = self.log._compact_install(built)
        if self._step_hook:
            self._step_hook("installed")
        return out

    def join(self, timeout: Optional[float] = None) -> None:
        t = self._thread
        if t is not None:
            t.join(timeout)


class LogView:
    """Immutable snapshot of an :class:`EventLog` for cross-thread reads
    while the owning thread keeps appending — and, tiered, keeps
    compacting.

    Captured by ``EventLog.view()``: column *references* plus the event
    count ``n`` at capture time, and the segment tuple when tiered.
    Because the log never mutates in place (growth reallocates,
    compaction swaps in fresh arrays and a fresh segment map, segments
    are immutable), nothing captured here can change — so the view needs
    no locking at all. It carries its own private :class:`_SortedIndex`
    (built lazily on first ``materialize``, or handed over by ``view()``
    when the log's base index already covers exactly the captured
    prefix — index objects are immutable once built) instead of touching
    the owning log's cached index *slots*, which are not thread-safe.
    """

    def __init__(self, user, item, ts, n: int, n_users: int,
                 index: _SortedIndex = None, pos=None, segments=None,
                 appended: Optional[int] = None):
        n = int(n)
        self._user = user[:n]
        self._item = item[:n]
        self._ts = ts[:n]
        self._pos = None if pos is None else pos[:n]
        self._segments: Tuple[_Segment, ...] = segments or ()
        self._n = n
        self._appended = int(appended) if appended is not None else n
        self.n_users = int(n_users)
        self._index: _SortedIndex = index

    def __len__(self) -> int:
        return self._n

    @property
    def n_events(self) -> int:
        """Absolute append positions at capture — the anchor a snapshot
        build or trainer cursor records (see ``EventLog.n_events``)."""
        return self._appended

    # same delta-query semantics as EventLog, against the frozen capture
    def users_with_events(self, lo: int, hi: int, start: int = 0,
                          ) -> np.ndarray:
        start = max(int(start), 0)
        if hi <= lo:
            return np.empty(0, np.int64)
        return _users_with_events(self._user, self._ts, self._pos,
                                  self._n, self._segments, lo, hi, start)

    def changed_users(self, prev_cutoff: int, new_cutoff: int, window: int,
                      since: int = 0) -> np.ndarray:
        entering = self.users_with_events(prev_cutoff, new_cutoff)
        aging = self.users_with_events(prev_cutoff - window,
                                       new_cutoff - window)
        late = self.users_with_events(new_cutoff - window, new_cutoff,
                                      start=since)
        return np.union1d(np.union1d(entering, aging), late)

    def events_since(self, start: int = 0,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(user, item, ts)`` columns of the retained events appended
        at positions ``>= start`` within the capture, in append order —
        the online trainer's consume primitive: it remembers the
        position it has trained through and asks each fresh view only
        for the suffix. Untiered this is a zero-copy slice; tiered it
        additionally resurfaces late events already demoted into warm
        segments (merged back into position order), so compaction never
        makes the trainer skip a retained event. Events past retention
        (dropped or trimmed) are the only ones missing — callers can
        count them as ``(n_events - start) - len(returned)``."""
        start = max(int(start), 0)
        if self._pos is None:
            s = min(start, self._n)
            return (self._user[s:], self._item[s:], self._ts[s:])
        i0 = int(np.searchsorted(self._pos, start))
        parts = [(self._user[i0:], self._item[i0:], self._ts[i0:],
                  self._pos[i0:])]
        for seg in self._segments:
            if seg.max_pos >= start:
                m = seg.pos >= start
                parts.append((seg.user[m], seg.item[m], seg.ts[m],
                              seg.pos[m]))
        if len(parts) == 1:
            u, it, t, _ = parts[0]
            return (u, it, t)
        u = np.concatenate([p[0] for p in parts])
        it = np.concatenate([p[1] for p in parts])
        t = np.concatenate([p[2] for p in parts])
        p = np.concatenate([p[3] for p in parts])
        order = np.argsort(p, kind="stable")
        return (u[order], it[order], t[order])

    def materialize(self, users, lo: int, hi: int, k: int,
                    ts_dtype=np.int32) -> Features:
        """Identical output to ``EventLog.materialize`` restricted to the
        capture. The hot block is always the fully-indexed fast path —
        the view is frozen, so there is never a pending suffix to merge;
        tiered, overlapping warm segments compose in exactly as on the
        live log."""
        users = np.asarray(users, np.int64).ravel()
        m = len(users)
        items = np.zeros((m, k), np.int32)
        ts_out = np.zeros((m, k), ts_dtype)
        valid = np.zeros((m, k), np.int32)
        if m == 0 or hi <= lo:
            return items, ts_out, valid
        segs = [s for s in self._segments if s.w0 < hi and s.w1 > lo]
        if not segs:
            if self._n == 0:
                return items, ts_out, valid
            if self._index is None:
                self._index = _SortedIndex(self._user, self._item, self._ts)
            a, counts = self._index.window(users, lo, hi, k)
            _scatter_right_aligned(self._index.order, self._item, self._ts,
                                   a, counts, k, items, ts_out, valid)
            return items, ts_out, valid
        blocks = [(s.index, s.item, s.ts) for s in segs]
        if self._n:
            if self._index is None:
                self._index = _SortedIndex(self._user, self._item, self._ts)
            blocks.append((self._index, self._item, self._ts))
        return _compose_blocks(blocks, users, lo, hi, k, ts_dtype,
                               items, ts_out, valid)
