"""Inference-time feature injection — the paper's contribution (§III-B).

"This approach merges user's batch-updated watch history and the recent
watch history, and then injects them as if it is batch-updated watch
history, while preserving the existing batch-trained model."

``FeatureInjector`` composes the two stores and the merge:

    features(users, now)
        batch  = BatchFeatureStore.lookup(users, now)      # stale, long
        recent = RealtimeFeatureService.lookup(users, now) # fresh, short
        return merge(batch, recent)                        # model-ready

The merge — time-order, dedup-by-item (freshest wins, real-time beats batch
on ties), truncate to feature_len — is the ``history_merge`` op
(kernels/history_merge): Pallas on TPU, jnp oracle on CPU.

Policies (selected per A/B arm):
  * "batch"   — control: batch features passed through untouched.
  * "inject"  — treatment: merged features injected as if batch.
  * "fresh"   — oracle upper bound / latency-ablation λ→0 limit: features
    recomputed from the full log at the request cutoff (no snapshot).
  * "decay"   — model-free recency baseline (Interest Clock, arXiv
    2404.19357): items scored by exponentially time-decayed event
    weights, ``0.5 ** (age / half_life)``, summed per item over the
    user's in-window events. The gateway serves these slates without
    the engine; ``features`` returns the same cutoff-exact features as
    "fresh" so :func:`decay_scores` sees every in-retention event.

The injector also anchors the serving path's cache-key invariant
(serving/scheduler.py): ``generation(now)`` names the snapshot cutoff whose
batch features are serving at ``now``, and everything derived from batch
features — including a user's cached prefill model state — is valid
exactly as long as that generation is. ``fresh_suffix(users, now)``
returns the complement: realtime events the serving snapshot *cannot*
contain (ts >= the generation's cutoff), which is precisely what may be
token-injected on top of a ``(user, generation)``-keyed cached state
without double-counting an event that the snapshot already absorbed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.feature_store import BatchFeatureStore
from repro.core.realtime import RealtimeFeatureService
from repro.kernels.history_merge.ops import history_merge

Features = Tuple[np.ndarray, np.ndarray, np.ndarray]  # items, ts, valid


def decay_scores(feats: Features, now: int, half_life: int,
                 n_items: int) -> np.ndarray:
    """Exponential time-decay item scores from event features.

    ``score[u, item] = sum over u's valid events of 0.5 ** (age /
    half_life)`` with ``age = now - ts`` — the Interest Clock recency
    weighting. Pure numpy on float64 with a fixed accumulation order,
    so identical inputs give bitwise-identical scores: the decay arm's
    slates are deterministic wherever its features are.
    """
    items, ts, valid = feats
    out = np.zeros((len(items), n_items), np.float64)
    r, c = np.nonzero(np.asarray(valid, bool))
    w = 0.5 ** ((now - ts[r, c].astype(np.float64)) / float(half_life))
    np.add.at(out, (r, items[r, c]), w)
    return out


@dataclasses.dataclass(frozen=True)
class InjectionConfig:
    policy: str = "inject"          # batch | inject | fresh | decay
    feature_len: int = 64           # output history length K
    merge_impl: str = "xla"         # xla | pallas | pallas_interpret
    # latency-ablation override: serve features as of (now - staleness)
    # computed directly from the log (policy "stale_cutoff").
    staleness: Optional[int] = None
    # "decay" policy: event half-life in request-clock units (default
    # one day — an event a day old carries half the weight of one now).
    half_life: int = 86400


class FeatureInjector:
    """The serving-path feature assembler for one A/B arm."""

    def __init__(self, cfg: InjectionConfig, batch_store: BatchFeatureStore,
                 realtime: Optional[RealtimeFeatureService]):
        self.cfg = cfg
        self.batch = batch_store
        self.realtime = realtime
        self.merge_calls = 0

    # ------------------------------------------------------------------
    def features(self, users: np.ndarray, now: int) -> Features:
        c = self.cfg
        if c.staleness is not None:
            # latency ablation: an idealized pipeline with refresh latency
            # `staleness` (0 = perfectly fresh).
            return self.batch.lookup_at_cutoff(users, now - c.staleness)
        if c.policy == "batch":
            return self.batch.lookup(users, now)
        if c.policy in ("fresh", "decay"):
            # decay shares the cutoff-exact feature path: its scoring
            # (decay_scores) wants every in-retention event, weighted by
            # age, with no snapshot staleness in the way.
            return self.batch.lookup_at_cutoff(users, now)
        if c.policy == "inject":
            b_items, b_ts, b_valid = self.batch.lookup(users, now)
            r_items, r_ts, r_valid = self.realtime.lookup(users, now)
            return self.merge((b_items, b_ts, b_valid),
                              (r_items, r_ts, r_valid))
        raise ValueError(f"unknown injection policy {c.policy!r}")

    # ------------------------------------------------------------------
    def generation(self, now: int) -> int:
        """Snapshot generation serving at ``now`` (-1 before the first
        snapshot). The serving gateway keys its prefill-state cache on this:
        a rolled generation changes the batch features, so every cached
        batch-history model state built from the old generation is stale."""
        snap = self.batch.latest_snapshot_ts(now)
        return -1 if snap is None else snap

    def fresh_suffix(self, users: np.ndarray, now: int,
                     ) -> List[List[Tuple[int, int]]]:
        """Per-user fresh-event suffixes for incremental (token-level)
        injection: realtime events visible at ``now`` that the serving
        snapshot cannot contain (ts >= snapshot cutoff), ascending time.

        Exact duplicate deliveries — same (item, ts) pair, the realtime
        service's at-least-once redelivery — are dropped; re-watches of an
        item at a *different* ts are kept (they are real events, and token
        injection, unlike the feature-level ``merge``, preserves repeats).
        """
        if self.realtime is None:
            return [[] for _ in range(len(users))]
        cutoff = self.generation(now)
        r_items, r_ts, r_valid = self.realtime.lookup(users, now)
        out: List[List[Tuple[int, int]]] = []
        for row in range(len(users)):
            seen = set()
            evs: List[Tuple[int, int]] = []
            for i, t, v in zip(r_items[row], r_ts[row], r_valid[row]):
                if not v or t < cutoff:
                    continue
                pair = (int(i), int(t))
                if pair in seen:
                    continue
                seen.add(pair)
                evs.append(pair)
            out.append(evs)
        return out

    def fresh_suffix_tokens(self, users: np.ndarray, now: int,
                            cap: Optional[int] = None,
                            ) -> List[List[int]]:
        """Per-user fresh suffixes as **model token** lists — what the
        serving path actually injects on top of a cached prefill state.

        Same visibility/dedup contract as :meth:`fresh_suffix`, with the
        item->token mapping (``core.pipeline.items_to_tokens``) applied
        and, when ``cap`` is given, each suffix truncated to its ``cap``
        *newest* events first — truncating before tokenization is what
        keeps the cached and full-prefill serving paths on identical
        token streams (the engine's ``pad_tokens`` would otherwise clip
        them at different lengths).
        """
        from repro.core.pipeline import items_to_tokens
        out: List[List[int]] = []
        for evs in self.fresh_suffix(users, now):
            if cap is not None:
                evs = evs[-cap:]
            out.append(items_to_tokens(
                np.asarray([item for item, _ in evs], np.int64),
                np.ones(len(evs), np.int64)).tolist())
        return out

    # ------------------------------------------------------------------
    def merge(self, batch: Features, recent: Features) -> Features:
        """merge(batch, recent) -> injected features of length feature_len."""
        self.merge_calls += 1
        args = [jnp.asarray(a) for a in (*batch, *recent)]
        oi, ot, ov = history_merge(*args, out_len=self.cfg.feature_len,
                                   impl=self.cfg.merge_impl)
        return np.asarray(oi), np.asarray(ot), np.asarray(ov)
