"""Rotary position embeddings (llama-style rotate-half)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,), fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions``.

    ``positions`` broadcasts against the leading dims of x up to ``seq``:
    typically (seq,) or (batch, seq).
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, hd/2) broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
