"""Modality frontend STUBS (the one permitted carve-out, see DESIGN.md §4).

[vlm]/[audio] architectures specify the transformer backbone only; the
frontends here produce *correctly-shaped* precomputed embeddings / codec
tokens so examples and smoke tests are runnable end to end without a real
ViT or EnCodec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# llava-next anyres tiling: a 672x672 image -> 4 tiles + base = 5 * 576
VISION_PATCHES_PER_IMAGE = 2880
# EnCodec at 50 Hz frames
AUDIO_FRAMES_PER_SECOND = 50


def frontend_prefix_len(cfg: ModelConfig, seq_len: int) -> int:
    """How many positions of an input of ``seq_len`` are frontend embeds."""
    if cfg.frontend == "vision":
        return min(VISION_PATCHES_PER_IMAGE, seq_len // 2)
    if cfg.frontend == "audio":
        # musicgen conditions on a text/melody prompt embedding prefix
        return min(64, seq_len // 8)
    return 0


def vision_stub_embeds(rng, batch: int, n_patches: int, d_model: int,
                       dtype=jnp.bfloat16):
    """Stand-in for SigLIP/ViT + projector output (patch embeddings)."""
    return jax.random.normal(rng, (batch, n_patches, d_model), jnp.float32
                             ).astype(dtype) * 0.02


def audio_stub_embeds(rng, batch: int, n_frames: int, d_model: int,
                      dtype=jnp.bfloat16):
    """Stand-in for the conditioning encoder output (T5/melody features)."""
    return jax.random.normal(rng, (batch, n_frames, d_model), jnp.float32
                             ).astype(dtype) * 0.02


def encodec_stub_tokens(rng, batch: int, n_frames: int, vocab: int = 2048):
    """Stand-in for EnCodec RVQ codes (single-stream, per assignment)."""
    return jax.random.randint(rng, (batch, n_frames), 0, vocab, jnp.int32)
