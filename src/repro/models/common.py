"""Shared model utilities: initializers, dtype policy, sharding helpers."""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree of jnp arrays

DEFAULT_PARAM_DTYPE = jnp.bfloat16
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


def normal_init(key, shape, std, dtype=DEFAULT_PARAM_DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def scaled_init(key, shape, fan_in, dtype=DEFAULT_PARAM_DTYPE):
    return normal_init(key, shape, 1.0 / np.sqrt(max(fan_in, 1)), dtype)


def zeros(shape, dtype=DEFAULT_PARAM_DTYPE):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=DEFAULT_PARAM_DTYPE):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Split-on-demand PRNG key dispenser for init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def shard_hint(x, spec: Optional[Tuple]) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; no-op otherwise.

    ``spec`` is a raw PartitionSpec-compatible tuple whose entries are mesh
    axis names (already resolved from logical names by the caller).
    """
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - old jax fallback
        mesh = None
    if mesh is None or mesh.empty:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def tree_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
