"""RMSNorm (scale-only), computed in fp32 for stability."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, ones


def init_rmsnorm(keys: KeyGen, dim: int, dtype=jnp.bfloat16):
    del keys
    return {"scale": ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)
