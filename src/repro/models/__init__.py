from repro.models.model import (  # noqa: F401
    block_pattern, cache_from_prefill, cache_shapes, decode_step, extend,
    forward, init_cache, init_params, param_shapes, pattern_sig, prefill,
)
