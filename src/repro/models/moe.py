"""Mixture-of-Experts MLP with sort-based capacity dispatch.

TPU adaptation (DESIGN.md §5): no ragged ops — tokens are routed into
fixed-capacity per-expert buffers via an argsort permutation (MegaBlocks/
MaxText "dropping" style), computed **per batch row** so routing stays local
to the data shard (no cross-device all-to-all in the baseline; expert
parallelism over an explicit axis is a perf-iteration variant).

FLOPs are proportional to E·C = S·top_k·capacity_factor — i.e. faithful to
the *active* parameter count, which is what the roofline compares against.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, cdiv, scaled_init

def init_moe(kg: KeyGen, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        "router": scaled_init(kg(), (d, e), d, jnp.float32),
        "gate": scaled_init(kg(), (e, d, f), d, dtype),
        "up": scaled_init(kg(), (e, d, f), d, dtype),
        "down": scaled_init(kg(), (e, f, d), f, dtype),
    }


def moe_capacity(seq: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    return max(1, cdiv(int(seq * m.top_k * m.capacity_factor), m.n_experts))


def moe_apply(params, x, cfg: ModelConfig, *, rng=None, moe_sharding=None,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), load-balance aux loss scalar).

    ``moe_sharding``: optional (up_sharding, down_sharding) NamedShardings
    applied to the expert weights at USE time. Perf iteration (§Perf,
    mixtral train): with FSDP-stored expert weights (d over dp) GSPMD
    contracted over the sharded d and all-reduced 10 GiB (b,e,c,f) partial
    products per layer; constraining the weights to (experts, ·, tp) here
    forces the FSDP idiom instead — all-gather the (much smaller) weights
    once per layer, compute locally.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    c = moe_capacity(s, cfg)
    gate_w, up_w, down_w = params["gate"], params["up"], params["down"]
    ep_split = 0
    if isinstance(moe_sharding, tuple) and moe_sharding[0] == "ep":
        # all-to-all expert parallelism with f-splitting (§Perf, mixtral):
        # e experts × m f-shards = tp "expert-shards"; dispatch moves to
        # expert-sharded layout via all-to-all, compute is fully local,
        # and the f-shard partials psum over groups of m.
        _, ep_sharding, ep_split = moe_sharding
    elif isinstance(moe_sharding, tuple):
        up_sh, down_sh = moe_sharding
        gate_w = jax.lax.with_sharding_constraint(gate_w, up_sh)
        up_w = jax.lax.with_sharding_constraint(up_w, up_sh)
        down_w = jax.lax.with_sharding_constraint(down_w, down_sh)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    if rng is not None and m.router_jitter > 0:
        logits = logits + m.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B,S,K)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch, per batch row --------------------------
    sk = s * k
    e_flat = top_e.reshape(b, sk)
    order = jnp.argsort(e_flat, axis=-1)  # stable
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    # position within each expert's run of the sorted id list
    idx = jnp.arange(sk, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=-1)
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_in_expert = idx - run_start
    dropped = pos_in_expert >= c
    dest = jnp.where(dropped, e * c, sorted_e * c + pos_in_expert)  # overflow bin

    tok_idx = order // k  # (B, SK) source token of each sorted (token,k) pair
    x_sorted = jnp.take_along_axis(x, tok_idx[..., None], axis=1)  # (B,SK,d)

    buf = jnp.zeros((b, e * c + 1, d), x.dtype)
    brow = jnp.arange(b, dtype=jnp.int32)[:, None]
    buf = buf.at[brow, dest].set(x_sorted, mode="drop")
    expert_in = buf[:, : e * c].reshape(b, e, c, d)

    # ---- per-expert SwiGLU -------------------------------------------
    if ep_split:
        ns, fm = ep_split, cfg.d_ff // ep_split
        xin = jnp.repeat(expert_in, ns, axis=1)  # (b, e*ns, c, d)
        if ep_sharding is not None:  # None = single-device math test
            xin = jax.lax.with_sharding_constraint(xin, ep_sharding)
        # weights (e,d,f) -> (e*ns, d, f/ns) expert-shards
        gw = gate_w.reshape(e, d, ns, fm).transpose(0, 2, 1, 3).reshape(
            e * ns, d, fm)
        uw = up_w.reshape(e, d, ns, fm).transpose(0, 2, 1, 3).reshape(
            e * ns, d, fm)
        dw = down_w.reshape(e, ns, fm, d).reshape(e * ns, fm, d)
        g = jnp.einsum("bEcd,Edf->bEcf", xin, gw)
        u = jnp.einsum("bEcd,Edf->bEcf", xin, uw)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        part = jnp.einsum("bEcf,Efd->bEcd", h, dw)
        expert_out = part.reshape(b, e, ns, c, d).sum(axis=2)
    else:
        g = jnp.einsum("becd,edf->becf", expert_in, gate_w)
        u = jnp.einsum("becd,edf->becf", expert_in, up_w)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        expert_out = jnp.einsum("becf,efd->becd", h, down_w)

    # ---- gather back + combine ---------------------------------------
    flat = jnp.concatenate(
        [expert_out.reshape(b, e * c, d), jnp.zeros((b, 1, d), x.dtype)], axis=1)
    y_sorted = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (B,SK,d)
    y_flat = jnp.zeros((b, sk, d), x.dtype).at[brow, order].set(y_sorted)
    y = (y_flat.reshape(b, s, k, d) *
         top_w[..., None].astype(x.dtype)).sum(axis=2)

    # ---- Switch-style load balance loss ------------------------------
    assign = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)  # top-1 fraction
    f_e = assign.mean(axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e) * m.load_balance_coef
    return y, aux
