"""Mamba2 block: state-space duality (SSD) chunked scan.

Follows the discrete SSD formulation of [arXiv:2405.21060]: per head h with
head state (head_dim, d_state),

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = C_t · h_t + D * x_t

computed chunkwise — an intra-chunk quadratic ("attention-like") term plus an
inter-chunk recurrent state pass (``lax.scan`` over chunks). The Pallas
``ssd_scan`` kernel implements the same contraction with the state carried in
VMEM scratch across a sequential grid dimension.

Sharding: heads (= d_inner/head_dim) shard over the model axis; B/C are
shared across heads (single group), replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import KeyGen, normal_init, scaled_init, zeros


def init_ssm(kg: KeyGen, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    s = cfg.ssm
    d, din, nh, ds, cw = (cfg.d_model, cfg.d_inner, cfg.n_ssm_heads,
                          s.d_state, s.conv_width)
    # dt bias initialized so softplus(dt_bias) spans [dt_min, dt_max]
    import numpy as np
    u = np.random.RandomState(0).uniform(size=(nh,))
    dt_init = s.dt_min * (s.dt_max / s.dt_min) ** u
    dt_bias = np.log(np.expm1(dt_init))
    return {
        "wz": scaled_init(kg(), (d, din), d, dtype),
        "wx": scaled_init(kg(), (d, din), d, dtype),
        "wB": scaled_init(kg(), (d, ds), d, dtype),
        "wC": scaled_init(kg(), (d, ds), d, dtype),
        "wdt": scaled_init(kg(), (d, nh), d, dtype),
        "conv_x": normal_init(kg(), (cw, din), 0.2, dtype),
        "conv_B": normal_init(kg(), (cw, ds), 0.2, dtype),
        "conv_C": normal_init(kg(), (cw, ds), 0.2, dtype),
        "conv_bias_x": zeros((din,), dtype),
        "conv_bias_B": zeros((ds,), dtype),
        "conv_bias_C": zeros((ds,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out_proj": scaled_init(kg(), (din, d), din, dtype),
    }


def _causal_conv(x, w, b, tail=None, valid=None):
    """Depthwise causal conv. x (B,S,C), w (cw,C), b (C,).

    ``tail`` (B,cw-1,C): state from the previous segment (decode/continuation);
    zeros if None. Returns (y (B,S,C), new_tail (B,cw-1,C)).

    ``valid`` (B,S) bool — assumed a contiguous run per row (right-aligned
    prefill or left-aligned inject suffix). The new tail is gathered per
    row so it ends at the row's LAST REAL token; rows with no valid tokens
    pass the incoming tail through unchanged.
    """
    bsz, s, c = x.shape
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((bsz, cw - 1, c), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+cw-1, C)
    y = sum(xp[:, i:i + s] * w[i] for i in range(cw)) + b
    if valid is None:
        new_tail = xp[:, s: s + cw - 1]
    else:
        last = jnp.max(jnp.where(valid, jnp.arange(s)[None, :], -1), axis=-1)
        idx = last[:, None] + 1 + jnp.arange(cw - 1)[None, :]  # xp coords
        new_tail = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_tail


def _segsum(x):
    """(..., Q) -> (..., Q, Q) lower-triangular cumulative segment sums:
    out[i, j] = sum_{j < t <= i} x[t], -inf above diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int, init_state=None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan (pure-jnp reference / XLA path).

    x (b,s,nh,hp); dt (b,s,nh) post-softplus; A (nh,) negative;
    B,C (b,s,ds); D (nh,). Returns (y (b,s,nh,hp), final_state (b,nh,hp,ds)).
    """
    b, s, nh, hp = x.shape
    ds = B.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def r(t):  # (b,s,...) -> (b,nc,chunk,...)
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc, dtc, Bc, Cc = r(xf), r(dtf), r(Bf), r(Cf)
    dA = dtc * A[None, None, None, :]  # (b,nc,Q,nh)
    dA_cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # ---- intra-chunk (quadratic) term --------------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, 3)))  # (b,nc,nh,Q,Q)
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)  # (b,nc,Q,Q)
    M = scores[:, :, None] * L  # (b,nc,nh,Q,Q)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M, dtc, xc)

    # ---- chunk states + inter-chunk recurrence ------------------------
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,Q,nh)
    chunk_states = jnp.einsum(
        "bcjs,bcjh,bcjhp->bchps", Bc, dtc * decay_to_end, xc)  # (b,nc,nh,hp,ds)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (b,nc,nh)

    h0 = (jnp.zeros((b, nh, hp, ds), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        st, dec = inp  # (b,nh,hp,ds), (b,nh)
        h_out = h  # state *entering* this chunk
        h_new = dec[:, :, None, None] * h + st
        return h_new, h_out

    sc = jnp.moveaxis(chunk_states, 1, 0)  # (nc,b,nh,hp,ds)
    dc = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,b,nh)
    h_final, h_in = jax.lax.scan(step, h0, (sc, dc))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (b,nc,nh,hp,ds) state entering chunk

    decay_from_start = jnp.exp(dA_cum)  # (b,nc,Q,nh)
    y_inter = jnp.einsum("bcis,bcih,bchps->bcihp", Cc, decay_from_start, h_in)

    y = (y_intra + y_inter + D[None, None, None, :, None] * xc)
    return y.reshape(b, s, nh, hp).astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, B, C, D, state):
    """Single-token state update. x (b,nh,hp); dt (b,nh); B,C (b,ds);
    state (b,nh,hp,ds). Returns (y (b,nh,hp), new_state)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    a = jnp.exp(dtf * A[None, :])  # (b,nh)
    upd = jnp.einsum("bh,bhp,bs->bhps", dtf, xf, Bf)
    new_state = a[:, :, None, None] * state + upd
    y = jnp.einsum("bs,bhps->bhp", Cf, new_state) + D[None, :, None] * xf
    return y.astype(x.dtype), new_state


def _gated_norm(y, z, scale, eps):
    """Mamba2 gated RMSNorm: rmsnorm(y * silu(z)) * scale."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def ssm_forward(params, x, cfg: ModelConfig, *, cache=None, use_kernel=False,
                valid=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence Mamba2 mixer. x (B,S,d) -> (y (B,S,d), cache).

    cache = {"conv_x","conv_B","conv_C" tails, "state"} — returned so a
    decode session (or an injection suffix-continuation) can resume.

    ``valid`` (B,S) bool: padding positions become *identity* steps in the
    recurrence (dt forced to 0 ⇒ no decay, no state update), so left-padded
    batches do not contaminate the state. Pad-position outputs are garbage
    and must be masked by the caller (the loss / logits gather does).
    """
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    nh, hp, ds = cfg.n_ssm_heads, s_cfg.head_dim, s_cfg.d_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xr = jnp.einsum("bsd,de->bse", x, params["wx"])
    Br = jnp.einsum("bsd,de->bse", x, params["wB"])
    Cr = jnp.einsum("bsd,de->bse", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"])

    if valid is not None:
        # zero pad inputs so they can't leak through the causal conv window
        vm = valid[..., None].astype(xr.dtype)
        xr, Br, Cr = xr * vm, Br * vm, Cr * vm

    tails = cache or {}
    xr, tail_x = _causal_conv(xr, params["conv_x"], params["conv_bias_x"],
                              tails.get("conv_x"), valid)
    Br, tail_B = _causal_conv(Br, params["conv_B"], params["conv_bias_B"],
                              tails.get("conv_B"), valid)
    Cr, tail_C = _causal_conv(Cr, params["conv_C"], params["conv_bias_C"],
                              tails.get("conv_C"), valid)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if valid is not None:
        dt = dt * valid[..., None].astype(jnp.float32)  # identity steps
    A = -jnp.exp(params["A_log"])
    xh = xr.reshape(b, s, nh, hp)

    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, h_final = ssd_ops.ssd_scan(
            xh, dt, A, Br, Cr, params["D"], chunk=s_cfg.chunk_size,
            init_state=tails.get("state"))
    else:
        y, h_final = ssd_chunked(xh, dt, A, Br, Cr, params["D"],
                                 chunk=min(s_cfg.chunk_size, s),
                                 init_state=tails.get("state"))

    y = y.reshape(b, s, -1)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y.reshape(b * s, -1),
                     params["out_proj"]).reshape(b, s, -1)
    cache_out = {"conv_x": tail_x, "conv_B": tail_B, "conv_C": tail_C,
                 "state": h_final}
    return out, cache_out


def ssm_decode(params, x, cache, cfg: ModelConfig,
               ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode. x (B,1,d); cache from ssm_forward/init_ssm_cache."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    nh, hp = cfg.n_ssm_heads, s_cfg.head_dim
    x1 = x[:, 0]

    z = x1 @ params["wz"]
    xr = x1 @ params["wx"]
    Br = x1 @ params["wB"]
    Cr = x1 @ params["wC"]
    dt = x1 @ params["wdt"]

    def conv_step(tail, new, w, bias):
        # tail (B,cw-1,C), new (B,C)
        window = jnp.concatenate([tail, new[:, None]], axis=1)  # (B,cw,C)
        y = jnp.einsum("bwc,wc->bc", window, w) + bias
        return jax.nn.silu(y.astype(jnp.float32)).astype(new.dtype), window[:, 1:]

    xr, tail_x = conv_step(cache["conv_x"], xr, params["conv_x"], params["conv_bias_x"])
    Br, tail_B = conv_step(cache["conv_B"], Br, params["conv_B"], params["conv_bias_B"])
    Cr, tail_C = conv_step(cache["conv_C"], Cr, params["conv_C"], params["conv_bias_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_state = ssd_decode_step(
        xr.reshape(b, nh, hp), dt, A, Br, Cr, params["D"], cache["state"])

    y = y.reshape(b, -1)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv_x": tail_x, "conv_B": tail_B, "conv_C": tail_C,
                 "state": new_state}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    return {
        "conv_x": zeros((batch, s.conv_width - 1, cfg.d_inner), dtype),
        "conv_B": zeros((batch, s.conv_width - 1, s.d_state), dtype),
        "conv_C": zeros((batch, s.conv_width - 1, s.d_state), dtype),
        "state": jnp.zeros((batch, cfg.n_ssm_heads, s.head_dim, s.d_state),
                           jnp.float32),
    }
