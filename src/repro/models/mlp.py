"""SwiGLU MLP."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, scaled_init


def init_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    return {
        "gate": scaled_init(kg(), (d_model, d_ff), d_model, dtype),
        "up": scaled_init(kg(), (d_model, d_ff), d_model, dtype),
        "down": scaled_init(kg(), (d_ff, d_model), d_ff, dtype),
    }


def mlp(params, x):
    g = jnp.einsum("...d,df->...f", x, params["gate"])
    u = jnp.einsum("...d,df->...f", x, params["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["down"])
