"""GQA attention: chunked causal prefill + ring-buffer KV-cache decode.

Design notes (TPU adaptation, see DESIGN.md §5/§6):

* The XLA path never materializes the full (S, S) score matrix: prefill
  scans over query chunks, bounding live memory at (chunk_q, S) fp32 scores
  per (batch, head) shard. The Pallas ``flash_attention`` kernel is the
  TPU-target implementation of the same contraction; ``impl="pallas"``
  routes through it (interpret=True on CPU in tests).
* GQA is computed in FLAT-head form: KV heads are repeated to n_heads
  before the contraction (``_repeat_kv``). Under tensor parallelism the
  repeat is a local per-shard slice (Megatron-style KV replication inside
  the TP group) — the grouped (nkv, g) factorization is NOT partitionable
  when nkv < tp and made GSPMD replicate 32k-seq score tensors. The Pallas
  kernels keep the grouped form (single-device VMEM tiling, where it IS
  the right shape).
* Sliding-window attention uses a **ring-buffer KV cache of capacity =
  window**; full attention uses capacity = max_seq. Keys are stored
  RoPE-rotated at write time, so ring overwrite needs no re-rotation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, scaled_init, zeros
from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_attention(kg: KeyGen, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, nq, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p = {
        "wq": scaled_init(kg(), (d, nq, hd), d, dtype),
        "wk": scaled_init(kg(), (d, nkv, hd), d, dtype),
        "wv": scaled_init(kg(), (d, nkv, hd), d, dtype),
        "wo": scaled_init(kg(), (nq, hd, d), nq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((nq, hd), dtype)
        p["bk"] = zeros((nkv, hd), dtype)
        p["bv"] = zeros((nkv, hd), dtype)
    return p


def _project_qkv(params, x, positions, cfg: ModelConfig):
    """x (B,S,d) -> q (B,S,nq,hd), k/v (B,S,nkv,hd); q,k RoPE-rotated."""
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, g):
    """(B,S,nkv,hd) -> (B,S,nq,hd) by repeating each KV head g times.

    Flat-head layout on purpose: the grouped (nkv, g) factorization cannot
    be expressed to GSPMD when nkv < tp (it replicated 32k-seq score
    tensors — observed 54 GiB/device). With flat heads sharded over tp the
    repeat lowers to a local slice per shard (Megatron-style KV-head
    replication within the TP group).
    """
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _attend_chunk(q_chunk, k, v, mask, scale):
    """q_chunk (B,cq,nq,hd) · k/v (B,S,nq,hd) -> (B,cq,nq,hd).

    mask (B, cq, S) boolean: True = attendable.
    """
    # f32 accumulation WITHOUT materializing f32 copies of K in HBM (an
    # .astype(f32) on the output makes XLA upcast the operands instead —
    # observed as full-cache f32 conversions per decode step)
    scores = jnp.einsum("bqnh,bsnh->bnqs", q_chunk, k,
                        preferred_element_type=jnp.float32)
    scores = scores * scale
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqs,bsnh->bqnh", probs.astype(v.dtype), v)
    return out


def _pad_heads(t, target: int):
    """Pad the head axis (B,S,H,hd) with zero heads up to ``target``.

    Perf iteration (EXPERIMENTS.md §Perf, llava/granite): head counts that
    don't divide tp (56, 24 vs 16) force head_dim-sharded attention whose
    score contraction psums (B,S,S)-sized tensors — padding to the next
    multiple of tp makes heads shardable. Zero q-heads produce garbage
    rows that are sliced off before the output projection; +tp/H extra
    attention FLOPs (<15%), zero extra parameters.
    """
    h = t.shape[2]
    if h == target:
        return t
    return jnp.pad(t, ((0, 0), (0, 0), (0, target - h), (0, 0)))


def attention_full(params, x, positions, cfg: ModelConfig, *,
                   valid: Optional[jnp.ndarray] = None,
                   prefix_kv: Optional[Dict[str, Any]] = None,
                   prefix_positions: Optional[jnp.ndarray] = None,
                   prefix_valid: Optional[jnp.ndarray] = None,
                   q_chunk: int = 512, head_pad_to: int = 0,
                   attn_sharding=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Causal (optionally sliding-window) self-attention over a full sequence.

    Returns (output (B,S,d), kv dict {"k","v"} each (B,S,nkv,hd)) — the kv
    dict seeds a decode cache after prefill.

    ``prefix_kv``: already-computed K/V of a cached prefix (B,Sp,nkv,hd) —
    the *incremental prefill* path used by inference-time feature injection:
    only the injected suffix is recomputed, queries attend to prefix+suffix.
    The returned kv dict covers prefix+suffix.
    """
    b, s, d = x.shape
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    g = cfg.n_heads // nkv
    scale = hd ** -0.5
    q, k, v = _project_qkv(params, x, positions, cfg)

    qpos_full = positions  # (B,S) or (S,)
    if qpos_full.ndim == 1:
        qpos_full = jnp.broadcast_to(qpos_full[None, :], (b, s))
    kpos = qpos_full
    kvalid = valid if valid is not None else jnp.ones((b, s), bool)

    if prefix_kv is not None:
        sp = prefix_kv["k"].shape[1]
        k = jnp.concatenate([prefix_kv["k"], k], axis=1)
        v = jnp.concatenate([prefix_kv["v"], v], axis=1)
        ppos = (prefix_positions if prefix_positions is not None
                else jnp.broadcast_to(jnp.arange(sp, dtype=jnp.int32)[None], (b, sp)))
        kpos = jnp.concatenate([ppos, kpos], axis=1)
        pval = (prefix_valid if prefix_valid is not None
                else jnp.ones((b, sp), bool))
        kvalid = jnp.concatenate([pval, kvalid], axis=1)

    n_chunks = max(1, s // q_chunk) if s % q_chunk == 0 else -1
    if n_chunks == -1 or s <= q_chunk:
        # small / non-divisible sequences: single chunk
        q_chunk, n_chunks = s, 1

    nq = cfg.n_heads
    h_pad = nq
    if head_pad_to and nq % head_pad_to:
        h_pad = ((nq + head_pad_to - 1) // head_pad_to) * head_pad_to
    k_rep = _pad_heads(_repeat_kv(k, g), h_pad)
    v_rep = _pad_heads(_repeat_kv(v, g), h_pad)
    q = _pad_heads(q, h_pad)
    if attn_sharding is not None:
        q = jax.lax.with_sharding_constraint(q, attn_sharding)
        k_rep = jax.lax.with_sharding_constraint(k_rep, attn_sharding)
        v_rep = jax.lax.with_sharding_constraint(v_rep, attn_sharding)

    def body(carry, idx):
        del carry
        start = idx * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, start, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos_full, start, q_chunk, axis=1)
        mask = qp[:, :, None] >= kpos[:, None, :]  # causal
        if cfg.sliding_window:
            mask &= (qp[:, :, None] - kpos[:, None, :]) < cfg.sliding_window
        mask &= kvalid[:, None, :]
        out = _attend_chunk(qc, k_rep, v_rep, mask, scale)
        return None, out

    if n_chunks == 1:
        _, out = body(None, jnp.int32(0))
    else:
        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks, dtype=jnp.int32))
        # outs: (n_chunks, B, cq, H, hd) -> (B, S, H, hd)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h_pad, hd)

    out = out[:, :, :nq]  # drop zero pad heads
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}


# ----------------------------------------------------------------------
# Decode path: ring-buffer KV cache
# ----------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int,
                  dtype=jnp.bfloat16) -> Dict[str, Any]:
    """One attention layer's cache. ``capacity`` = window size for SWA archs,
    max context otherwise. ``valid`` marks slots holding *real* tokens —
    left-padded prefills seed it False on pad slots (default all-True is
    correct for both fresh sessions, where the position logic gates, and
    the dry-run's notionally-full caches)."""
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": zeros((batch, capacity, nkv, hd), dtype),
        "v": zeros((batch, capacity, nkv, hd), dtype),
        "valid": jnp.ones((batch, capacity), bool),
    }


def cache_from_prefill(kv: Dict[str, Any], capacity: int,
                       valid: Optional[jnp.ndarray] = None) -> Dict[str, Any]:
    """Seed a decode cache from prefill K/V (keeps the trailing window if the
    prefill is longer than capacity). ``valid`` (B,S): prefill pad mask."""
    k, v = kv["k"], kv["v"]
    b, s = k.shape[:2]
    if valid is None:
        valid = jnp.ones((b, s), bool)
    else:
        # COMPACT per row (valid slots to the front, original order kept):
        # decode validity gates on ``slot <= pos`` and the decode position
        # can exceed the number of real entries after padded injection
        # segments — compaction guarantees every real entry sits below it.
        # (Attention is slot-order-agnostic: keys carry their RoPE rotation.)
        perm = jnp.argsort(~valid, axis=1, stable=True)
        k = jnp.take_along_axis(k, perm[:, :, None, None], axis=1)
        v = jnp.take_along_axis(v, perm[:, :, None, None], axis=1)
        valid = jnp.take_along_axis(valid, perm, axis=1)
    if s >= capacity:
        # ring layout: entry at slot (pos % capacity); after s tokens the
        # slots hold positions [s-capacity, s). Reconstruct that layout.
        shift = s % capacity
        return {"k": jnp.roll(k[:, s - capacity:], shift, axis=1),
                "v": jnp.roll(v[:, s - capacity:], shift, axis=1),
                "valid": jnp.roll(valid[:, s - capacity:], shift, axis=1)}
    pad = capacity - s
    return {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "valid": jnp.pad(valid, ((0, 0), (0, pad))),
    }


def _ring_write(cache_row, new_row, slot):
    """cache_row (W, nkv, hd), new_row (nkv, hd), slot scalar int32."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache_row, new_row[None], slot, axis=0)


def attention_decode(params, x, pos, cache, cfg: ModelConfig,
                     ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode step.

    x (B,1,d); pos (B,) int32 — number of tokens already in context (the new
    token's absolute position); cache {"k","v"} (B,W,nkv,hd).
    Returns (out (B,1,d), updated cache).
    """
    b, one, d = x.shape
    assert one == 1
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    g = cfg.n_heads // nkv
    w = cache["k"].shape[1]
    scale = hd ** -0.5

    q, k_new, v_new = _project_qkv(params, x, pos[:, None], cfg)
    slot = (pos % w).astype(jnp.int32)
    stored = cache.get("valid")
    if stored is None:
        stored = jnp.ones((b, w), bool)
    if jax.default_backend() == "cpu":
        # XLA CPU lowers the batched per-row dynamic-update (a scatter)
        # into a SEQUENTIAL while loop over rows, copying whole cache rows
        # per iteration — the dominant decode cost at serving batch sizes,
        # and a cross-row serialization that also defeats data-parallel
        # meshes. A one-hot blend writes identical values as one
        # vectorized op. TPU keeps the native scatter: its write is O(1)
        # per row while the blend would re-stream the whole cache.
        hit = (jnp.arange(w, dtype=jnp.int32)[None, :] == slot[:, None])
        k = jnp.where(hit[:, :, None, None], k_new[:, :1], cache["k"])
        v = jnp.where(hit[:, :, None, None], v_new[:, :1], cache["v"])
        stored = stored | hit
    else:
        k = jax.vmap(_ring_write)(cache["k"], k_new[:, 0], slot)
        v = jax.vmap(_ring_write)(cache["v"], v_new[:, 0], slot)
        stored = jax.vmap(
            lambda row, s: jax.lax.dynamic_update_slice_in_dim(
                row, jnp.ones((1,), bool), s, axis=0))(stored, slot)

    # validity: slot i holds a token iff i <= pos (ring: all slots once full)
    idx = jnp.arange(w, dtype=jnp.int32)[None, :]  # (1,W)
    valid = idx <= pos[:, None]
    # after ring wrap every slot is valid:
    valid = valid | (pos[:, None] >= w)
    valid &= stored  # left-padded prefill slots stay masked

    k_rep = _repeat_kv(k, g)  # (B,W,nq,hd) — local slice under TP
    v_rep = _repeat_kv(v, g)
    scores = jnp.einsum("bqnh,bsnh->bnqs", q, k_rep,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqs,bsnh->bqnh", probs.astype(v.dtype), v_rep)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return y, {"k": k, "v": v, "valid": stored}
