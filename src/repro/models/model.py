"""Unified decoder-only model over any ``ModelConfig``.

Layer-stack execution uses **pattern scan**: the per-layer (mixer, mlp) kind
sequence of every assigned arch is periodic — period 1 for uniform stacks,
period 8 for Jamba's attn:mamba 1:7 interleave — so parameters are stored
stacked as ``blocks["pos{p}"]`` with leading dim R = n_layers / P and the
stack runs as a single ``lax.scan`` over R repeats (compile time stays flat
in depth: deepseek-67b's 95 layers lower as 1 scan, not 95 inlined blocks).

Three entry points:
  * ``forward``      — full-sequence logits (training / scoring)
  * ``prefill``      — full-sequence + returns a decode cache
  * ``decode_step``  — ONE token against the cache (serving)

The decode cache is a dict ``{"pos{p}": layer_cache}`` whose leaves carry a
leading R dim; attention layers hold ring-buffer K/V, SSM layers hold
(conv tails, recurrent state). This same cache is what the ITFI serving
engine snapshots for the *batch* feature state and advances incrementally
when fresh events are injected (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import KeyGen, normal_init
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_apply
from repro.models.norms import init_rmsnorm, rmsnorm

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Layer pattern
# ----------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> int:
    """Smallest period P with n_layers % P == 0 and kinds[i] == kinds[i % P]."""
    sig = list(zip(cfg.layer_kinds(), cfg.mlp_kinds()))
    n = cfg.n_layers
    for p in range(1, n + 1):
        if n % p == 0 and all(sig[i] == sig[i % p] for i in range(n)):
            return p
    return n


def pattern_sig(cfg: ModelConfig):
    p = block_pattern(cfg)
    sig = list(zip(cfg.layer_kinds(), cfg.mlp_kinds()))
    return sig[:p]


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _init_layer(kg: KeyGen, cfg: ModelConfig, kind: str, mlp_kind: str,
                dtype) -> Dict[str, Any]:
    p: Dict[str, Any] = {"norm1": init_rmsnorm(kg, cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(kg, cfg, dtype)
    else:
        p["ssm"] = ssm_mod.init_ssm(kg, cfg, dtype)
    if mlp_kind != "none":
        p["norm2"] = init_rmsnorm(kg, cfg.d_model, dtype)
    if mlp_kind == "dense":
        p["mlp"] = init_mlp(kg, cfg.d_model, cfg.d_ff, dtype)
    elif mlp_kind == "moe":
        p["moe"] = init_moe(kg, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, rng, dtype=jnp.bfloat16) -> Dict[str, Any]:
    kg = KeyGen(rng)
    pat = pattern_sig(cfg)
    P = len(pat)
    R = cfg.n_layers // P
    blocks = {}
    for p, (kind, mlp_kind) in enumerate(pat):
        reps = [_init_layer(kg, cfg, kind, mlp_kind, dtype) for _ in range(R)]
        blocks[f"pos{p}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    params = {
        "embed": {"table": normal_init(kg(), (cfg.vocab_padded, cfg.d_model),
                                       cfg.d_model ** -0.5, dtype)},
        "blocks": blocks,
        "final_norm": init_rmsnorm(kg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "table": normal_init(kg(), (cfg.vocab_padded, cfg.d_model),
                                 cfg.d_model ** -0.5, dtype)}
    return params


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    """Abstract param pytree (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.ShapeDtypeStruct((2,), jnp.uint32))


# ----------------------------------------------------------------------
# Sublayer application
# ----------------------------------------------------------------------

def _apply_sublayer(lp, x, *, cfg, kind, mlp_kind, mode, cache, positions,
                    valid, prefix_valid, q_chunk, use_kernels, moe_rng,
                    head_pad_to=0, attn_sharding=None, moe_sharding=None):
    """Returns (x, cache_out, aux)."""
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if mode == "decode":
            mix, cache_out = attn_mod.attention_decode(
                lp["attn"], h, positions, cache, cfg)
        else:
            mix, kv = attn_mod.attention_full(
                lp["attn"], h, positions, cfg, valid=valid,
                prefix_kv=cache if mode == "extend" else None,
                prefix_valid=prefix_valid, q_chunk=q_chunk,
                head_pad_to=head_pad_to, attn_sharding=attn_sharding)
            cache_out = kv if mode in ("prefill", "extend") else None
    else:  # ssm
        if mode == "decode":
            mix, cache_out = ssm_mod.ssm_decode(lp["ssm"], h, cache, cfg)
        else:
            mix, state = ssm_mod.ssm_forward(
                lp["ssm"], h, cfg, cache=cache if mode == "extend" else None,
                use_kernel=use_kernels, valid=valid)
            cache_out = state if mode in ("prefill", "extend") else None
    x = x + mix

    aux = jnp.zeros((), jnp.float32)
    if mlp_kind != "none":
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if mlp_kind == "dense":
            out = mlp(lp["mlp"], h)
        else:
            out, aux = moe_apply(lp["moe"], h, cfg, rng=moe_rng,
                                 moe_sharding=moe_sharding)
        x = x + out
    return x, cache_out, aux


# ----------------------------------------------------------------------
# Stack execution
# ----------------------------------------------------------------------

def _run_stack(params, x, *, cfg, mode, caches, positions, valid, q_chunk,
               use_kernels, remat, moe_rng, prefix_valid=None,
               act_sharding=None, head_pad_to=0, attn_sharding=None,
               moe_sharding=None):
    pat = pattern_sig(cfg)
    P = len(pat)
    R = cfg.n_layers // P

    def body(carry, xs):
        x, aux_sum = carry
        block_params, cache_in, rngs = xs
        cache_out = {}
        for p, (kind, mlp_kind) in enumerate(pat):
            key = f"pos{p}"
            x, c_out, aux = _apply_sublayer(
                block_params[key], x, cfg=cfg, kind=kind, mlp_kind=mlp_kind,
                mode=mode, cache=None if cache_in is None else cache_in[key],
                positions=positions, valid=valid, prefix_valid=prefix_valid,
                q_chunk=q_chunk, use_kernels=use_kernels,
                moe_rng=None if rngs is None else rngs[key],
                head_pad_to=head_pad_to, attn_sharding=attn_sharding,
                moe_sharding=moe_sharding)
            if c_out is not None:
                cache_out[key] = c_out
            if act_sharding is not None:
                # keep layer-boundary activations (the remat/scan carries)
                # sharded — this is what bounds live memory at scale
                x = jax.lax.with_sharding_constraint(x, act_sharding)
        return (x, aux_sum + aux), (cache_out if cache_out else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    rngs = None
    if moe_rng is not None and any(mk == "moe" for _, mk in pat):
        flat = jax.random.split(moe_rng, (R, P))
        rngs = {f"pos{p}": flat[:, p] for p in range(P)}

    xs = (params["blocks"], caches, rngs)
    (x, aux_sum), caches_out = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux_sum, caches_out


# ----------------------------------------------------------------------
# Public entry points
# ----------------------------------------------------------------------

def _embed(params, cfg, tokens, prefix_embeds, embed_mesh=None):
    table = params["embed"]["table"]
    if embed_mesh is None:
        x = table[tokens]  # (B,S_text,d) gather
    else:
        # Explicit shard_map lookup: the table is stored (vocab replicated,
        # d_model sharded over "model"), so the gather is LOCAL per device.
        # XLA's own gather partitioning mis-compiles this pattern inside
        # scanned/remat bodies (hlo-verifier failure), so we don't let it
        # guess. Grad: shard_map transposes to a local scatter-add + psum.
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        dp = tuple(a for a in ("pod", "data") if a in embed_mesh.axis_names)
        dpn = 1
        for a in dp:
            dpn *= embed_mesh.shape[a]
        bspec = dp if tokens.shape[0] % dpn == 0 else None
        tpn = embed_mesh.shape.get("model", 1)
        dspec = "model" if cfg.d_model % tpn == 0 else None
        x = shard_map(
            lambda tbl, tok: tbl[tok], mesh=embed_mesh,
            in_specs=(PS(None, dspec), PS(bspec, None)),
            out_specs=PS(bspec, None, dspec))(table, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x, head_sharding=None):
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    if head_sharding is not None:
        # reshard the (tied) table to vocab-sharded for the head matmul so
        # logits come out vocab-sharded (cheap: table bytes ≪ logits bytes)
        table = jax.lax.with_sharding_constraint(table, head_sharding)
    logits = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(vmask[None, None, :], logits, NEG_INF)
    return logits


def _default_positions(tokens, prefix_embeds):
    b = tokens.shape[0]
    s = tokens.shape[1] + (prefix_embeds.shape[1] if prefix_embeds is not None else 0)
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            positions=None, valid=None, q_chunk: int = 512,
            use_kernels: bool = False, remat: bool = False, moe_rng=None,
            act_sharding=None, logits_sharding=None, head_sharding=None,
            embed_mesh=None, head_pad_to=0, attn_sharding=None,
            moe_sharding=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence logits. Returns (logits (B,S,Vp) fp32, moe aux loss)."""
    x = _embed(params, cfg, tokens, prefix_embeds, embed_mesh)
    if positions is None:
        positions = _default_positions(tokens, prefix_embeds)
    x, aux, _ = _run_stack(
        params, x, cfg=cfg, mode="forward", caches=None, positions=positions,
        valid=valid, q_chunk=q_chunk, use_kernels=use_kernels, remat=remat,
        moe_rng=moe_rng, act_sharding=act_sharding, head_pad_to=head_pad_to,
        attn_sharding=attn_sharding, moe_sharding=moe_sharding)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x, head_sharding)
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    return logits, aux


def prefill(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            positions=None, valid=None, q_chunk: int = 512,
            use_kernels: bool = False, act_sharding=None,
            head_sharding=None, logits_last_only: bool = False,
            embed_mesh=None, head_pad_to=0, attn_sharding=None,
            moe_sharding=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence pass that also returns the decode cache (per-layer K/V
    for attention positions, conv/state for SSM positions).

    ``logits_last_only``: serving prefill only needs the next-token logits —
    skipping the (B,S,Vp) materialization is a large memory/compute saving
    at 32k prefill."""
    x = _embed(params, cfg, tokens, prefix_embeds, embed_mesh)
    if positions is None:
        positions = _default_positions(tokens, prefix_embeds)
    x, _, caches = _run_stack(
        params, x, cfg=cfg, mode="prefill", caches=None, positions=positions,
        valid=valid, q_chunk=q_chunk, use_kernels=use_kernels, remat=False,
        moe_rng=None, act_sharding=act_sharding, head_pad_to=head_pad_to,
        attn_sharding=attn_sharding, moe_sharding=moe_sharding)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_last_only:
        x = x[:, -1:]
    return _logits(params, cfg, x, head_sharding), caches


def extend(params, cfg: ModelConfig, caches, tokens, start_pos, *,
           valid=None, prefix_valid=None, q_chunk: int = 512,
           use_kernels: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Incremental prefill: run only the ``tokens`` suffix against an
    existing prefill cache (the KV/state snapshot of the *batch* history).

    This is the TPU-native form of the paper's inference-time injection —
    fresh events cost O(suffix), not O(full history) (DESIGN.md §2).

    tokens (B,Ss); start_pos (B,) = prefix length per row. Returns
    (logits over suffix positions, caches covering prefix+suffix).
    """
    x = _embed(params, cfg, tokens, None)
    b, ss = tokens.shape
    positions = start_pos[:, None] + jnp.arange(ss, dtype=jnp.int32)[None, :]
    x, _, caches_out = _run_stack(
        params, x, cfg=cfg, mode="extend", caches=caches, positions=positions,
        valid=valid, prefix_valid=prefix_valid, q_chunk=q_chunk,
        use_kernels=use_kernels, remat=False, moe_rng=None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), caches_out


def decode_step(params, cfg: ModelConfig, caches, tokens, pos,
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """ONE-token serve step. tokens (B,1) int32; pos (B,) int32 = number of
    tokens already in the cache (the new token's absolute position)."""
    x = _embed(params, cfg, tokens, None)
    x, _, caches_out = _run_stack(
        params, x, cfg=cfg, mode="decode", caches=caches, positions=pos,
        valid=None, q_chunk=1, use_kernels=False, remat=False, moe_rng=None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), caches_out


# ----------------------------------------------------------------------
# Cache construction
# ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Fresh (empty) decode cache. ``capacity`` = KV slots for attention
    layers (clamped to the sliding window when the arch has one)."""
    pat = pattern_sig(cfg)
    R = cfg.n_layers // len(pat)
    cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
    caches = {}
    for p, (kind, _) in enumerate(pat):
        if kind == "attn":
            one = attn_mod.init_kv_cache(cfg, batch, cap, dtype)
        else:
            one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        caches[f"pos{p}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), one)
    return caches


def cache_shapes(cfg: ModelConfig, batch: int, capacity: int,
                 dtype=jnp.bfloat16):
    """Abstract cache pytree for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity, dtype))


def cache_from_prefill(cfg: ModelConfig, caches, capacity: int,
                       valid=None) -> Dict[str, Any]:
    """Convert prefill per-layer outputs into a ring decode cache.

    ``valid`` (B,S): the prefill pad mask — left-padded slots stay masked
    in the ring cache so decode never attends them."""
    pat = pattern_sig(cfg)
    cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
    out = {}
    for p, (kind, _) in enumerate(pat):
        key = f"pos{p}"
        if kind == "attn":
            out[key] = jax.vmap(
                lambda kv: attn_mod.cache_from_prefill(kv, cap, valid)
            )(caches[key])
        else:
            out[key] = caches[key]
    return out
