"""End-to-end injection serving loop — the system the paper describes.

This connects the pieces the repo already has into one request path
(PAPER.md §III-B, ROADMAP north star):

    features:  FeatureInjector (BatchFeatureStore + RealtimeFeatureService)
    tokens:    items_to_tokens (item i -> token i+1, pad -> 0)
    model:     ServingEngine.prefill / inject / finalize / decode

The cost structure is the paper's whole point: the *batch* history of a
user changes only when the daily snapshot rolls, so its model state
(prefill KV/SSM cache) is cacheable across requests. ``InjectionServer``
keeps a **prefill-state cache** keyed by ``(user, snapshot generation)``;
a request for a cached user pays only

    inject(fresh suffix) + decode          (O(Δ) per request)

instead of

    prefill(full history) + decode         (O(history) per request)

The **cache-key invariant**: an entry keyed ``(user, generation)`` is a
pure function of (that user's event log at the generation's snapshot
cutoff, the model parameters). Neither request time nor fresh events
enter the key — fresh events ride in through ``inject`` per request and
are never written back into the cached state. That is what makes a hit
safe to serve at any ``now`` within the generation, and it is why the
key MUST carry the generation: the same user's batch history differs
across snapshot cutoffs, so a ``(user,)``-keyed cache would silently
serve yesterday's state after the daily job rolls.

Cache mechanics:
  * admission on miss — the miss rows of a pane are prefilled in one
    fixed-shape batch and inserted per user;
  * LRU eviction over a configurable entry budget and an optional
    per-shard byte budget (each entry is one user's sequence-form prefill
    state: O(prefill_len) KV per attention layer, O(1) state per SSM
    layer; on a data-parallel mesh the pane-resident working set divides
    across shards, so accounting is per shard — see PrefillStateCache);
  * generation invalidation — when ``maybe_run_due_snapshots`` rolls the
    snapshot generation, every cached state was built from now-stale batch
    features; the key includes the generation (stale entries can never be
    *served*), and the whole old generation is additionally purged
    **eagerly** rather than waiting for LRU pressure: stale entries can
    never hit again (their key embeds a dead generation), so every byte
    they hold is pure waste — and under an entry-count budget they would
    otherwise evict *live* entries while they aged out.

Requests are grouped into fixed-shape panes of ``max_batch`` rows (the
engine jits one shape per entry point); short panes are padded with a
repeat of row 0 and the padding rows are discarded from the outputs.
Because every pane is padded to exactly ``max_batch`` — and a sharded
engine validates ``max_batch`` against the mesh's data-axis size at
construction — uneven hit/miss splits can never produce a pane shape
that recompiles or shards unevenly: the pane shape is a constant of the
server's lifetime, on one device or sixty-four.

The ``policy`` mirrors ``InjectionConfig``: "batch" (stale features,
control arm), "inject" (cached state + fresh-suffix injection — the
paper), "fresh" (features recomputed at the request cutoff; inherently
uncacheable, the oracle upper bound). ``use_cache=False`` degrades
"inject" to full-prefill-per-request — the baseline the serving benchmark
compares against.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.injection import FeatureInjector
from repro.core.pipeline import items_to_tokens
from repro.serving.engine import ServingEngine


# ----------------------------------------------------------------------
# Prefill-state cache
# ----------------------------------------------------------------------

class PrefillStateCache:
    """LRU cache: (user, generation) -> one user's prefill state.

    An entry holds the sequence-form engine state sliced to one row
    (cache leaves keep their leading layer-repeat axis; batch axis 1 has
    extent 1) plus the prefill's last-position logits — the next-item
    scores when the request carries no fresh suffix.

    Eviction runs over two budgets: an entry count (``budget``) and an
    optional **per-shard byte** budget (``byte_budget``). Byte accounting
    is per data-parallel shard because that is the unit that must fit in
    one device's HBM: a single-row entry is replicated host-side, but the
    moment rows are assembled into a pane and shipped to a ``dp``-way
    mesh, each shard holds ``1/dp`` of the pane — so an entry's
    accountable size is ``ceil(nbytes / shards)``. ``shards`` is the
    engine's data-axis size (1 on a single device, making per-shard ==
    total).
    """

    def __init__(self, budget: int, byte_budget: Optional[int] = None,
                 shards: int = 1):
        if budget < 1:
            raise ValueError(f"cache budget must be >= 1, got {budget}")
        if byte_budget is not None and byte_budget < 1:
            raise ValueError(
                f"byte budget must be >= 1 when set, got {byte_budget}")
        self.budget = budget
        self.byte_budget = byte_budget
        self.shards = max(int(shards), 1)
        # value = (entry, per-shard bytes); bytes memoized at put() time so
        # eviction/statistics never re-walk the state pytree
        self._entries: "OrderedDict[Tuple[int, int], Tuple[Dict[str, Any], int]]" = \
            OrderedDict()
        self.bytes_per_shard = 0      # current resident total, per shard
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return key in self._entries

    @staticmethod
    def entry_nbytes(entry: Dict[str, Any]) -> int:
        """Logical bytes of one cached state (all array leaves)."""
        return sum(x.nbytes for x in jax.tree.leaves(entry)
                   if hasattr(x, "nbytes"))

    def get(self, user: int, gen: int) -> Optional[Dict[str, Any]]:
        rec = self._entries.get((user, gen))
        if rec is None:
            self.misses += 1
            return None
        self._entries.move_to_end((user, gen))
        self.hits += 1
        return rec[0]

    def _pop_lru(self) -> None:
        _, (_, nb) = self._entries.popitem(last=False)
        self.bytes_per_shard -= nb
        self.evictions += 1

    def put(self, user: int, gen: int, entry: Dict[str, Any]) -> None:
        nb = -(-self.entry_nbytes(entry) // self.shards)  # ceil div
        old = self._entries.get((user, gen))
        if old is not None:
            self.bytes_per_shard -= old[1]
        self._entries[(user, gen)] = (entry, nb)
        self._entries.move_to_end((user, gen))
        self.bytes_per_shard += nb
        while len(self._entries) > self.budget:
            self._pop_lru()
        while (self.byte_budget is not None and len(self._entries) > 1
               and self.bytes_per_shard > self.byte_budget):
            # len > 1: the just-admitted entry always stays — a byte budget
            # smaller than one entry must still serve the current pane
            self._pop_lru()

    def invalidate_except(self, gen: int) -> int:
        """Purge every entry from a generation other than ``gen``."""
        stale = [k for k in self._entries if k[1] != gen]
        for k in stale:
            self.bytes_per_shard -= self._entries.pop(k)[1]
        self.invalidations += len(stale)
        return len(stale)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "bytes_per_shard": self.bytes_per_shard,
                "shards": self.shards}


# ----------------------------------------------------------------------
# Server
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerConfig:
    slate_len: int = 4            # items decoded per request
    cache_entries: int = 4096     # LRU budget (user-generation states)
    cache_bytes: Optional[int] = None  # per-shard byte budget (None = off)
    use_cache: bool = True        # False -> full prefill per request
    run_batch_jobs: bool = True   # roll due snapshots inside serve()


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray            # (N, vocab_padded) next-item logits
    slate: np.ndarray             # (N, slate_len) greedy token ids
    cache_hits: int               # rows served from the prefill-state cache
    cache_misses: int             # rows that paid a prefill this request


class InjectionServer:
    """The full request path, one call: ``serve(users, now)``.

    Works identically on a single device and on a data-parallel mesh: the
    engine owns all placement (a mesh-constructed ``ServingEngine`` jits
    with NamedSharding in/out specs), the server only ever builds
    fixed-shape ``max_batch`` panes — which the engine has already
    validated against the mesh's data-axis size — so the loop code has no
    sharding branches at all.
    """

    def __init__(self, engine: ServingEngine, injector: FeatureInjector,
                 cfg: ServerConfig = ServerConfig()):
        self.engine = engine
        self.injector = injector
        self.cfg = cfg
        self.cache = PrefillStateCache(cfg.cache_entries,
                                       byte_budget=cfg.cache_bytes,
                                       shards=engine.data_shards)
        self._gen = None  # generation the cache was last validated against
        self.requests = 0
        self.panes = 0
        self.prefill_calls = 0
        self.inject_calls = 0
        self.decode_steps = 0

    # ------------------------------------------------------------------
    def _sync_generation(self, now: int) -> int:
        """Roll due snapshots and purge cache entries the roll staled."""
        if self.cfg.run_batch_jobs:
            self.injector.batch.maybe_run_due_snapshots(now)
        gen = self.injector.generation(now)
        if gen != self._gen:
            self.cache.invalidate_except(gen)
            self._gen = gen
        return gen

    def warm(self, users: Sequence[int], now: int) -> int:
        """Cache-warming pass: admit ``users``' batch-history prefill
        states without serving — the post-snapshot precompute a daily job
        runs so live traffic starts on the inject-only path. Returns the
        number of states prefilled. No-op when caching is off or the
        policy is uncacheable. Clamped to the first ``cache_entries``
        users (pass highest-priority users first), and stops early once
        the byte budget is full — warming past either budget would
        prefill states that LRU-evict before they serve."""
        users = np.asarray(users, np.int64).ravel()[:self.cache.budget]
        if not self.cfg.use_cache or self.injector.cfg.policy == "fresh":
            return 0
        gen = self._sync_generation(now)
        before = self.cache.misses
        ev0 = self.cache.evictions
        b = self.engine.scfg.max_batch
        for lo in range(0, len(users), b):
            self._lookup_or_admit(users[lo:lo + b], now, gen)
            if self.cache.evictions > ev0:
                break  # a budget (the byte budget — the entry clamp above
                #        already bounds entries) is full: further warming
                #        would only evict states we just paid to prefill
        return self.cache.misses - before

    def serve(self, users: Sequence[int], now: int) -> ServeResult:
        users = np.asarray(users, np.int64).ravel()
        gen = self._sync_generation(now)
        b = self.engine.scfg.max_batch

        # Cache-aware batching: group the wave into pure-hit panes (pay
        # inject-only) and miss panes (pay one admission prefill each)
        # instead of slicing in arrival order — one cold row in a pane of
        # hits would otherwise drag the whole pane onto the prefill path.
        # Rows are independent, so regrouping cannot change any result;
        # outputs are scattered back to arrival order.
        cacheable = self.cfg.use_cache and self.injector.cfg.policy != "fresh"
        if cacheable and len(users) > b:
            is_miss = np.array([(int(u), gen) not in self.cache
                                for u in users])
            order = np.argsort(is_miss, kind="stable")  # hits first
        else:
            order = np.arange(len(users))

        scores = np.zeros((len(users), self.engine.cfg.vocab_padded),
                          np.float32)
        slates = np.zeros((len(users), self.cfg.slate_len), np.int32)
        hits0, miss0 = self.cache.hits, self.cache.misses
        for lo in range(0, len(users), b):  # pane-split: never drop rows
            idx = order[lo:lo + b]
            s, sl = self._serve_pane(users[idx], now, gen)
            scores[idx] = s[:len(idx)]
            slates[idx] = sl[:len(idx)]
            self.panes += 1
        self.requests += len(users)
        return ServeResult(
            scores=scores, slate=slates,
            cache_hits=self.cache.hits - hits0,
            cache_misses=self.cache.misses - miss0)

    # ------------------------------------------------------------------
    # Feature -> token assembly
    # ------------------------------------------------------------------

    def _history_tokens(self, pane: np.ndarray, now: int) -> List[List[int]]:
        """Per-row batch-history token lists under the injector's policy."""
        inj = self.injector
        if inj.cfg.policy == "fresh":
            items, _, valid = inj.batch.lookup_at_cutoff(pane, now)
        else:  # "batch" and "inject" share the snapshot prefix
            items, _, valid = inj.batch.lookup(pane, now)
        toks = items_to_tokens(items, valid)
        return [toks[r][valid[r] > 0].tolist() for r in range(len(pane))]

    def _suffix_tokens(self, pane: np.ndarray, now: int) -> List[List[int]]:
        if self.injector.cfg.policy != "inject":
            return [[] for _ in range(len(pane))]
        suffixes = self.injector.fresh_suffix(pane, now)
        # cap at inject_len newest events so the cached and full-prefill
        # paths see identical token streams (pad_tokens would otherwise
        # truncate them at different lengths)
        cap = self.engine.scfg.inject_len
        return [items_to_tokens(
            np.asarray([item for item, _ in evs[-cap:]], np.int64),
            np.ones(len(evs[-cap:]), np.int64)).tolist() for evs in suffixes]

    # ------------------------------------------------------------------
    # Pane execution
    # ------------------------------------------------------------------

    def _serve_pane(self, pane: np.ndarray, now: int, gen: int,
                    ) -> Tuple[np.ndarray, np.ndarray]:
        eng = self.engine
        suffix = self._suffix_tokens(pane, now)
        cacheable = self.cfg.use_cache and self.injector.cfg.policy != "fresh"
        if not cacheable:
            hists = self._history_tokens(pane, now)
            # truncate history to prefill_len BEFORE appending the suffix —
            # exactly what the cached path's prefill pane sees — so both
            # paths run identical token streams even when the feature
            # history is longer than prefill_len
            p = eng.scfg.prefill_len
            streams = [h[-p:] + s for h, s in zip(hists, suffix)]
            toks, valid = eng.pad_tokens(streams, p + eng.scfg.inject_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            first = state["logits"][:, -1]
            return self._decode_slate(state, first)

        entries = self._lookup_or_admit(pane, now, gen)
        state = _cat_rows(entries, eng.scfg.max_batch)
        last = np.stack([e["last_logits"] for e in _pad_list(
            entries, eng.scfg.max_batch)])
        if any(suffix):
            stoks, svalid = eng.pad_tokens(suffix, eng.scfg.inject_len,
                                           align="left")
            # the cached pre-inject scores ride along as the fallback, so
            # per-row "last fresh event vs empty suffix" selection happens
            # inside the inject jit — no logits ever sync to pick them
            state = eng.inject(state, stoks, svalid, fallback_logits=last)
            self.inject_calls += 1
            first = state["first_logits"]
        else:
            first = last
        return self._decode_slate(state, first)

    def _lookup_or_admit(self, pane: np.ndarray, now: int, gen: int,
                         ) -> List[Dict[str, Any]]:
        """Return per-row cache entries, prefilling the misses in one
        fixed-shape batch (one prefill per pane worst case)."""
        eng = self.engine
        entries: Dict[int, Dict[str, Any]] = {}
        miss_users: List[int] = []
        for u in pane.tolist():
            # probe once per ROW (not per unique user) so hit/miss counters
            # stay in request units even when a pane repeats a user; the
            # admission list itself is deduplicated below
            e = self.cache.get(u, gen)
            if e is None:
                if u not in miss_users:
                    miss_users.append(u)
            else:
                entries[u] = e
        if miss_users:
            hists = self._history_tokens(np.asarray(miss_users), now)
            toks, valid = eng.pad_tokens(hists, eng.scfg.prefill_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            host = _host_state(state)  # one device→host sync per leaf
            for j, u in enumerate(miss_users):
                entry = _slice_row(host, j)
                self.cache.put(u, gen, entry)
                entries[u] = entry
        return [entries[u] for u in pane.tolist()]

    def _decode_slate(self, state: Dict[str, Any], first_logits,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """finalize -> greedy slate of ``slate_len`` *distinct* items.

        The whole slate (mask chosen → argmax → decode, repeated) runs as
        one jit call in the engine — the per-token host loop this replaces
        was the single largest serve-path cost (eager masking + one
        device sync per decoded item)."""
        eng = self.engine
        slate = eng.decode_slate(state, first_logits, self.cfg.slate_len)
        self.decode_steps += self.cfg.slate_len - 1
        return np.asarray(first_logits, np.float32), slate

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {"requests": self.requests, "panes": self.panes,
                "prefill_calls": self.prefill_calls,
                "inject_calls": self.inject_calls,
                "decode_steps": self.decode_steps,
                "cache": self.cache.stats()}


# ----------------------------------------------------------------------
# Per-row state plumbing (batch axis of every cache leaf is axis 1;
# verified for attention K/V, SSM conv/state and the Jamba hybrid)
#
# Entries are HOST-resident numpy: slicing/assembling panes row-by-row in
# eager jax ops was the serve path's dominant cost (hundreds of tiny
# dispatches per pane), while numpy slices/concats are C-speed memcpy.
# The assembled pane crosses to the device (mesh-sharded, when the engine
# has one) exactly once, at the next jit boundary — the engine device_puts
# every operand to its serving layout. On a CPU host this is free (it is
# all host memory); on TPU it trades HBM residency for PCIe transfer per
# admission+hit, and the device-resident follow-up is a paged state pool
# (slot-indexed gather instead of host concat) — see docs/serving.md.
# ----------------------------------------------------------------------

def _host_state(state: Dict[str, Any]) -> Dict[str, Any]:
    """Pull a batched sequence-form prefill state to host, whole-pane at a
    time (one device→host sync per cache leaf, not per row)."""
    return {
        "caches": jax.tree.map(np.asarray, state["caches"]),
        "valid": np.asarray(state["valid"]),
        "next_pos": np.asarray(state["next_pos"]),
        "last_logits": np.asarray(state["logits"][:, -1]),
    }


def _slice_row(host: Dict[str, Any], row: int) -> Dict[str, Any]:
    """One row of a host-form pane state, copied so the entry doesn't pin
    the whole pane's buffers in the LRU."""
    return {
        "caches": jax.tree.map(lambda x: x[:, row:row + 1].copy(),
                               host["caches"]),
        "valid": host["valid"][row:row + 1].copy(),
        "next_pos": host["next_pos"][row:row + 1].copy(),
        "last_logits": host["last_logits"][row].copy(),
    }


def _pad_list(entries: List[Dict[str, Any]], b: int) -> List[Dict[str, Any]]:
    if not entries:
        raise ValueError("empty pane")
    return entries + [entries[0]] * (b - len(entries))


def _cat_rows(entries: List[Dict[str, Any]], b: int) -> Dict[str, Any]:
    """Assemble per-user entries into one max_batch engine state (short
    panes padded by repeating row 0; padding rows are discarded later)."""
    rows = _pad_list(entries, b)
    return {
        "caches": jax.tree.map(lambda *xs: np.concatenate(xs, axis=1),
                               *[e["caches"] for e in rows]),
        "valid": np.concatenate([e["valid"] for e in rows], axis=0),
        "next_pos": np.concatenate([e["next_pos"] for e in rows], axis=0),
        "logits": None,  # per-row slices don't keep full prefill logits
    }
