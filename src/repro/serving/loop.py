"""Legacy wave-oriented serving API — a thin wrapper over the Gateway.

.. deprecated::
    ``InjectionServer.serve(users, now)`` predates the request-level
    serving API. The system's one serving facade is now the
    :class:`~repro.serving.scheduler.Gateway` (typed
    ``Request``/``Response`` lifecycle, micro-batching scheduler,
    per-request policy/slate_len/deadline, unified event ingestion and
    telemetry — see ``serving/api.py`` and ``serving/scheduler.py``,
    and docs/serving.md for the migration guide). A wave is just a
    degenerate request trace — every arrival at the same instant, all on
    the gateway defaults:

        serve(users, now)  ==  submit_many([Request(u, now) for u in users])
                               + flush(now)

    which is literally how this wrapper is implemented, so it serves
    **bitwise-identical** slates/scores to the pre-Gateway wave loop
    (same pane formation, same cache-aware hit/miss partitioning, same
    engine call sequence) — verified by tests/test_serving_api.py.
    ``serve()`` emits a DeprecationWarning; new code should construct a
    Gateway directly.

The serving design itself — the prefill-state cache keyed
``(user, snapshot generation)``, the cache-key invariant, the
warm-handoff generation rollover, cache-aware pane formation,
host-resident LRU entries — lives with the scheduler; see the module
docstring of ``serving/scheduler.py`` and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import numpy as np

from repro.core.injection import FeatureInjector
from repro.serving.api import GatewayStats, Request
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (  # noqa: F401  (re-exported: the
    Gateway, PrefillStateCache, ServerConfig)        # pre-Gateway public
#                                                      surface lived here


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray            # (N, vocab_padded) next-item logits
    slate: np.ndarray             # (N, slate_len) greedy token ids
    cache_hits: int               # rows served from the prefill-state cache
    cache_misses: int             # rows that paid a prefill this request


class InjectionServer:
    """Back-compat wave API: the full request path, one call —
    ``serve(users, now)``. Deprecated; thin shim over :class:`Gateway`.

    Everything stateful (cache, counters, clock) belongs to the wrapped
    gateway, exposed read-through so existing callers and tests keep
    working; ``warm``/``stats`` delegate directly.
    """

    def __init__(self, engine: ServingEngine, injector: FeatureInjector,
                 cfg: ServerConfig = ServerConfig()):
        self.gateway = Gateway(engine, injector, cfg)

    # -- read-through compatibility surface ----------------------------
    @property
    def engine(self) -> ServingEngine:
        return self.gateway.engine

    @property
    def injector(self) -> FeatureInjector:
        return self.gateway.injector

    @property
    def cfg(self) -> ServerConfig:
        return self.gateway.cfg

    @property
    def cache(self) -> PrefillStateCache:
        return self.gateway.cache

    @property
    def requests(self) -> int:
        return self.gateway.requests

    @property
    def panes(self) -> int:
        return self.gateway.panes

    @property
    def prefill_calls(self) -> int:
        return self.gateway.prefill_calls

    @property
    def inject_calls(self) -> int:
        return self.gateway.inject_calls

    @property
    def decode_steps(self) -> int:
        return self.gateway.decode_steps

    # ------------------------------------------------------------------
    def warm(self, users: Sequence[int], now: int) -> int:
        """Daily-job cache precompute; see :meth:`Gateway.warm`."""
        return self.gateway.warm(users, now)

    def serve(self, users: Sequence[int], now: int) -> ServeResult:
        """Serve one pre-grouped wave. Deprecated: submit Requests to
        the Gateway instead (this shim is exactly ``submit_many`` +
        ``flush`` on default-policy requests)."""
        warnings.warn(
            "InjectionServer.serve(users, now) is deprecated; use "
            "Gateway.submit/submit_many with typed Requests "
            "(repro.serving.scheduler.Gateway) — see docs/serving.md "
            "for the migration guide", DeprecationWarning, stacklevel=2)
        gw = self.gateway
        # Legacy semantics: the wave is served AT the call's ``now``,
        # even if an earlier call used a later time — the pre-Gateway
        # loop read features/generation at whatever ``now`` it was
        # handed. The request API's clock is deliberately monotonic, so
        # the shim rewinds it explicitly rather than inheriting
        # "serve at max(now, previous now)" behavior the legacy loop
        # never had.
        gw._clock = int(now)
        users = np.asarray(users, np.int64).ravel()
        if len(users) == 0:
            gw.tick(now)  # the legacy loop still synced the snapshot
            return ServeResult(
                scores=np.zeros((0, gw.engine.cfg.vocab_padded), np.float32),
                slate=np.zeros((0, gw.cfg.slate_len), np.int32),
                cache_hits=0, cache_misses=0)
        hits0, miss0 = gw.cache.hits, gw.cache.misses
        tickets = gw.submit_many(
            [Request(user=int(u), now=int(now)) for u in users])
        gw.flush(now)
        return ServeResult(
            scores=np.stack([t.response.scores for t in tickets]),
            slate=np.stack([t.response.slate for t in tickets]),
            cache_hits=gw.cache.hits - hits0,
            cache_misses=gw.cache.misses - miss0)

    # ------------------------------------------------------------------
    def stats(self) -> GatewayStats:
        return self.gateway.stats()
