"""End-to-end injection serving loop — the system the paper describes.

This connects the pieces the repo already has into one request path
(PAPER.md §III-B, ROADMAP north star):

    features:  FeatureInjector (BatchFeatureStore + RealtimeFeatureService)
    tokens:    items_to_tokens (item i -> token i+1, pad -> 0)
    model:     ServingEngine.prefill / inject / finalize / decode

The cost structure is the paper's whole point: the *batch* history of a
user changes only when the daily snapshot rolls, so its model state
(prefill KV/SSM cache) is cacheable across requests. ``InjectionServer``
keeps a **prefill-state cache** keyed by ``(user, snapshot generation)``;
a request for a cached user pays only

    inject(fresh suffix) + decode          (O(Δ) per request)

instead of

    prefill(full history) + decode         (O(history) per request)

Cache mechanics:
  * admission on miss — the miss rows of a pane are prefilled in one
    fixed-shape batch and inserted per user;
  * LRU eviction over a configurable entry budget (each entry is one
    user's sequence-form prefill state: O(prefill_len) KV per attention
    layer, O(1) state per SSM layer);
  * generation invalidation — when ``maybe_run_due_snapshots`` rolls the
    snapshot generation, every cached state was built from now-stale batch
    features; the key includes the generation (stale entries can never be
    *served*) and the whole old generation is purged eagerly (memory is
    released immediately, not on LRU pressure).

Requests are grouped into fixed-shape panes of ``max_batch`` rows (the
engine jits one shape per entry point); short panes are padded with a
repeat of row 0 and the padding rows are discarded from the outputs.

The ``policy`` mirrors ``InjectionConfig``: "batch" (stale features,
control arm), "inject" (cached state + fresh-suffix injection — the
paper), "fresh" (features recomputed at the request cutoff; inherently
uncacheable, the oracle upper bound). ``use_cache=False`` degrades
"inject" to full-prefill-per-request — the baseline the serving benchmark
compares against.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.injection import FeatureInjector
from repro.core.pipeline import items_to_tokens
from repro.serving.engine import ServingEngine


# ----------------------------------------------------------------------
# Prefill-state cache
# ----------------------------------------------------------------------

class PrefillStateCache:
    """LRU cache: (user, generation) -> one user's prefill state.

    An entry holds the sequence-form engine state sliced to one row
    (cache leaves keep their leading layer-repeat axis; batch axis 1 has
    extent 1) plus the prefill's last-position logits — the next-item
    scores when the request carries no fresh suffix.
    """

    def __init__(self, budget: int):
        if budget < 1:
            raise ValueError(f"cache budget must be >= 1, got {budget}")
        self.budget = budget
        self._entries: "OrderedDict[Tuple[int, int], Dict[str, Any]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return key in self._entries

    def get(self, user: int, gen: int) -> Optional[Dict[str, Any]]:
        entry = self._entries.get((user, gen))
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end((user, gen))
        self.hits += 1
        return entry

    def put(self, user: int, gen: int, entry: Dict[str, Any]) -> None:
        self._entries[(user, gen)] = entry
        self._entries.move_to_end((user, gen))
        while len(self._entries) > self.budget:
            self._entries.popitem(last=False)
            self.evictions += 1

    def invalidate_except(self, gen: int) -> int:
        """Purge every entry from a generation other than ``gen``."""
        stale = [k for k in self._entries if k[1] != gen]
        for k in stale:
            del self._entries[k]
        self.invalidations += len(stale)
        return len(stale)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations}


# ----------------------------------------------------------------------
# Server
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerConfig:
    slate_len: int = 4            # items decoded per request
    cache_entries: int = 4096     # LRU budget (user-generation states)
    use_cache: bool = True        # False -> full prefill per request
    run_batch_jobs: bool = True   # roll due snapshots inside serve()


@dataclasses.dataclass
class ServeResult:
    scores: np.ndarray            # (N, vocab_padded) next-item logits
    slate: np.ndarray             # (N, slate_len) greedy token ids
    cache_hits: int               # rows served from the prefill-state cache
    cache_misses: int             # rows that paid a prefill this request


class InjectionServer:
    """The full request path, one call: ``serve(users, now)``."""

    def __init__(self, engine: ServingEngine, injector: FeatureInjector,
                 cfg: ServerConfig = ServerConfig()):
        self.engine = engine
        self.injector = injector
        self.cfg = cfg
        self.cache = PrefillStateCache(cfg.cache_entries)
        self._gen = None  # generation the cache was last validated against
        self.requests = 0
        self.panes = 0
        self.prefill_calls = 0
        self.inject_calls = 0
        self.decode_steps = 0

    # ------------------------------------------------------------------
    def _sync_generation(self, now: int) -> int:
        """Roll due snapshots and purge cache entries the roll staled."""
        if self.cfg.run_batch_jobs:
            self.injector.batch.maybe_run_due_snapshots(now)
        gen = self.injector.generation(now)
        if gen != self._gen:
            self.cache.invalidate_except(gen)
            self._gen = gen
        return gen

    def warm(self, users: Sequence[int], now: int) -> int:
        """Cache-warming pass: admit ``users``' batch-history prefill
        states without serving — the post-snapshot precompute a daily job
        runs so live traffic starts on the inject-only path. Returns the
        number of states prefilled. No-op when caching is off or the
        policy is uncacheable. Clamped to the first ``cache_entries``
        users (pass highest-priority users first) — warming past the
        budget would prefill states that LRU-evict before they serve."""
        users = np.asarray(users, np.int64).ravel()[:self.cache.budget]
        if not self.cfg.use_cache or self.injector.cfg.policy == "fresh":
            return 0
        gen = self._sync_generation(now)
        before = self.cache.misses
        b = self.engine.scfg.max_batch
        for lo in range(0, len(users), b):
            self._lookup_or_admit(users[lo:lo + b], now, gen)
        return self.cache.misses - before

    def serve(self, users: Sequence[int], now: int) -> ServeResult:
        users = np.asarray(users, np.int64).ravel()
        gen = self._sync_generation(now)
        b = self.engine.scfg.max_batch

        # Cache-aware batching: group the wave into pure-hit panes (pay
        # inject-only) and miss panes (pay one admission prefill each)
        # instead of slicing in arrival order — one cold row in a pane of
        # hits would otherwise drag the whole pane onto the prefill path.
        # Rows are independent, so regrouping cannot change any result;
        # outputs are scattered back to arrival order.
        cacheable = self.cfg.use_cache and self.injector.cfg.policy != "fresh"
        if cacheable and len(users) > b:
            is_miss = np.array([(int(u), gen) not in self.cache
                                for u in users])
            order = np.argsort(is_miss, kind="stable")  # hits first
        else:
            order = np.arange(len(users))

        scores = np.zeros((len(users), self.engine.cfg.vocab_padded),
                          np.float32)
        slates = np.zeros((len(users), self.cfg.slate_len), np.int32)
        hits0, miss0 = self.cache.hits, self.cache.misses
        for lo in range(0, len(users), b):  # pane-split: never drop rows
            idx = order[lo:lo + b]
            s, sl = self._serve_pane(users[idx], now, gen)
            scores[idx] = s[:len(idx)]
            slates[idx] = sl[:len(idx)]
            self.panes += 1
        self.requests += len(users)
        return ServeResult(
            scores=scores, slate=slates,
            cache_hits=self.cache.hits - hits0,
            cache_misses=self.cache.misses - miss0)

    # ------------------------------------------------------------------
    # Feature -> token assembly
    # ------------------------------------------------------------------

    def _history_tokens(self, pane: np.ndarray, now: int) -> List[List[int]]:
        """Per-row batch-history token lists under the injector's policy."""
        inj = self.injector
        if inj.cfg.policy == "fresh":
            items, _, valid = inj.batch.lookup_at_cutoff(pane, now)
        else:  # "batch" and "inject" share the snapshot prefix
            items, _, valid = inj.batch.lookup(pane, now)
        toks = items_to_tokens(items, valid)
        return [toks[r][valid[r] > 0].tolist() for r in range(len(pane))]

    def _suffix_tokens(self, pane: np.ndarray, now: int) -> List[List[int]]:
        if self.injector.cfg.policy != "inject":
            return [[] for _ in range(len(pane))]
        suffixes = self.injector.fresh_suffix(pane, now)
        # cap at inject_len newest events so the cached and full-prefill
        # paths see identical token streams (pad_tokens would otherwise
        # truncate them at different lengths)
        cap = self.engine.scfg.inject_len
        return [items_to_tokens(
            np.asarray([item for item, _ in evs[-cap:]], np.int64),
            np.ones(len(evs[-cap:]), np.int64)).tolist() for evs in suffixes]

    # ------------------------------------------------------------------
    # Pane execution
    # ------------------------------------------------------------------

    def _serve_pane(self, pane: np.ndarray, now: int, gen: int,
                    ) -> Tuple[np.ndarray, np.ndarray]:
        eng = self.engine
        suffix = self._suffix_tokens(pane, now)
        cacheable = self.cfg.use_cache and self.injector.cfg.policy != "fresh"
        if not cacheable:
            hists = self._history_tokens(pane, now)
            # truncate history to prefill_len BEFORE appending the suffix —
            # exactly what the cached path's prefill pane sees — so both
            # paths run identical token streams even when the feature
            # history is longer than prefill_len
            p = eng.scfg.prefill_len
            streams = [h[-p:] + s for h, s in zip(hists, suffix)]
            toks, valid = eng.pad_tokens(streams, p + eng.scfg.inject_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            first = state["logits"][:, -1]
            return self._decode_slate(state, first)

        entries = self._lookup_or_admit(pane, now, gen)
        state = _cat_rows(entries, eng.scfg.max_batch)
        last = jnp.stack([e["last_logits"] for e in _pad_list(
            entries, eng.scfg.max_batch)])
        if any(suffix):
            stoks, svalid = eng.pad_tokens(suffix, eng.scfg.inject_len,
                                           align="left")
            state = eng.inject(state, stoks, svalid)
            self.inject_calls += 1
            n_valid = svalid.sum(-1)
            idx = jnp.asarray(np.maximum(n_valid - 1, 0))
            rows = jnp.arange(state["logits"].shape[0])
            injected = state["logits"][rows, idx]  # last *valid* suffix pos
            first = jnp.where(jnp.asarray(n_valid > 0)[:, None],
                              injected, last)
        else:
            first = last
        return self._decode_slate(state, first)

    def _lookup_or_admit(self, pane: np.ndarray, now: int, gen: int,
                         ) -> List[Dict[str, Any]]:
        """Return per-row cache entries, prefilling the misses in one
        fixed-shape batch (one prefill per pane worst case)."""
        eng = self.engine
        entries: Dict[int, Dict[str, Any]] = {}
        miss_users: List[int] = []
        for u in pane.tolist():
            # probe once per ROW (not per unique user) so hit/miss counters
            # stay in request units even when a pane repeats a user; the
            # admission list itself is deduplicated below
            e = self.cache.get(u, gen)
            if e is None:
                if u not in miss_users:
                    miss_users.append(u)
            else:
                entries[u] = e
        if miss_users:
            hists = self._history_tokens(np.asarray(miss_users), now)
            toks, valid = eng.pad_tokens(hists, eng.scfg.prefill_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            for j, u in enumerate(miss_users):
                entry = _slice_row(state, j)
                self.cache.put(u, gen, entry)
                entries[u] = entry
        return [entries[u] for u in pane.tolist()]

    def _decode_slate(self, state: Dict[str, Any], first_logits,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """finalize -> greedy slate: feed each decoded item back in.
        Already-slated items are masked per row — a slate recommends
        ``slate_len`` *distinct* items."""
        eng = self.engine
        b = self.engine.scfg.max_batch
        dec = eng.finalize(state)
        chosen = np.zeros((b, self.engine.cfg.vocab_padded), bool)

        def pick(logits):
            tok = np.asarray(eng.sample(
                jnp.where(jnp.asarray(chosen), -1e30, logits)))
            chosen[np.arange(b), tok] = True
            return tok

        slate = [pick(first_logits)]
        for _ in range(self.cfg.slate_len - 1):
            logits, dec = eng.decode(dec, slate[-1][:, None])
            self.decode_steps += 1
            slate.append(pick(logits))
        return (np.asarray(first_logits, np.float32),
                np.stack(slate, axis=1))

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {"requests": self.requests, "panes": self.panes,
                "prefill_calls": self.prefill_calls,
                "inject_calls": self.inject_calls,
                "decode_steps": self.decode_steps,
                "cache": self.cache.stats()}


# ----------------------------------------------------------------------
# Per-row state plumbing (batch axis of every cache leaf is axis 1;
# verified for attention K/V, SSM conv/state and the Jamba hybrid)
# ----------------------------------------------------------------------

def _slice_row(state: Dict[str, Any], row: int) -> Dict[str, Any]:
    """Extract one row of a batched sequence-form prefill state."""
    return {
        "caches": jax.tree.map(lambda x: x[:, row:row + 1], state["caches"]),
        "valid": state["valid"][row:row + 1],
        "next_pos": state["next_pos"][row:row + 1],
        "last_logits": state["logits"][row, -1],
    }


def _pad_list(entries: List[Dict[str, Any]], b: int) -> List[Dict[str, Any]]:
    if not entries:
        raise ValueError("empty pane")
    return entries + [entries[0]] * (b - len(entries))


def _cat_rows(entries: List[Dict[str, Any]], b: int) -> Dict[str, Any]:
    """Assemble per-user entries into one max_batch engine state (short
    panes padded by repeating row 0; padding rows are discarded later)."""
    rows = _pad_list(entries, b)
    return {
        "caches": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                               *[e["caches"] for e in rows]),
        "valid": jnp.concatenate([e["valid"] for e in rows], axis=0),
        "next_pos": jnp.concatenate([e["next_pos"] for e in rows], axis=0),
        "logits": None,  # per-row slices don't keep full prefill logits
    }
