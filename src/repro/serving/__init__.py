"""Public serving surface.

The one serving facade is the :class:`Gateway` (submit/poll streaming
lifecycle over typed Requests); :class:`InjectionServer` is the
deprecated wave-era shim kept for bitwise-compat callers.
"""
from repro.serving.api import (  # noqa: F401
    Event, GatewayStats, Request, RequestTelemetry, Response,
    RolloverStats, Ticket, as_event, assign_arms, hash_arm)
from repro.serving.engine import (  # noqa: F401
    ServingConfig, ServingEngine, make_serve_step)
from repro.serving.pool import (  # noqa: F401
    DeviceStatePool, PagedStateCache)
from repro.serving.scheduler import (  # noqa: F401
    Gateway, PrefillStateCache, ServerConfig)
from repro.serving.loop import (  # noqa: F401
    InjectionServer, ServeResult)
from repro.serving.loadgen import (  # noqa: F401
    SCENARIO_NAMES, ScenarioResult, ScenarioSpec, SLOContract, Trace,
    evaluate_slo, get_scenario, make_trace, run_scenario)

__all__ = [
    # request-level API (serving/api.py)
    "Event", "Request", "Response", "RequestTelemetry", "Ticket",
    "GatewayStats", "RolloverStats", "as_event", "hash_arm", "assign_arms",
    # engine (serving/engine.py)
    "ServingConfig", "ServingEngine", "make_serve_step",
    # paged device state pool (serving/pool.py)
    "DeviceStatePool", "PagedStateCache",
    # scheduler / facade (serving/scheduler.py)
    "Gateway", "ServerConfig", "PrefillStateCache",
    # deprecated wave shim (serving/loop.py)
    "InjectionServer", "ServeResult",
    # scenario harness (serving/loadgen.py)
    "SCENARIO_NAMES", "SLOContract", "ScenarioSpec", "ScenarioResult",
    "Trace", "evaluate_slo", "get_scenario", "make_trace", "run_scenario",
]
