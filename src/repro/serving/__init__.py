from repro.serving.engine import (  # noqa: F401
    ServingConfig, ServingEngine, make_serve_step)
from repro.serving.loop import (  # noqa: F401
    InjectionServer, PrefillStateCache, ServeResult, ServerConfig)
