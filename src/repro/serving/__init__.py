from repro.serving.engine import (  # noqa: F401
    ServingConfig, ServingEngine, make_serve_step)
