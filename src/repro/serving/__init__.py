from repro.serving.api import (  # noqa: F401
    Event, Request, RequestTelemetry, Response, Ticket, as_event,
    assign_arms, hash_arm)
from repro.serving.engine import (  # noqa: F401
    ServingConfig, ServingEngine, make_serve_step)
from repro.serving.scheduler import (  # noqa: F401
    Gateway, PrefillStateCache, ServerConfig)
from repro.serving.loop import (  # noqa: F401
    InjectionServer, ServeResult)
