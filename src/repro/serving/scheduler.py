"""Request-level serving: the Gateway facade + micro-batching scheduler.

The engine jits one fixed ``max_batch`` pane shape per entry point, but
real traffic is per-request: arrivals trickle in, carry their own A/B
arm (policy) and slate length, and are not pre-grouped into waves. The
:class:`Gateway` is the bridge — the *one* serving facade:

    ticket = gw.submit(Request(user=7, now=now))    # enqueue an arrival
    gw.observe(Event(user=7, item=42, ts=now))      # feedback ingestion
    gw.tick(now + 60)                               # clock: snapshots,
                                                    # deadline flushes
    ticket.response.slate                           # filled at flush

**Micro-batching.** Queued requests coalesce into the engine's
fixed-shape ``max_batch`` panes. A pane flushes when it is *full*, or
when a queued request's ``deadline`` is reached by the gateway clock
(the pane is padded and served short — latency beats utilization once a
deadline fires), or on an explicit ``flush()``. When more than one
pane's worth of requests is queued at drain time, the scheduler reuses
the cache-aware partitioning the wave path proved out: rows whose
``(user, generation)`` prefill state is cached are grouped into
pure-hit panes ahead of miss rows (stable order otherwise), so one cold
row cannot drag a pane of hits onto the prefill path. Rows are
independent, so regrouping never changes any row's result.

**Continuous batching** (``ServerConfig.max_wait``). Wave semantics
make a trickle arrival wait for its pane to fill (or for a deadline):
at one arrival per sim-second and ``max_batch=16`` the last-served row
has waited 15 seconds before the pane even forms. With ``max_wait``
set, a queued request is served once it has waited that long —
``max_wait=0`` admits every arrival immediately in a padded partial
pane — while a backlogged queue (``submit_many``, or arrivals faster
than service) still forms full panes first, so the scheduler degrades
to wave behavior exactly when utilization matters. Rows are
independent, so any grouping serves bitwise-identical results; the
knob only trades pane occupancy against queue delay. Completed tickets
stream out through :meth:`Gateway.poll` / :meth:`Gateway.drain` as
their rows retire — callers are no longer forced through wave-shaped
``flush()``.

**The paged state pool** (``ServerConfig.pool_slots``). By default
per-user prefill states live in a host-numpy LRU and every pane is
re-assembled with host concats (one host->device transfer per pane).
With ``pool_slots`` set, states live in a preallocated device-resident
slot pool (serving/pool.py): pane assembly is a one-hot slot gather
and admission writeback a one-hot scatter, both inside jit and both
collective-free on a mesh. The slot table (:class:`PagedStateCache`)
keeps the host LRU's exact key/counter/rekey surface, so the PR 5 warm
handoff composes unchanged — a generation rekey renames table keys and
never touches device arrays. Both backends serve bitwise-identical
slates (tests/test_state_pool.py).

**Mixed-policy panes.** Per-request ``policy`` resolves at
feature-assembly time, so control ("batch"), treatment ("inject") and
oracle ("fresh") rows coexist in one pane: batch/inject rows share the
snapshot history (and therefore the same cached prefill state — a batch
row is just an inject row with an empty suffix), while fresh rows are
prefilled at the request cutoff as *ephemeral* admissions (never
cached: their history depends on ``now``, violating the cache-key
invariant). This is what makes the paper's A/B split expressible on one
serving fleet: arms are request labels, not server deployments.

**Generation rollover.** The daily boundary is no longer a cliff: with
``ServerConfig.snapshot_build_budget`` set, the snapshot build runs as
an incremental :class:`~repro.core.feature_store.SnapshotBuilder`
advanced one budget-bounded slice per clock call (serving keeps
reading the previous generation until the build lands), and when the
generation does roll, the cache takes a **warm handoff**: entries
whose snapshot row is bitwise unchanged are rekeyed to the new
generation (identical history => identical prefill state — results
are bitwise what a purge + re-prefill would serve), changed users are
invalidated and optionally re-warmed between panes by a budgeted
``warm_step``. See docs/serving.md "Generation rollover".

**Telemetry.** Every response carries a :class:`RequestTelemetry`
(pane id, queue delay, cache hit, prefill-vs-inject path, generation);
``Gateway.stats()`` aggregates them (path counts, queue-delay
percentiles over a sliding window, rollover rekey/invalidate/build
counters) on top of the engine/cache counters.

The legacy wave API (``InjectionServer.serve(users, now)`` in
serving/loop.py) is a thin wrapper over this facade and serves
bitwise-identical results: a wave is ``submit_many`` + ``flush`` with
every request on the gateway defaults.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.injection import FeatureInjector, decay_scores
from repro.core.pipeline import items_to_tokens
from repro.serving.api import (POLICIES, GatewayStats, Request,
                               RequestTelemetry, Response, RolloverStats,
                               Ticket, as_event)
from repro.serving.engine import ServingEngine


# ----------------------------------------------------------------------
# Prefill-state cache
# ----------------------------------------------------------------------

class PrefillStateCache:
    """LRU cache: (user, generation) -> one user's prefill state.

    An entry holds the sequence-form engine state sliced to one row
    (cache leaves keep their leading layer-repeat axis; batch axis 1 has
    extent 1) plus the prefill's last-position logits — the next-item
    scores when the request carries no fresh suffix.

    Eviction runs over two budgets: an entry count (``budget``) and an
    optional **per-shard byte** budget (``byte_budget``). Byte accounting
    is per data-parallel shard because that is the unit that must fit in
    one device's HBM: a single-row entry is replicated host-side, but the
    moment rows are assembled into a pane and shipped to a ``dp``-way
    mesh, each shard holds ``1/dp`` of the pane — so an entry's
    accountable size is ``ceil(nbytes / shards)``. ``shards`` is the
    engine's data-axis size (1 on a single device, making per-shard ==
    total).
    """

    def __init__(self, budget: int, byte_budget: Optional[int] = None,
                 shards: int = 1):
        if budget < 1:
            raise ValueError(f"cache budget must be >= 1, got {budget}")
        if byte_budget is not None and byte_budget < 1:
            raise ValueError(
                f"byte budget must be >= 1 when set, got {byte_budget}")
        self.budget = budget
        self.byte_budget = byte_budget
        self.shards = max(int(shards), 1)
        # value = (entry, per-shard bytes); bytes memoized at put() time so
        # eviction/statistics never re-walk the state pytree
        self._entries: "OrderedDict[Tuple[int, int], Tuple[Dict[str, Any], int]]" = \
            OrderedDict()
        self.bytes_per_shard = 0      # current resident total, per shard
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.rekeys = 0
        # handoff window: old-generation entries of CHANGED users kept
        # alive across a rollover (retain_changed rekey). They are the
        # first victims under any budget pressure — dual-generation
        # residency is a courtesy, never worth evicting a live entry for.
        self._handoff_stale: set = set()
        self.stale_evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return key in self._entries

    @staticmethod
    def entry_nbytes(entry: Dict[str, Any]) -> int:
        """Logical bytes of one cached state (all array leaves)."""
        return sum(x.nbytes for x in jax.tree.leaves(entry)
                   if hasattr(x, "nbytes"))

    def get(self, user: int, gen: int) -> Optional[Dict[str, Any]]:
        rec = self._entries.get((user, gen))
        if rec is None:
            self.misses += 1
            return None
        self._entries.move_to_end((user, gen))
        self.hits += 1
        return rec[0]

    def _pop_lru(self) -> None:
        # rollover-aware victim order: a retained dual-generation entry
        # (changed user, old generation — kept through the handoff
        # window) evicts before ANY live entry, in LRU order among the
        # stale; only when no stale entry remains does the true LRU go.
        # The scan is bounded by the handoff window: _handoff_stale is
        # empty outside it, so steady-state eviction stays O(1).
        if self._handoff_stale:
            key = next((k for k in self._entries
                        if k in self._handoff_stale), None)
            if key is not None:
                nb = self._entries.pop(key)[1]
                self._handoff_stale.discard(key)
                self.bytes_per_shard -= nb
                self.evictions += 1
                self.stale_evictions += 1
                return
            self._handoff_stale.clear()  # all dangling: drop the set
        _, (_, nb) = self._entries.popitem(last=False)
        self.bytes_per_shard -= nb
        self.evictions += 1

    def put(self, user: int, gen: int, entry: Dict[str, Any]) -> None:
        nb = -(-self.entry_nbytes(entry) // self.shards)  # ceil div
        old = self._entries.get((user, gen))
        if old is not None:
            self.bytes_per_shard -= old[1]
        self._entries[(user, gen)] = (entry, nb)
        self._entries.move_to_end((user, gen))
        self.bytes_per_shard += nb
        while len(self._entries) > self.budget:
            self._pop_lru()
        while (self.byte_budget is not None and len(self._entries) > 1
               and self.bytes_per_shard > self.byte_budget):
            # len > 1: the just-admitted entry always stays — a byte budget
            # smaller than one entry must still serve the current pane
            self._pop_lru()

    def invalidate_except(self, gen: int) -> int:
        """Purge every entry from a generation other than ``gen``."""
        stale = [k for k in self._entries if k[1] != gen]
        for k in stale:
            self.bytes_per_shard -= self._entries.pop(k)[1]
        self.invalidations += len(stale)
        self._handoff_stale = {k for k in self._handoff_stale
                               if k in self._entries}
        return len(stale)

    def rekey_generation(self, old_gen: int, new_gen: int, changed,
                         retain_changed: bool = False) -> Tuple[int, int]:
        """Warm handoff across a generation rollover.

        Entries keyed ``(user, old_gen)`` whose user is **not** in
        ``changed`` are rekeyed to ``(user, new_gen)`` in place (LRU
        order and byte accounting preserved): an unchanged snapshot row
        means an identical batch history, and a prefill state is a pure
        function of (history, params) — so the entry under the new key
        is bitwise the entry a fresh admission would build. The caller is
        responsible for ``changed`` being a certified row-diff between
        two frozen generations
        (``BatchFeatureStore.changed_users_between``); rekeying across a
        recomputed (evicted) generation is never safe.

        Changed users' ``old_gen`` entries are invalidated — or, with
        ``retain_changed=True``, retained under their old key for the
        handoff window (the cache briefly holds both generations for
        those users) and marked first-victim for every budget eviction;
        the next handoff or ``invalidate_except`` sweeps survivors.
        Entries from any other stale generation, and ``old_gen``
        duplicates of users already cached under ``new_gen``, are always
        invalidated.

        Returns ``(rekeyed, invalidated)`` counts; the retained set is
        ``_handoff_stale`` / ``stats()["handoff_stale"]``.
        """
        changed_set = {int(u) for u in np.asarray(changed).ravel()}
        live_new = {u for (u, g) in self._entries if g == new_gen}
        out: "OrderedDict[Tuple[int, int], Tuple[Dict[str, Any], int]]" = \
            OrderedDict()
        stale: set = set()
        rekeyed = invalidated = 0
        for (u, g), rec in self._entries.items():
            if g == new_gen:
                out[(u, g)] = rec
            elif g == old_gen and u not in live_new:
                if u not in changed_set:
                    out[(u, new_gen)] = rec
                    rekeyed += 1
                elif retain_changed:
                    out[(u, g)] = rec
                    stale.add((u, g))
                else:
                    self.bytes_per_shard -= rec[1]
                    invalidated += 1
            else:
                self.bytes_per_shard -= rec[1]
                invalidated += 1
        self._entries = out
        self._handoff_stale = stale
        self.rekeys += rekeyed
        self.invalidations += invalidated
        return rekeyed, invalidated

    def rekey_entry(self, user: int, old_gen, new_gen) -> bool:
        """Rename ONE entry ``(user, old_gen)`` -> ``(user, new_gen)``
        in place (the per-entry twin of :meth:`rekey_generation`, used
        by the O(delta) re-warm: the caller has certified that the old
        entry plus a deferred inject reproduces what a fresh admission
        at ``new_gen`` would serve). Counts as a rekey; an existing
        ``new_gen`` entry for the user is replaced. Returns False when
        no ``(user, old_gen)`` entry exists."""
        rec = self._entries.pop((user, old_gen), None)
        if rec is None:
            return False
        prev = self._entries.pop((user, new_gen), None)
        if prev is not None:
            self.bytes_per_shard -= prev[1]
        self._entries[(user, new_gen)] = rec
        self._entries.move_to_end((user, new_gen))
        self._handoff_stale.discard((user, old_gen))
        self.rekeys += 1
        return True

    def drop(self, user: int, gen) -> bool:
        """Invalidate one entry (serve-time fallback when a deferred
        delta no longer fits the inject budget: the row must take a
        full prefill instead). Returns False when absent."""
        rec = self._entries.pop((user, gen), None)
        if rec is None:
            return False
        self.bytes_per_shard -= rec[1]
        self._handoff_stale.discard((user, gen))
        self.invalidations += 1
        return True

    # ------------------------------------------------------------------
    # Backend-neutral delta-rewarm surface (mirrored by PagedStateCache:
    # here pending tokens live inside the host entry dict, there in a
    # host-side sidecar next to the slot table — the gateway only ever
    # talks to these three methods, so the serve path cannot care which)
    # ------------------------------------------------------------------

    def has_entry(self, user: int, gen) -> bool:
        """Membership probe with NO side effects — no LRU bump, no
        hit/miss counters (``get`` counts; this peeks)."""
        return (user, gen) in self._entries

    def get_pending(self, user: int, gen) -> Optional[list]:
        """The entry's deferred-inject token list, or None."""
        rec = self._entries.get((user, gen))
        return rec[0].get("pending") if rec is not None else None

    def set_pending(self, user: int, gen, tokens) -> None:
        """Attach (or, with an empty list, clear) the entry's deferred
        snapshot-delta tokens. Raises KeyError when the entry is absent
        — pending tokens without a state to defer onto are a bug."""
        rec = self._entries.get((user, gen))
        if rec is None:
            raise KeyError(f"no entry ({user}, {gen}) to attach pending "
                           f"inject tokens to")
        if tokens:
            rec[0]["pending"] = list(tokens)
        else:
            rec[0].pop("pending", None)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "rekeys": self.rekeys,
                "handoff_stale": len(self._handoff_stale),
                "stale_evictions": self.stale_evictions,
                "bytes_per_shard": self.bytes_per_shard,
                "shards": self.shards}


# ----------------------------------------------------------------------
# Config
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Gateway/serving configuration, validated at construction.

    ``slate_len`` is the *default* items-per-request (a Request may
    override it per row, up to the engine's vocabulary — checked at
    Gateway construction / submit, where the engine is known).
    ``cache_entries`` is the prefill-state LRU budget; ``warm()`` clamps
    its user list to it (warming past the budget would prefill states
    that evict before they ever serve), so a budget of 1 is legal but
    warms exactly one user.

    **Rollover behavior.** ``warm_handoff`` keeps the rollover warm:
    cached prefill states whose snapshot row is unchanged across the
    generation roll are rekeyed to the new generation instead of purged
    (results are bitwise identical either way — the handoff only changes
    which rows pay a prefill). ``snapshot_build_budget`` switches the
    daily job from one synchronous full materialization inside
    ``submit``/``tick`` to an incremental delta build advanced by at
    most that many users per clock call (``None`` keeps the legacy
    synchronous build). ``background_build`` moves the whole build onto
    a dedicated worker thread (``BackgroundSnapshotBuilder``): clock
    calls shrink to O(1) ``poll()``s and the finished generation
    installs atomically on the serving thread — bitwise the same arrays
    as the synchronous modes, at the memory cost of double-buffering
    the feature plane during the build (it supersedes
    ``snapshot_build_budget``; sync stays the default).
    ``rewarm_budget`` re-prefills up to that many
    invalidated (changed) users per ``tick`` after a rollover, so the
    miss storm drains between panes instead of on live requests (0 =
    off; ``warm_step()`` can also be driven explicitly).

    **Continuous batching / the paged pool.** ``max_wait`` bounds how
    long a queued request may wait (in request-clock units) before it
    is served in a padded partial pane — ``0`` serves every arrival the
    moment it lands, ``None`` keeps wave semantics (pane-full /
    deadline / explicit flush only). ``pool_slots`` moves the
    prefill-state cache from the host LRU to the device-resident slot
    pool (serving/pool.py; must be >= the engine's ``max_batch``, and
    it supersedes ``cache_entries``/``cache_bytes`` — a fixed pool IS
    both budgets). The two knobs are independent: a pooled gateway can
    run wave-style and a continuous one can run on the host LRU.

    **Deadline-aware load shedding.** ``pane_service_time`` gives the
    scheduler a service model: executing one pane occupies the server
    for that many request-clock units, tracked by a busy-until marker
    (``None`` keeps the legacy instantaneous-service semantics — served
    results are bitwise unchanged either way; the model only adds
    completion-time accounting). On top of it, ``shed_policy="deadline"``
    rejects a request — at submit time or when its pane would form —
    whenever its *projected* completion time (queue position ahead of
    it, in panes, times the pane cost, on top of the busy-until marker)
    exceeds its deadline: a slate served after its deadline is worthless
    to the caller, and executing it anyway steals service time from
    requests that can still make theirs. A shed request's ticket
    resolves immediately with a typed ``Response(shed=True)`` marker
    (empty slate, telemetry ``path="shed"``) and is counted in
    ``GatewayStats.shed``; requests without a deadline are never shed.
    Requests that ARE served past their deadline (a coarse tick jumped
    the clock past it, or the service model's pane cost overran it)
    count in ``GatewayStats.deadline_misses``.
    """
    slate_len: int = 4            # items decoded per request (default)
    cache_entries: int = 4096     # LRU budget (user-generation states)
    cache_bytes: Optional[int] = None  # per-shard byte budget (None = off)
    use_cache: bool = True        # False -> full prefill per request
    run_batch_jobs: bool = True   # roll due snapshots on the clock
    warm_handoff: bool = True     # rekey unchanged rows across rollover
    snapshot_build_budget: Optional[int] = None  # users per build step
    background_build: bool = False  # build snapshots on a worker thread
    rewarm_budget: int = 0        # users re-prefilled per tick post-roll
    pool_slots: Optional[int] = None  # device state-pool slots (None = host LRU)
    max_wait: Optional[int] = None    # serve a request after waiting this long
    pane_service_time: Optional[int] = None  # sim-s one pane occupies the server
    shed_policy: Optional[str] = None  # None | "deadline" (needs service time)
    patch_policy: str = "purge"   # "purge" | "rewarm": cache policy at a
    #                               weight-patch install (see install_patch)
    delta_rewarm: bool = False    # O(delta) re-warm via deferred inject
    #                               (host LRU or paged pool; see
    #                               _try_delta_rewarm)
    log_compaction: Optional[str] = None  # None | "sync" | "background":
    #                               tick-driven tiered-EventLog window
    #                               compaction (needs a windowed log)

    def __post_init__(self):
        if self.snapshot_build_budget is not None \
                and self.snapshot_build_budget < 1:
            raise ValueError(
                f"snapshot_build_budget must be >= 1 when set (None runs "
                f"the legacy synchronous build), got "
                f"{self.snapshot_build_budget}")
        if self.rewarm_budget < 0:
            raise ValueError(
                f"rewarm_budget must be >= 0, got {self.rewarm_budget}")
        if self.slate_len < 1:
            raise ValueError(
                f"slate_len must be >= 1, got {self.slate_len}")
        if self.cache_entries < 1:
            raise ValueError(
                f"cache_entries must be >= 1, got {self.cache_entries} "
                f"(warm() clamps its user list to this budget, so even a "
                f"cacheless deployment needs a >= 1 placeholder — use "
                f"use_cache=False to disable caching)")
        if self.cache_bytes is not None and self.cache_bytes < 1:
            raise ValueError(
                f"cache_bytes must be >= 1 when set (None disables the "
                f"byte budget), got {self.cache_bytes}")
        if self.pool_slots is not None and self.pool_slots < 1:
            raise ValueError(
                f"pool_slots must be >= 1 when set (None keeps the host "
                f"LRU), got {self.pool_slots}")
        if self.max_wait is not None and self.max_wait < 0:
            raise ValueError(
                f"max_wait must be >= 0 when set (0 serves every arrival "
                f"immediately; None keeps wave semantics), got "
                f"{self.max_wait}")
        if self.pane_service_time is not None and self.pane_service_time < 1:
            raise ValueError(
                f"pane_service_time must be >= 1 when set (None keeps "
                f"instantaneous-service semantics), got "
                f"{self.pane_service_time}")
        if self.shed_policy not in (None, "deadline"):
            raise ValueError(
                f"unknown shed_policy {self.shed_policy!r}; expected "
                f"None (never shed) or 'deadline'")
        if self.shed_policy is not None and self.pane_service_time is None:
            raise ValueError(
                "shed_policy='deadline' needs pane_service_time set: "
                "without a service model every queue drains instantly "
                "and no projected completion can ever miss a deadline")
        if self.patch_policy not in ("purge", "rewarm"):
            raise ValueError(
                f"unknown patch_policy {self.patch_policy!r}; expected "
                f"'purge' (drop version-stale entries at a weight-patch "
                f"install) or 'rewarm' (queue them for budgeted re-warm)")
        if self.log_compaction not in (None, "sync", "background"):
            raise ValueError(
                f"unknown log_compaction {self.log_compaction!r}; "
                f"expected None (no tick-driven compaction), 'sync' "
                f"(compact inline on the tick that finds a window due) "
                f"or 'background' (off-thread BackgroundCompactor, "
                f"polled/installed on ticks)")


# ----------------------------------------------------------------------
# The Gateway
# ----------------------------------------------------------------------

class Gateway:
    """The unified serving facade: request submission, micro-batching,
    event ingestion and clock/snapshot management in one object.

    Works identically on a single device and on a data-parallel mesh:
    the engine owns all placement, the gateway only ever builds
    fixed-shape ``max_batch`` panes — which the engine has already
    validated against the mesh's data-axis size — so the scheduling code
    has no sharding branches at all.
    """

    def __init__(self, engine: ServingEngine, injector: FeatureInjector,
                 cfg: ServerConfig = ServerConfig()):
        if injector.cfg.policy not in POLICIES:
            raise ValueError(
                f"unknown default policy {injector.cfg.policy!r} on the "
                f"injector; the gateway serves {POLICIES}")
        if cfg.slate_len > engine.cfg.vocab_size:
            raise ValueError(
                f"slate_len={cfg.slate_len} exceeds the engine's item "
                f"vocabulary ({engine.cfg.vocab_size}); a slate decodes "
                f"distinct items, so it cannot be longer than the catalog")
        self.engine = engine
        self.injector = injector
        self.cfg = cfg
        if cfg.pool_slots is not None:
            from repro.serving.pool import DeviceStatePool, PagedStateCache
            self.pool: Optional["DeviceStatePool"] = DeviceStatePool(
                engine, cfg.pool_slots)
            self.cache = PagedStateCache(self.pool)
        else:
            self.pool = None
            self.cache = PrefillStateCache(cfg.cache_entries,
                                           byte_budget=cfg.cache_bytes,
                                           shards=engine.data_shards)
        # the cache-key generation is COMPOSITE: (snapshot cutoff,
        # model version). Both caches compare keys only by equality, so
        # a weight-patch install invalidates exactly like a snapshot
        # roll — by making every old key unequal to the current one
        self._gen: Optional[Tuple[int, int]] = None
        self._model_version = 0   # advances only inside install_patch
        self._trainer = None      # attached OnlineTrainer (patch source)
        # (old_vgen, new_vgen) of the last CERTIFIED warm handoff, while
        # its retained old-generation entries are still eligible for the
        # O(delta) deferred-inject re-warm; cleared by the next handoff
        # or patch install
        self._handoff_from: Optional[Tuple[Tuple[int, int],
                                           Tuple[int, int]]] = None
        self._clock: Optional[int] = None
        self._queue: List[Ticket] = []
        self._completed: deque = deque()  # served, unclaimed by poll()
        self._next_id = 0
        # incremental daily job (snapshot_build_budget mode)
        self._builder = None          # in-flight SnapshotBuilder, or None
        self._compactor = None        # BackgroundCompactor, lazily created
        self._skip_register: List[int] = []  # past-retention boundaries,
        #                               registered when the build installs
        self._rewarm_queue: deque = deque()  # users invalidated at handoff
        # counters / telemetry
        self.requests = 0
        self.panes = 0
        self.prefill_calls = 0
        self.inject_calls = 0
        self.decode_steps = 0
        self.shed = 0             # requests rejected by the load-shedder
        self.deadline_misses = 0  # requests served past their deadline
        self._busy_until = 0      # service model: sim-time the server frees
        self._path_counts = {"prefill": 0, "inject": 0, "cached": 0,
                             "decay": 0}
        self._queue_delays: deque = deque(maxlen=4096)
        self._deadline_flushes = 0
        self._rollover = {"rollovers": 0, "rekeyed": 0, "invalidated": 0,
                          "retained": 0, "rebuilt": 0, "delta_rewarms": 0,
                          "build_steps": 0, "build_time_s": 0.0,
                          "build_slice_max_s": 0.0}
        self._patches_applied = 0
        self._patch_install_max_s = 0.0

    # ------------------------------------------------------------------
    # Clock / snapshot plumbing
    # ------------------------------------------------------------------

    @property
    def clock(self) -> Optional[int]:
        """The gateway's current time: the max ``now`` seen across
        submit/tick/flush. Never moves backwards."""
        return self._clock

    @property
    def pending(self) -> int:
        """Requests queued but not yet served."""
        return len(self._queue)

    def _advance(self, now: Optional[int]) -> None:
        if now is not None and (self._clock is None or now > self._clock):
            self._clock = int(now)

    def _sync_generation(self, now: int) -> Tuple[int, int]:
        """Advance the daily job and hand the cache across any resulting
        generation roll. Returns the current **composite** generation
        ``(snapshot cutoff, model version)`` — the cache key axis pair:
        snapshot rolls move the first component (warm handoff below),
        weight-patch installs move the second (``install_patch``).

        With ``snapshot_build_budget`` unset the job is the legacy
        synchronous ``maybe_run_due_snapshots`` (a due boundary
        materializes the full plane inside this call); with a budget the
        in-flight :class:`SnapshotBuilder` advances by at most one
        budget-sized slice per call, so a 1M-user build amortizes across
        panes instead of stalling one submit; with ``background_build``
        the slice is an O(1) ``poll()`` of the worker thread. Either
        way, the moment the generation actually rolls the cache takes
        the **warm handoff** (see ``_handoff``) instead of the old
        purge-everything. The wall time each call spends advancing the
        job is tracked in ``build_slice_max_s`` — the boundary-stall
        telemetry the scenario SLO gates read."""
        if self.cfg.run_batch_jobs:
            t0 = time.perf_counter()
            if self.cfg.background_build \
                    or self.cfg.snapshot_build_budget is not None:
                self._step_snapshot_build(now)
            else:
                self.injector.batch.maybe_run_due_snapshots(now)
            dt = time.perf_counter() - t0
            if dt > self._rollover["build_slice_max_s"]:
                self._rollover["build_slice_max_s"] = dt
        gen = (self.injector.generation(now), self._model_version)
        if gen != self._gen:
            self._handoff(self._gen, gen)
            self._gen = gen
        return gen

    def _step_snapshot_build(self, now: int) -> None:
        """One budget-bounded slice of the amortized daily job: start a
        builder when a boundary has passed, advance it.

        Catch-up matches the synchronous job's contract: after a gap of
        several periods, every missed boundary inside the retention
        window is built **in order** (one builder each — which also
        keeps every delta one-period small), and only boundaries that
        would be evicted immediately register without arrays. The
        generation therefore rolls forward boundary by boundary as
        builds land, never jumping over a generation the synchronous
        path would have materialized."""
        store = self.injector.batch
        c = store.cfg
        latest_due = store.latest_due_boundary(now)
        if self._builder is None:
            if not store._snapshot_times:
                # cold store: there is no previous generation to delta
                # against or serve from, so amortizing buys nothing —
                # delegate the whole catch-up to the synchronous job
                store.maybe_run_due_snapshots(now)
                return
            due = store._snapshot_times[-1] + c.snapshot_period
            if due > latest_due:
                return
            # boundaries already past retention will register WITHOUT
            # arrays (the synchronous job's retention skip) — but only
            # once the first real build installs: registering them now
            # would make a register-only generation the serving latest
            # for the whole build window, and everything cached against
            # it would key to a recompute-on-read (non-frozen)
            # generation, violating the cache-key invariant
            skipped = []
            while c.snapshot_retention is not None and due <= latest_due \
                    - c.snapshot_retention * c.snapshot_period:
                skipped.append(due)
                due += c.snapshot_period
            self._builder = (store.begin_snapshot_background(due)
                             if self.cfg.background_build
                             else store.begin_snapshot(due))
            self._skip_register = skipped
        b = self._builder
        if self.cfg.background_build:
            # O(1) while the worker runs; the call that finds the worker
            # finished pays only the finish-time fixup + atomic install
            remaining = b.poll()
        else:
            remaining = b.step(self.cfg.snapshot_build_budget)
            self._rollover["build_steps"] += 1
        if remaining == 0:
            if self.cfg.background_build:
                self._rollover["build_steps"] += b.steps
            self._rollover["build_time_s"] += b.step_time_s
            for due in self._skip_register:
                store._register_time(due)
            self._skip_register = []
            self._builder = None

    def _handoff(self, old_gen: Optional[Tuple[int, int]],
                 new_gen: Tuple[int, int]) -> None:
        """Cache handoff at a generation roll: rekey entries whose
        snapshot row is unchanged (identical history => identical prefill
        state, so served results are bitwise what a purge + re-prefill
        would produce), invalidate the changed rest, and queue the
        invalidated users for budgeted re-warming. Falls back to the
        purge-everything rollover whenever the exact changed set cannot
        be certified (first generation, handoff disabled, a generation
        gap, either generation evicted/recomputed, or a model-version
        change riding the same roll — a prefill state is a function of
        (history, params), so rekeying across params is never safe;
        ``install_patch`` handles the params axis itself)."""
        self._handoff_from = None
        if old_gen is None:
            # first sync: the gateway is discovering the current
            # generation, not rolling one — nothing can be cached yet
            self.cache.invalidate_except(new_gen)
            return
        changed = None
        if self.cfg.warm_handoff and old_gen[0] >= 0 \
                and old_gen[1] == new_gen[1]:
            changed = self.injector.batch.changed_users_between(
                old_gen[0], new_gen[0])
        stale_users = [u for (u, g) in self.cache._entries if g != new_gen]
        if changed is None:
            invalidated = self.cache.invalidate_except(new_gen)
            rekeyed = 0
        else:
            # certified handoff: changed users' old-generation entries
            # are RETAINED for the handoff window (first-victim under
            # budget pressure) instead of purged — the dual-generation
            # residency the rollover-aware eviction order manages
            rekeyed, invalidated = self.cache.rekey_generation(
                old_gen, new_gen, changed, retain_changed=True)
            self._rollover["retained"] += len(self.cache._handoff_stale)
            self._handoff_from = (old_gen, new_gen)
        # MRU-first re-warm order: the hottest invalidated users are the
        # ones most likely to be requested right after the roll
        # (dict.fromkeys dedups a user cached under two stale generations)
        self._rewarm_queue = deque(dict.fromkeys(
            u for u in reversed(stale_users)
            if (u, new_gen) not in self.cache))
        self._rollover["rollovers"] += 1
        self._rollover["rekeyed"] += rekeyed
        self._rollover["invalidated"] += invalidated

    # ------------------------------------------------------------------
    # Online weight patches (hot swap)
    # ------------------------------------------------------------------

    def attach_trainer(self, trainer) -> None:
        """Attach an :class:`~repro.training.online.OnlineTrainer` as the
        gateway's patch source: every ``tick``/drain boundary polls it
        for finished delta patches and installs them via
        :meth:`install_patch` — always *between* panes, never mid-pane.
        The trainer's base version must match the gateway's current
        model version (both start at 0)."""
        if trainer is not None and trainer.version != self._model_version:
            raise ValueError(
                f"trainer is at version {trainer.version} but the "
                f"gateway serves model version {self._model_version}; "
                f"patches would fail the base-version guard")
        self._trainer = trainer

    def install_patch(self, patch) -> int:
        """Hot-swap a :class:`~repro.training.online.WeightPatch` into
        the live engine: O(patch) — only the patched leaves move, the
        jit caches survive (same shapes/dtypes), and there is no
        checkpoint reload. The patch must be based on the currently
        served version (base-version guard); the install advances the
        model-version axis of the composite cache generation, so every
        state prefilled under the old weights becomes unreachable
        atomically. ``patch_policy`` decides their fate: ``"purge"``
        drops them; ``"rewarm"`` queues their users (MRU-first) for the
        budgeted ``warm_step`` re-prefill under the new weights.

        Only this method ever advances ``model_version``, and it runs
        synchronously on the serving thread between panes — a pane in
        flight always scores every row under one parameter set.
        Returns the number of leaves swapped."""
        if patch.base_version != self._model_version:
            raise ValueError(
                f"patch {patch.version} is based on version "
                f"{patch.base_version}, but the gateway serves version "
                f"{self._model_version}; re-emit the patch from the "
                f"served version (patches never skip or rewind)")
        t0 = time.perf_counter()
        n = self.engine.apply_patch(patch.leaves)
        self._model_version = int(patch.version)
        self._patches_applied += 1
        # a params change invalidates the delta-rewarm window too: the
        # retained old-generation states were prefilled under old weights
        self._handoff_from = None
        if self._gen is not None:
            old_vgen = self._gen
            new_vgen = (old_vgen[0], self._model_version)
            stale_users = [u for (u, g) in self.cache._entries
                           if g != new_vgen]
            self.cache.invalidate_except(new_vgen)
            if self.cfg.patch_policy == "rewarm":
                self._rewarm_queue = deque(dict.fromkeys(
                    reversed(stale_users)))
            else:
                self._rewarm_queue.clear()
            self._gen = new_vgen
        dt = time.perf_counter() - t0
        if dt > self._patch_install_max_s:
            self._patch_install_max_s = dt
        return n

    def _maybe_install_patches(self) -> int:
        """Drain the attached trainer's finished patches (if any) into
        the engine. Called at the top of ``tick`` and of every queue
        drain — the between-panes boundaries — so an in-flight pane
        never observes a version change."""
        tr = self._trainer
        if tr is None:
            return 0
        n = 0
        while True:
            patch = tr.poll_patch()
            if patch is None:
                break
            self.install_patch(patch)
            n += 1
        return n

    # ------------------------------------------------------------------
    # Ingestion (the other half of the facade)
    # ------------------------------------------------------------------

    def _event_user_limit(self) -> int:
        """Max exclusive user id BOTH stores accept. Ingestion validates
        against this *before* any write: the batch log and the realtime
        ring must never diverge on what they absorbed — a half-applied
        event batch would make the merge double-count or drop events
        forever after."""
        limit = self.injector.batch.cfg.n_users
        if self.injector.realtime is not None:
            limit = min(limit, self.injector.realtime.cfg.n_users)
        return limit

    def observe(self, ev) -> None:
        """Ingest one feedback event into both feature stores (offline
        log + realtime stream). Accepts an :class:`Event`, a
        ``(user, item, ts)`` tuple, or any object with those attributes
        — the same hook signature the platform exposes. The user id is
        validated against *both* stores up front so a rejected event
        mutates neither."""
        ev = as_event(ev)
        limit = self._event_user_limit()
        if not 0 <= ev.user < limit:
            raise IndexError(
                f"event user {ev.user} out of range [0, {limit}) for the "
                f"feature stores; nothing was ingested")
        self.injector.batch.append(ev.user, ev.item, ev.ts)
        if self.injector.realtime is not None:
            self.injector.realtime.ingest(ev.user, ev.item, ev.ts)

    def observe_many(self, users, items, tss) -> None:
        """Columnar bulk ingest (parallel arrays) of feedback events.

        The whole batch is validated against BOTH stores before either
        absorbs anything: the batch log's own range check fires before
        it writes, but the realtime store's used to fire only *after*
        the log had already extended — a bad batch left the two stores
        silently diverged (events the merge would count once instead of
        twice, or the reverse). A rejected batch now mutates nothing."""
        users = np.asarray(users, np.int64).ravel()
        items = np.asarray(items).ravel()
        tss = np.asarray(tss).ravel()
        if not (len(users) == len(items) == len(tss)):
            raise ValueError(
                f"observe_many wants parallel arrays; got lengths "
                f"users={len(users)} items={len(items)} ts={len(tss)}")
        if len(users):
            limit = self._event_user_limit()
            lo, hi = int(users.min()), int(users.max())
            if lo < 0 or hi >= limit:
                raise IndexError(
                    f"event user ids out of range [0, {limit}): "
                    f"[{lo}, {hi}]; nothing was ingested")
        self.injector.batch.extend(users, items, tss)
        if self.injector.realtime is not None:
            self.injector.realtime.extend(users, items, tss)

    def tick(self, now: int) -> List[Ticket]:
        """Advance the gateway clock: advance/roll due snapshots (warm
        handoff on a generation change), flush the queue if any pending
        request's deadline has been reached, then spend the configured
        ``rewarm_budget`` re-prefilling users the last rollover
        invalidated. Returns tickets served by a deadline flush
        (usually none)."""
        self._advance(now)
        self._maybe_install_patches()
        self._sync_generation(self._clock)
        if self.cfg.log_compaction is not None:
            self._step_compaction(self._clock)
        served: List[Ticket] = []
        if self._deadline_due():
            self._deadline_flushes += 1
            served = self._drain(full_panes_only=False)
        elif self._wait_exceeded():
            served = self._drain(full_panes_only=False)
        if self.cfg.rewarm_budget:
            self.warm_step(self.cfg.rewarm_budget)
        return served

    def _step_compaction(self, now: Optional[int]) -> None:
        """Tick-driven tiered-log maintenance (``log_compaction``):
        fold elapsed hot-tail windows into warm segments and evict past
        retention, bounding ingest memory. ``"sync"`` compacts inline on
        the tick that finds a window due; ``"background"`` starts an
        off-thread :class:`~repro.core.event_log.BackgroundCompactor`
        build and installs it on a later tick's O(1) poll — either way
        installation happens here, between panes, so no pane ever reads
        a half-swapped tail. The attached trainer's cursor rides along
        as ``keep_from``: events it has not consumed yet are pinned in
        the hot tail (never trimmed or evicted under it), which is what
        keeps ``events_since`` gapless across compaction."""
        log = self.injector.batch._log
        if now is None or log.window is None:
            return
        keep_from = (self._trainer.cursor
                     if self._trainer is not None else None)
        if self.cfg.log_compaction == "background":
            if self._compactor is None:
                from repro.core.event_log import BackgroundCompactor
                self._compactor = BackgroundCompactor(log)
            if self._compactor.active:
                self._compactor.poll()
            elif log.compaction_due(int(now)):
                self._compactor.start(int(now), keep_from=keep_from)
        elif log.compaction_due(int(now)):
            log.compact(int(now), keep_from=keep_from)

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def _check_request(self, req: Request) -> None:
        if req.slate_len is not None and \
                req.slate_len > self.engine.cfg.vocab_size:
            raise ValueError(
                f"request slate_len={req.slate_len} exceeds the engine's "
                f"item vocabulary ({self.engine.cfg.vocab_size})")
        n_users = self.injector.batch.cfg.n_users
        if req.user >= n_users:
            # fail at the call site — inside pane execution this would be
            # a numpy IndexError that takes the whole pane down with it
            raise ValueError(
                f"request user {req.user} is out of range for the "
                f"feature plane (n_users={n_users})")

    def submit(self, request: Request) -> Ticket:
        """Enqueue one arrival. Flushes immediately when the queue
        reaches a full ``max_batch`` pane, or when the arrival's clock
        reaches a pending deadline; otherwise the request waits for
        pane-full / deadline / ``tick`` / ``flush``. With
        ``shed_policy="deadline"`` an arrival whose projected completion
        already exceeds its deadline is rejected here — its ticket
        resolves immediately with the shed marker and never enqueues."""
        self._check_request(request)
        self._advance(request.now)
        t = Ticket(request, self._next_id, time.perf_counter())
        self._next_id += 1
        if self._should_shed(request, len(self._queue)):
            self._shed_ticket(t)
            return t
        self._queue.append(t)
        self._maybe_flush()
        return t

    def submit_many(self, requests: Sequence[Request]) -> List[Ticket]:
        """Enqueue a batch of arrivals that are known together (a wave).

        Unlike per-request ``submit``, the whole batch lands in the
        queue before any pane forms, so the cache-aware partitioning
        sees all of it at once — this is exactly the legacy wave
        semantics, and full panes are flushed eagerly; a short remainder
        stays queued for deadline/flush."""
        for req in requests:
            # validate the WHOLE batch before enqueuing any of it: a bad
            # request mid-batch must not leave earlier rows queued with
            # their ticket handles lost to the exception
            self._check_request(req)
        tickets = []
        for req in requests:
            t = Ticket(req, self._next_id, time.perf_counter())
            self._next_id += 1
            self._advance(req.now)
            if self._should_shed(req, len(self._queue)):
                self._shed_ticket(t)
            else:
                self._queue.append(t)
            tickets.append(t)
        self._maybe_flush()
        return tickets

    def flush(self, now: Optional[int] = None) -> List[Ticket]:
        """Serve everything queued (the last pane padded if short)."""
        self._advance(now)
        return self._drain(full_panes_only=False)

    def poll(self) -> List[Ticket]:
        """Claim every ticket whose row has retired since the last
        ``poll``/``drain`` — the streaming half of the completion API.
        Never blocks and never serves; pair it with ``submit`` (+
        ``tick`` to advance the clock) for a caller loop that consumes
        responses as rows retire instead of holding wave-shaped ticket
        lists. Tickets stay claimable exactly once."""
        out = list(self._completed)
        self._completed.clear()
        return out

    def drain(self, deadline: Optional[int] = None) -> List[Ticket]:
        """Advance the clock to ``deadline`` (when given), serve
        everything still queued (last pane padded if short), and claim
        completions: returns every ticket finished since the last
        ``poll``/``drain`` — the just-served queue plus anything an
        earlier pane-full or deadline flush already retired."""
        self.flush(deadline)
        return self.poll()

    def _deadline_due(self) -> bool:
        if self._clock is None:
            return False
        return any(t.request.deadline is not None
                   and t.request.deadline <= self._clock
                   for t in self._queue)

    def _wait_exceeded(self) -> bool:
        """Continuous admission: some queued request has waited
        ``max_wait`` request-clock units (always true for ``max_wait=0``
        with anything queued)."""
        mw = self.cfg.max_wait
        if mw is None or self._clock is None or not self._queue:
            return False
        return any(self._clock - t.request.now >= mw for t in self._queue)

    # ------------------------------------------------------------------
    # Deadline-aware load shedding (shed_policy="deadline")
    # ------------------------------------------------------------------

    def _projected_done(self, position: int) -> int:
        """Projected completion time of a request at queue ``position``
        (0-based), assuming back-to-back full-pane drains from here on:
        the request rides pane ``position // max_batch`` of the drain,
        and each pane occupies the server for ``pane_service_time`` on
        top of the busy-until marker. This is the *optimistic* drain
        schedule — the queue can only complete later than this (partial
        panes, new arrivals jumping into earlier panes never happen,
        reordering preserves pane count) — so shedding on it never
        rejects a request that could actually have been served in time
        under full panes."""
        cost = self.cfg.pane_service_time
        base = self._busy_until
        if self._clock is not None:
            base = max(base, int(self._clock))
        b = self.engine.scfg.max_batch
        return base + (position // b + 1) * cost

    def _should_shed(self, req: Request, position: int) -> bool:
        """Submit-time admission control: would this request, placed at
        ``position`` in the queue, already complete past its deadline?
        Requests without a deadline are never shed."""
        if self.cfg.shed_policy != "deadline" or req.deadline is None:
            return False
        return self._projected_done(position) > req.deadline

    def _shed_overdue(self) -> List[Ticket]:
        """Flush-time admission recheck, run before panes form: walk
        the queue in order and shed any deadline-carrying request whose
        projected completion — at the position it actually occupies
        after earlier sheds compact the queue — exceeds its deadline.
        Kept requests keep their relative order; returns the shed
        tickets (already resolved and claimable)."""
        kept: List[Ticket] = []
        shed: List[Ticket] = []
        for t in self._queue:
            d = t.request.deadline
            if d is not None and self._projected_done(len(kept)) > d:
                self._shed_ticket(t)
                shed.append(t)
            else:
                kept.append(t)
        self._queue = kept
        return shed

    def _shed_ticket(self, t: Ticket) -> None:
        """Resolve a ticket with the typed shed marker: empty
        slate/scores, telemetry ``path="shed"`` with ``pane_id=-1``,
        claimable through ``poll``/``drain`` like any completion — a
        shed ticket must never block a caller draining the stream. Shed
        rows count in ``stats().shed``, not in ``paths`` (they were
        never served) and not in the queue-delay percentiles."""
        now = int(self._clock) if self._clock is not None else t.request.now
        tel = RequestTelemetry(
            request_id=t.request_id, user=t.request.user,
            policy=self._policy_of(t.request),
            slate_len=t.request.slate_len or self.cfg.slate_len,
            pane_id=-1, queue_delay=max(0, now - t.request.now),
            cache_hit=False, path="shed",
            generation=self._gen[0] if self._gen is not None else -1,
            submitted_at=t.request.now, served_at=now, tag=t.request.tag,
            model_version=self._model_version)
        t.response = Response(slate=np.empty(0, np.int32),
                              scores=np.empty(0, np.float32),
                              telemetry=tel, shed=True)
        t.completed_wall = time.perf_counter()
        self._completed.append(t)
        self.shed += 1

    def _maybe_flush(self) -> None:
        """The one flush-trigger policy for every enqueue path: a due
        deadline drains everything (padded short pane); a request past
        the continuous-mode ``max_wait`` likewise drains everything —
        the queue it drains is whatever is known at that moment, so a
        ``submit_many`` wave still forms full panes while per-arrival
        ``submit`` serves immediately; otherwise a full pane's worth of
        queued requests drains eagerly."""
        if self._deadline_due():
            self._deadline_flushes += 1
            self._drain(full_panes_only=False)
        elif self._wait_exceeded():
            self._drain(full_panes_only=False)
        elif len(self._queue) >= self.engine.scfg.max_batch:
            self._drain(full_panes_only=True)

    # ------------------------------------------------------------------
    # The scheduler core
    # ------------------------------------------------------------------

    def _row_cacheable(self, policy: str) -> bool:
        # "fresh" histories move with the serve clock (cache-key
        # invariant); "decay" rows never build an engine state at all
        return self.cfg.use_cache and policy not in ("fresh", "decay")

    def _policy_of(self, req: Request) -> str:
        return req.policy or self.injector.cfg.policy

    def _drain(self, full_panes_only: bool) -> List[Ticket]:
        """Form and serve panes from the queue.

        Cache-aware pane formation: when more than one pane is queued,
        rows are stably partitioned hits-first over the *whole* queue
        (uncacheable rows sort with the misses) before slicing into
        fixed ``max_batch`` panes — one cold row in a pane of hits would
        otherwise drag the whole pane onto the prefill path. Rows are
        independent, so regrouping cannot change any result.
        """
        self._maybe_install_patches()
        if not self._queue:
            return []
        now = self._clock
        gen = self._sync_generation(now)
        shed: List[Ticket] = []
        if self.cfg.shed_policy == "deadline":
            # shed before panes form (and before the cache-aware
            # reorder): a request that cannot make its deadline must
            # not occupy a pane row a viable request could ride
            shed = self._shed_overdue()
            if not self._queue:
                return shed
        b = self.engine.scfg.max_batch
        q = self._queue
        if len(q) > b:
            is_miss = np.array([
                not self._row_cacheable(self._policy_of(t.request))
                or (t.request.user, gen) not in self.cache
                for t in q])
            order = np.argsort(is_miss, kind="stable")  # hits first
            q = [q[i] for i in order]
        # adopt the (possibly reordered) queue up front and dequeue pane
        # by pane AS each one serves: if a later pane raises, the served
        # tickets are already out of the queue — a retried flush must
        # never re-execute a pane whose responses the caller may hold
        self._queue = q
        served: List[Ticket] = shed
        while len(self._queue) >= b:
            pane = self._queue[:b]
            self._execute(pane, gen)
            self._queue = self._queue[b:]
            served.extend(pane)
        if not full_panes_only and self._queue:
            pane = list(self._queue)
            self._execute(pane, gen)
            self._queue = []
            served.extend(pane)
        return served

    # ------------------------------------------------------------------
    # Feature -> token assembly (per-row policy and clock)
    # ------------------------------------------------------------------

    def _histories(self, reqs: Sequence[Request], policies: Sequence[str],
                   now: int) -> List[List[int]]:
        """Per-row batch-history token lists, read at the pane's serve
        clock ``now``. Features are **serve-time**, not arrival-time: a
        pane is assembled once, when it executes, against the freshest
        store state available — which is also what keeps a mixed pane at
        one store lookup per history flavor ("batch"/"inject" share the
        snapshot prefix; "fresh" reads at the serve cutoff) instead of
        one per distinct arrival time."""
        out: List[Optional[List[int]]] = [None] * len(reqs)
        groups: "OrderedDict[bool, List[int]]" = OrderedDict()
        for i, pol in enumerate(policies):
            groups.setdefault(pol == "fresh", []).append(i)
        for fresh, rows in groups.items():
            users = np.asarray([reqs[i].user for i in rows], np.int64)
            if fresh:
                items, _, valid = self.injector.batch.lookup_at_cutoff(
                    users, now)
            else:
                items, _, valid = self.injector.batch.lookup(users, now)
            toks = items_to_tokens(items, valid)
            for j, i in enumerate(rows):
                out[i] = toks[j][valid[j] > 0].tolist()
        return out  # type: ignore[return-value]

    def _suffixes(self, reqs: Sequence[Request], policies: Sequence[str],
                  now: int) -> List[List[int]]:
        """Per-row fresh-suffix token lists at the serve clock; only
        "inject" rows carry one (a single ``fresh_suffix_tokens`` call
        per pane, capped at inject_len newest events — see its docstring
        for why truncation happens before tokenization)."""
        out: List[List[int]] = [[] for _ in reqs]
        if self.injector.realtime is None:
            return out
        rows = [i for i, pol in enumerate(policies) if pol == "inject"]
        if not rows:
            return out
        users = np.asarray([reqs[i].user for i in rows], np.int64)
        sfx = self.injector.fresh_suffix_tokens(
            users, now, cap=self.engine.scfg.inject_len)
        for j, i in enumerate(rows):
            out[i] = sfx[j]
        return out

    # ------------------------------------------------------------------
    # Pane execution
    # ------------------------------------------------------------------

    def _execute(self, pane: List[Ticket], gen: Tuple[int, int]) -> None:
        eng = self.engine
        pane_id = self.panes
        self.panes += 1
        reqs = [t.request for t in pane]
        now = int(self._clock)  # serve-time feature clock for the pane
        policies = [self._policy_of(r) for r in reqs]
        slate_lens = [r.slate_len or self.cfg.slate_len for r in reqs]
        # per-pane-row results, scattered by the policy branches below
        row_slate: List[Optional[np.ndarray]] = [None] * len(reqs)
        row_scores: List[Optional[np.ndarray]] = [None] * len(reqs)
        hit_all = [False] * len(reqs)
        path_all = [""] * len(reqs)

        # "decay" rows are served model-free (no engine state, no cache
        # entry): slates ranked by exponentially time-decayed event
        # scores over the row's cutoff-exact features. Carved out here
        # so the engine pane below only carries model-scored rows —
        # rows are independent, so the split cannot change any result.
        drows = [i for i, p in enumerate(policies) if p == "decay"]
        if drows:
            self._serve_decay(reqs, drows, slate_lens, now,
                              row_slate, row_scores, path_all)
        erows = [i for i, p in enumerate(policies) if p != "decay"]
        if erows:
            self._serve_engine(reqs, erows, policies, slate_lens, gen, now,
                               row_slate, row_scores, hit_all, path_all)

        # service model: with pane_service_time set, this pane occupies
        # the server for `cost` sim-seconds past whenever it frees up —
        # completion times (and therefore queue delays and deadline
        # misses) account for the backlog, not just the flush clock
        cost = self.cfg.pane_service_time
        if cost is None:
            done_at = int(self._clock)
        else:
            self._busy_until = max(self._busy_until, int(self._clock)) + cost
            done_at = self._busy_until
        wall = time.perf_counter()
        for i, (t, pol) in enumerate(zip(pane, policies)):
            tel = RequestTelemetry(
                request_id=t.request_id, user=t.request.user, policy=pol,
                slate_len=slate_lens[i], pane_id=pane_id,
                # clamped at 0: the deprecated legacy shim rewinds the
                # otherwise-monotonic clock for non-monotonic serve(now)
                # replays, and a pending request from a later wave would
                # otherwise record a negative delay and pollute the
                # stats() queue-delay percentiles
                queue_delay=max(0, int(done_at - t.request.now)),
                cache_hit=hit_all[i], path=path_all[i], generation=gen[0],
                submitted_at=t.request.now, served_at=done_at,
                tag=t.request.tag, model_version=gen[1])
            t.response = Response(slate=row_slate[i], scores=row_scores[i],
                                  telemetry=tel)
            t.completed_wall = wall
            if t.request.deadline is not None \
                    and done_at > t.request.deadline:
                self.deadline_misses += 1
            self._path_counts[path_all[i]] += 1
            self._queue_delays.append(tel.queue_delay)
        self._completed.extend(pane)  # rows retire -> claimable via poll()
        self.requests += len(pane)

    def _serve_decay(self, reqs: Sequence[Request], rows: Sequence[int],
                     slate_lens: Sequence[int], now: int,
                     row_slate: List, row_scores: List,
                     path_all: List[str]) -> None:
        """Model-free serving for policy "decay": one cutoff-exact
        feature lookup for the pane's decay rows, per-item scores
        ``sum(0.5 ** (age / half_life))``, slate = highest-scoring
        distinct items (ties broken item-ascending — the stable argsort
        over negated scores — so slates are deterministic wherever the
        features are)."""
        users = np.asarray([reqs[i].user for i in rows], np.int64)
        feats = self.injector.batch.lookup_at_cutoff(users, now)
        sc = decay_scores(feats, now, self.injector.cfg.half_life,
                          self.engine.cfg.vocab_size)
        for j, i in enumerate(rows):
            order = np.argsort(-sc[j], kind="stable")
            row_slate[i] = order[:slate_lens[i]].astype(np.int32)
            row_scores[i] = sc[j].astype(np.float32)
            path_all[i] = "decay"

    def _serve_engine(self, reqs: Sequence[Request], rows: Sequence[int],
                      policies: Sequence[str], slate_lens: Sequence[int],
                      gen: Tuple[int, int], now: int,
                      row_slate: List, row_scores: List,
                      hit_all: List[bool], path_all: List[str]) -> None:
        """The model-scored pane body (every non-"decay" row)."""
        eng = self.engine
        ereqs = [reqs[i] for i in rows]
        epol = [policies[i] for i in rows]
        elens = [slate_lens[i] for i in rows]
        suffix = self._suffixes(ereqs, epol, now)
        cacheable = [self._row_cacheable(p) for p in epol]
        if self.cfg.delta_rewarm:
            # deferred-delta entries (O(delta) re-warm): the snapshot
            # delta the entry skipped at rekey time rides ahead of the
            # row's realtime suffix in the SAME inject — token-for-token
            # the stream the pre-rollover path would have injected. The
            # entry is read-only (states are never written back), so the
            # pending tokens stay attached until the entry is evicted or
            # the next handoff sweeps it. Peek without touching LRU
            # order or hit/miss counters; the cache probe happens next.
            cap = eng.scfg.inject_len
            for i, (req, can) in enumerate(zip(ereqs, cacheable)):
                if not can:
                    continue
                pending = self.cache.get_pending(req.user, gen)
                if not pending:
                    continue
                combined = list(pending) + suffix[i]
                if len(combined) <= cap:
                    suffix[i] = combined
                else:
                    # delta + fresh events outgrew one inject: the
                    # deferral no longer pays — fall back to a full
                    # prefill for this user (drop makes the row a miss)
                    self.cache.drop(req.user, gen)

        if not any(cacheable):
            # pure-uncacheable pane (policy "fresh", or caching off):
            # one prefill of history[-prefill_len:] + suffix per row —
            # truncating BEFORE the append keeps this path's token
            # streams identical to the cached path's prefill pane even
            # when the feature history is longer than prefill_len. A
            # suffix-free pane pads to prefill_len exactly: that puts
            # its rows at the same right-aligned RoPE offsets as the
            # cacheable path's prefill pane, so a row's scores don't
            # depend on which pane composition served it (the
            # continuous scheduler's partial panes must be bitwise
            # equal to the wave path's mixed panes).
            hists = self._histories(ereqs, epol, now)
            p = eng.scfg.prefill_len
            streams = [h[-p:] + s for h, s in zip(hists, suffix)]
            buf = p + (eng.scfg.inject_len if any(suffix) else 0)
            toks, valid = eng.pad_tokens(streams, buf)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            first = state["logits"][:, -1]
            hit_flags = [False] * len(ereqs)
            paths = ["prefill"] * len(ereqs)
        else:
            if self.pool is not None:
                state, last, hit_flags = self._assemble_pool(
                    ereqs, epol, cacheable, gen, now)
            else:
                entries, hit_flags = self._lookup_or_admit(
                    ereqs, epol, cacheable, gen, now)
                state = _cat_rows(entries, eng.scfg.max_batch)
                last = np.stack([e["last_logits"] for e in _pad_list(
                    entries, eng.scfg.max_batch)])
            if any(suffix):
                stoks, svalid = eng.pad_tokens(suffix, eng.scfg.inject_len,
                                               align="left")
                # the cached pre-inject scores ride along as the
                # fallback, so per-row "last fresh event vs empty
                # suffix" selection happens inside the inject jit — no
                # logits ever sync to pick them
                state = eng.inject(state, stoks, svalid, fallback_logits=last)
                self.inject_calls += 1
                first = state["first_logits"]
            else:
                first = last
            paths = ["prefill" if not h else ("inject" if s else "cached")
                     for h, s in zip(hit_flags, suffix)]

        slate, _ = self._decode(state, first, elens)
        scores = np.asarray(first, np.float32)
        for j, i in enumerate(rows):
            row_slate[i] = slate[j, :elens[j]].copy()
            row_scores[i] = scores[j].copy()
            hit_all[i] = hit_flags[j]
            path_all[i] = paths[j]

    def _decode(self, state: Dict[str, Any], first_logits,
                slate_lens: Sequence[int]) -> Tuple[np.ndarray, int]:
        """finalize -> greedy slate, one jit call for the whole pane.

        Uniform panes (every row on the configured default) take the
        exact decode program the wave path always ran; heterogeneous
        slate_lens decode to the pane max with per-row tails masked to
        -1 inside the jit (see ServingEngine.decode_slate)."""
        eng = self.engine
        max_len = max(slate_lens)
        if all(sl == slate_lens[0] for sl in slate_lens):
            slate = eng.decode_slate(state, first_logits, max_len)
        else:
            b = eng.scfg.max_batch
            row_lens = np.full(b, max_len, np.int32)
            row_lens[:len(slate_lens)] = slate_lens
            slate = eng.decode_slate(state, first_logits, max_len,
                                     row_lens=row_lens)
        self.decode_steps += max_len - 1
        return slate, max_len

    def _lookup_or_admit(self, reqs: Sequence[Request],
                         policies: Sequence[str],
                         cacheable: Sequence[bool], gen: int, now: int,
                         ) -> Tuple[List[Dict[str, Any]], List[bool]]:
        """Per-row prefill states, admitting all misses in ONE
        fixed-shape batch prefill (one prefill per pane worst case).

        Cacheable rows probe the LRU once per ROW (hit/miss counters
        stay in request units even when a pane repeats a user) and
        misses are admitted under the ``(user, generation)`` key.
        Uncacheable rows in a mixed pane (policy "fresh") are admitted
        *ephemerally* in the same prefill batch — their history is read
        at the serve cutoff, which moves with the clock, so caching them
        would violate the cache-key invariant; they are keyed by
        (user, policy) for intra-pane dedup only (one pane = one serve
        clock).
        """
        eng = self.engine
        entries: Dict[Any, Dict[str, Any]] = {}
        hit_flags: List[bool] = []
        keys: List[Any] = []
        miss_seen = set()
        miss_keys: List[Any] = []
        miss_rows: List[int] = []
        for i, (req, pol, can) in enumerate(zip(reqs, policies, cacheable)):
            if can:
                key = req.user
                # probe once per ROW (not per unique user) so hit/miss
                # counters stay in request units even when a pane repeats
                # a user; the admission list itself is deduplicated
                e = self.cache.get(req.user, gen)
                if e is None:
                    if key not in miss_seen:
                        miss_seen.add(key)
                        miss_keys.append(key)
                        miss_rows.append(i)
                    hit_flags.append(False)
                else:
                    entries[key] = e
                    hit_flags.append(True)
            else:
                key = (req.user, pol, "ephemeral")
                if key not in miss_seen:
                    miss_seen.add(key)
                    miss_keys.append(key)
                    miss_rows.append(i)
                hit_flags.append(False)
            keys.append(key)
        if miss_rows:
            hists = self._histories([reqs[i] for i in miss_rows],
                                    [policies[i] for i in miss_rows], now)
            toks, valid = eng.pad_tokens(hists, eng.scfg.prefill_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            host = _host_state(state)  # one device→host sync per leaf
            for j, (key, i) in enumerate(zip(miss_keys, miss_rows)):
                entry = _slice_row(host, j)
                if cacheable[i]:
                    self.cache.put(reqs[i].user, gen, entry)
                entries[key] = entry
        return [entries[k] for k in keys], hit_flags

    def _assemble_pool(self, reqs: Sequence[Request],
                       policies: Sequence[str],
                       cacheable: Sequence[bool], gen: int, now: int,
                       gather: bool = True,
                       ) -> Tuple[Optional[Dict[str, Any]], Any, List[bool]]:
        """Pooled twin of ``_lookup_or_admit`` + ``_cat_rows``: per-row
        slot resolution, one fixed-shape prefill for all misses
        scattered straight into pool slots, then a one-hot gather
        assembling the pane on device — no state ever visits the host.

        Probe/admission order, dedup, and the ephemeral treatment of
        uncacheable rows mirror the host path exactly (the two backends
        must stay bitwise-equal and counter-identical). Slots touched by
        this pane — hits and fresh admissions — are *pinned* so
        slot-pressure eviction during admission can never free a slot
        the pane is about to read; scratch slots of ephemeral rows
        return to the free list once the pane is assembled. With
        ``gather=False`` (the warming path) admission happens but no
        pane is assembled."""
        eng = self.engine
        cache = self.cache  # PagedStateCache
        slot_of: Dict[Any, int] = {}
        hit_flags: List[bool] = []
        keys: List[Any] = []
        miss_seen = set()
        miss_keys: List[Any] = []
        miss_rows: List[int] = []
        for i, (req, pol, can) in enumerate(zip(reqs, policies, cacheable)):
            if can:
                key = req.user
                s = cache.lookup(req.user, gen)
                if s is None:
                    if key not in miss_seen:
                        miss_seen.add(key)
                        miss_keys.append(key)
                        miss_rows.append(i)
                    hit_flags.append(False)
                else:
                    slot_of[key] = s
                    hit_flags.append(True)
            else:
                key = (req.user, pol, "ephemeral")
                if key not in miss_seen:
                    miss_seen.add(key)
                    miss_keys.append(key)
                    miss_rows.append(i)
                hit_flags.append(False)
            keys.append(key)
        pinned = set(slot_of.values())
        scratch: List[int] = []
        if miss_rows:
            hists = self._histories([reqs[i] for i in miss_rows],
                                    [policies[i] for i in miss_rows], now)
            toks, valid = eng.pad_tokens(hists, eng.scfg.prefill_len)
            state = eng.prefill(toks, valid)
            self.prefill_calls += 1
            for key, i in zip(miss_keys, miss_rows):
                if cacheable[i]:
                    s = cache.admit(reqs[i].user, gen, pinned)
                else:
                    s = cache.alloc_scratch(pinned)
                    scratch.append(s)
                pinned.add(s)
                slot_of[key] = s
            self.pool.scatter(state, [slot_of[k] for k in miss_keys])
        if not gather:
            for s in scratch:
                cache.free_scratch(s)
            return None, None, hit_flags
        row_slots = [slot_of[k] for k in keys]
        # pad short panes by repeating row 0's slot — same padding rows
        # (and therefore bitwise the same pane) as the host path's
        # _pad_list; padding is discarded after decode
        row_slots += [row_slots[0]] * (eng.scfg.max_batch - len(row_slots))
        pane, last = self.pool.gather(row_slots)
        for s in scratch:
            cache.free_scratch(s)
        return pane, last, hit_flags

    # ------------------------------------------------------------------
    # Warming
    # ------------------------------------------------------------------

    def _admit_users(self, users, gen: int, now: int) -> Tuple[int, bool]:
        """Admit ``users``' batch-history prefill states in fixed
        ``max_batch`` panes (no serving). Returns ``(prefilled,
        evicted)`` — stops after the first pane whose admission evicts:
        a full cache budget means further warming would only evict
        states we just paid to prefill. Shared by ``warm`` (daily-job
        precompute) and ``warm_step`` (post-rollover re-warm) so the
        admission semantics cannot drift between them."""
        pol = self.injector.cfg.policy
        b = self.engine.scfg.max_batch
        warmed = 0
        # evicting a RETAINED dual-generation entry is not budget
        # pressure — those are the designated victims of the handoff
        # window; only a live-entry eviction means the budget refilled
        ev0 = self.cache.evictions - self.cache.stale_evictions
        for lo in range(0, len(users), b):
            pane = [Request(user=int(u), now=int(now))
                    for u in users[lo:lo + b]]
            before = self.cache.misses
            if self.pool is not None:
                self._assemble_pool(pane, [pol] * len(pane),
                                    [True] * len(pane), gen, int(now),
                                    gather=False)
            else:
                self._lookup_or_admit(pane, [pol] * len(pane),
                                      [True] * len(pane), gen, int(now))
            warmed += self.cache.misses - before
            if self.cache.evictions - self.cache.stale_evictions > ev0:
                return warmed, True
        return warmed, False

    def warm(self, users, now: int) -> int:
        """Cache-warming pass: admit ``users``' batch-history prefill
        states without serving — the post-snapshot precompute a daily job
        runs so live traffic starts on the inject-only path. Returns the
        number of states prefilled. No-op when caching is off or the
        policy is uncacheable. Clamped to the first ``cache_entries``
        users (pass highest-priority users first), and stops early once
        the byte budget is full — warming past either budget would
        prefill states that LRU-evict before they serve."""
        users = np.asarray(users, np.int64).ravel()[:self.cache.budget]
        if not self.cfg.use_cache \
                or self.injector.cfg.policy in ("fresh", "decay"):
            return 0
        self._advance(now)
        gen = self._sync_generation(now)
        warmed, _ = self._admit_users(users, gen, int(now))
        return warmed

    def warm_step(self, budget: Optional[int] = None) -> int:
        """Budget-bounded post-rollover re-warm: prefill up to ``budget``
        users whose cached states the last generation handoff invalidated
        (MRU-first — the hottest users are the likeliest next arrivals),
        skipping any the live traffic already re-admitted. Run between
        panes (``tick`` drives it when ``rewarm_budget`` is set) so the
        post-rollover miss storm drains on idle clock instead of on live
        requests. Returns the number of states prefilled."""
        if budget is None:
            budget = self.cfg.rewarm_budget
        if budget <= 0 or not self._rewarm_queue:
            return 0
        if not self.cfg.use_cache \
                or self.injector.cfg.policy in ("fresh", "decay") \
                or self._clock is None:
            return 0
        gen = self._gen
        users: List[int] = []
        delta_done = 0
        while self._rewarm_queue and len(users) + delta_done < budget:
            u = self._rewarm_queue.popleft()
            if (u, gen) in self.cache:
                continue
            if self._try_delta_rewarm(int(u), gen):
                delta_done += 1
            else:
                users.append(int(u))
        warmed, evicted = self._admit_users(users, gen, int(self._clock))
        if evicted:
            # a cache budget is full again — live traffic refilled it.
            # Re-warming further would only evict resident (possibly
            # just-rewarmed) states, so the storm is over: drop the
            # rest of the queue, or every subsequent tick would repeat
            # this churn
            self._rewarm_queue.clear()
        self._rollover["rebuilt"] += warmed
        self._rollover["delta_rewarms"] += delta_done
        return warmed + delta_done

    def _try_delta_rewarm(self, u: int, new_vgen: Tuple[int, int]) -> bool:
        """O(delta) re-warm (``ServerConfig.delta_rewarm``): when a
        changed user's NEW snapshot row strictly extends their old row
        (append-only history, no retention trim), the retained
        old-generation entry already holds a prefill of a prefix of the
        new history — so instead of paying a fresh ``prefill_len``-wide
        prefill, rekey the retained entry to the new generation and
        attach the (new - old) delta as **pending inject tokens**. The
        serve path prepends them to the row's realtime suffix: one
        inject of ``delta + fresh`` on the old state is token-for-token
        the computation the pre-rollover gateway would have run (the
        delta events WERE that gateway's realtime suffix), so slates
        and scores are bitwise what serving across no rollover yields.

        Qualifies only inside the certified handoff window
        (``_handoff_from``), same model version on both sides, the old
        entry still resident, both snapshot rows still materialized,
        strict-prefix rows, the new row within ``prefill_len``, and the
        combined pending within ``inject_len``. Anything else falls
        back to the full re-warm prefill. Works identically on the host
        LRU and the paged pool through the backend-neutral
        ``has_entry``/``get_pending``/``set_pending`` surface — a pool
        rekey renames a slot-table key and parks the pending tokens in
        the table's host-side sidecar; the device state never moves.
        Returns True when the entry was rekeyed in place."""
        if not self.cfg.delta_rewarm:
            return False
        hf = self._handoff_from
        if hf is None or hf[1] != new_vgen:
            return False
        old_vgen = hf[0]
        if not self.cache.has_entry(u, old_vgen):
            return False
        store = self.injector.batch
        old_rows = store.snapshot_rows(old_vgen[0], [u])
        new_rows = store.snapshot_rows(new_vgen[0], [u])
        if old_rows is None or new_rows is None:
            return False
        o_items, _, o_valid = old_rows
        n_items, _, n_valid = new_rows
        o = o_items[0][o_valid[0] > 0]
        n = n_items[0][n_valid[0] > 0]
        if len(n) < len(o) or not np.array_equal(n[:len(o)], o):
            return False  # trimmed or rewritten row: prefix broken
        if len(n) > self.engine.scfg.prefill_len:
            return False  # fresh prefill would clip differently
        d = len(n) - len(o)
        pending = list(self.cache.get_pending(u, old_vgen) or ())
        if d:
            pending += items_to_tokens(
                n[len(o):], np.ones(d, np.int64)).tolist()
        if len(pending) > self.engine.scfg.inject_len:
            return False
        if not self.cache.rekey_entry(u, old_vgen, new_vgen):
            return False
        self.cache.set_pending(u, new_vgen, pending)
        return True

    # ------------------------------------------------------------------
    def stats(self) -> GatewayStats:
        """Counters + aggregated request telemetry as a typed frozen
        :class:`~repro.serving.api.GatewayStats` (``.as_dict()`` for the
        JSON view; ``["key"]`` indexing still works for dict-era
        callers)."""
        delays = np.asarray(self._queue_delays, np.int64)
        return GatewayStats(
            requests=self.requests, panes=self.panes,
            pending=len(self._queue),
            completed=len(self._completed),
            prefill_calls=self.prefill_calls,
            inject_calls=self.inject_calls,
            decode_steps=self.decode_steps,
            deadline_flushes=self._deadline_flushes,
            shed=self.shed,
            deadline_misses=self.deadline_misses,
            paths=dict(self._path_counts),
            queue_delay={
                "window": int(len(delays)),
                "p50": float(np.percentile(delays, 50)) if len(delays) else 0.0,
                "p99": float(np.percentile(delays, 99)) if len(delays) else 0.0,
                "max": int(delays.max()) if len(delays) else 0,
            },
            rollover=RolloverStats(
                **self._rollover,
                pending_build_users=(self._builder.remaining
                                     if self._builder is not None else 0),
                pending_rewarm=len(self._rewarm_queue),
            ),
            cache=self.cache.stats(),
            ingest=self.injector.batch._log.ingest_stats(),
            model_version=self._model_version,
            patches_applied=self._patches_applied,
            patch_install_max_ms=self._patch_install_max_s * 1e3,
        )


# ----------------------------------------------------------------------
# Per-row state plumbing (batch axis of every cache leaf is axis 1;
# verified for attention K/V, SSM conv/state and the Jamba hybrid)
#
# Entries are HOST-resident numpy: slicing/assembling panes row-by-row in
# eager jax ops was the serve path's dominant cost (hundreds of tiny
# dispatches per pane), while numpy slices/concats are C-speed memcpy.
# The assembled pane crosses to the device (mesh-sharded, when the engine
# has one) exactly once, at the next jit boundary — the engine device_puts
# every operand to its serving layout. On a CPU host this is free (it is
# all host memory); on TPU it trades HBM residency for PCIe transfer per
# admission+hit, and the device-resident follow-up is a paged state pool
# (slot-indexed gather instead of host concat) — see docs/serving.md.
# ----------------------------------------------------------------------

def _host_state(state: Dict[str, Any]) -> Dict[str, Any]:
    """Pull a batched sequence-form prefill state to host, whole-pane at a
    time (one device→host sync per cache leaf, not per row)."""
    return {
        "caches": jax.tree.map(np.asarray, state["caches"]),
        "valid": np.asarray(state["valid"]),
        "next_pos": np.asarray(state["next_pos"]),
        "last_logits": np.asarray(state["logits"][:, -1]),
    }


def _slice_row(host: Dict[str, Any], row: int) -> Dict[str, Any]:
    """One row of a host-form pane state, copied so the entry doesn't pin
    the whole pane's buffers in the LRU."""
    return {
        "caches": jax.tree.map(lambda x: x[:, row:row + 1].copy(),
                               host["caches"]),
        "valid": host["valid"][row:row + 1].copy(),
        "next_pos": host["next_pos"][row:row + 1].copy(),
        "last_logits": host["last_logits"][row].copy(),
    }


def _pad_list(entries: List[Dict[str, Any]], b: int) -> List[Dict[str, Any]]:
    if not entries:
        raise ValueError("empty pane")
    return entries + [entries[0]] * (b - len(entries))


def _cat_rows(entries: List[Dict[str, Any]], b: int) -> Dict[str, Any]:
    """Assemble per-user entries into one max_batch engine state (short
    panes padded by repeating row 0; padding rows are discarded later)."""
    rows = _pad_list(entries, b)
    return {
        "caches": jax.tree.map(lambda *xs: np.concatenate(xs, axis=1),
                               *[e["caches"] for e in rows]),
        "valid": np.concatenate([e["valid"] for e in rows], axis=0),
        "next_pos": np.concatenate([e["next_pos"] for e in rows], axis=0),
        "logits": None,  # per-row slices don't keep full prefill logits
    }
