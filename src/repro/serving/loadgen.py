"""Trace-driven load generation + SLO gates: the production scenario
harness.

Every benchmark before this module replayed uniform waves or trickles,
so the serving wins (warm handoff, continuous batching, the paged pool)
were only ever measured at steady state. Real recommendation traffic is
nothing like that — arrival rates swing over the day, flash crowds blow
through any fixed pane budget, new-user floods start at a 0% hit rate,
and churn storms land exactly when a snapshot generation rolls. This
module makes those regimes *reproducible*: a seeded generator emits one
deterministic interleaved stream of request/event/clock operations per
named scenario, replays it through the :class:`~repro.serving.scheduler.
Gateway` via ``submit``/``observe``/``tick``/``poll``, and gates the
result on the scenario's declared **SLO contract**.

**The op stream.** A :class:`Trace` is a flat tuple of ops ordered by
simulated second; within one second the clock tick comes first, then
feedback events, then request arrivals:

    ("t", now)                        gateway.tick(now)
    ("e", user, item, ts)             gateway.observe((user, item, ts))
    ("a", user, now, deadline)        gateway.submit(Request(...))

Everything is drawn from one ``np.random.RandomState(seed)``, so the
same spec always produces the bitwise-identical stream — hashed into
``Trace.fingerprint`` so a replay can *prove* it ran the same traffic.
Served slates/scores hash into a second fingerprint
(:func:`slate_fingerprint`), which is what the determinism gate in the
``scenarios`` bench and tests/test_scenarios.py compare.

**Named scenarios** (``SCENARIO_NAMES``; build one with
:func:`get_scenario`):

    diurnal          sinusoidal arrival rate over one simulated "day"
                     (peak at H/4, trough at 3H/4) with the snapshot
                     period/offset chosen so one generation rollover
                     lands AT the peak and one AT the trough — the
                     worst and best moments to pay a handoff.
    flash_crowd      a 50x arrival spike with a correlated event burst;
                     the one scenario whose SLO *requires* load
                     shedding (``min_shed``) while still bounding the
                     served p99 queue delay.
    cold_start_storm a flood of never-seen users (each arrival is a
                     brand-new id that first acts, then requests):
                     the 0% cache-hit regime, gated by ``max_hit_rate``.
    churn_heavy      steady traffic while the event stream touches a
                     large fraction of the population straddling a
                     mid-trace rollover — stressing the rekey handoff
                     and the budgeted re-warm queue.
    mixed_fleet      one steady trace replayed bit-for-bit across
                     attention/SSM/MoE architectures from configs/archs
                     (reduced shapes) — the contract that the harness,
                     scheduler and SLO gates are model-family-agnostic.

**SLO contracts** (:class:`SLOContract`) gate on *simulated-time*
metrics (queue-delay percentiles, shed/deadline-miss rates, hit-rate
bounds), which are deterministic and machine-independent — the numbers
committed in BENCH_scenarios.json must pass on any host. Wall-clock
serve-latency budgets per path group (hit/fresh/miss) are supported but
deliberately generous; they catch pathologies (a path suddenly paying
compile time), not microseconds. Steady-state scenarios assert
``max_shed_rate=0`` — shedding must never fire off-overload.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DAY = 86400

SCENARIO_NAMES = ("diurnal", "flash_crowd", "cold_start_storm",
                  "churn_heavy", "mixed_fleet")

# telemetry path -> SLO path group: "hit" is a pure cache read,
# "fresh" a cached state + injected suffix (the paper's hot path),
# "miss" a full batch-history prefill. The model-free "decay" path
# reads cutoff-exact features like the fresh oracle does, so it gates
# under the "fresh" group.
PATH_GROUPS = {"cached": "hit", "inject": "fresh", "prefill": "miss",
               "decay": "fresh"}


# ----------------------------------------------------------------------
# SLO contracts
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOContract:
    """Per-scenario service-level objectives. ``None`` disables a gate.

    Sim-time gates (deterministic, machine-independent):
      * ``queue_delay_p50``/``queue_delay_p99`` — percentile budgets in
        simulated seconds over *served* requests (shed rows never enter
        the latency population — they are gated by rate instead).
      * ``max_deadline_miss_rate`` — served-past-deadline fraction.
      * ``max_shed_rate`` / ``min_shed`` — shed fraction of submitted
        requests, and (for overload scenarios) proof shedding engaged.
      * ``min_hit_rate`` / ``max_hit_rate`` — cache-hit-rate bounds;
        ``max_hit_rate=0`` is how cold_start_storm certifies it really
        ran the 0%-hit regime.

    Wall-clock gates (machine-dependent, deliberately generous):
      * ``wall_ms_p99`` — per path group ("hit"/"fresh"/"miss"), p99 of
        submit→response wall milliseconds. A group with no served rows
        passes vacuously.
      * ``max_boundary_slice_ms`` — worst wall time any single clock
        call spent advancing the snapshot job during the replay
        (``RolloverStats.build_slice_max_s``). This is the boundary-
        stall gate: with the background builder it certifies the
        rollover never stalled a tick, at any traffic level.
    """
    queue_delay_p50: Optional[float] = None
    queue_delay_p99: Optional[float] = None
    max_deadline_miss_rate: Optional[float] = 0.0
    max_shed_rate: Optional[float] = 0.0
    min_shed: int = 0
    min_hit_rate: Optional[float] = None
    max_hit_rate: Optional[float] = None
    wall_ms_p99: Optional[Dict[str, float]] = None
    max_boundary_slice_ms: Optional[float] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def evaluate_slo(slo: SLOContract, metrics: Dict) -> Tuple[bool, List[Dict]]:
    """Check ``metrics`` (see :func:`collect_metrics`) against a
    contract. Returns ``(passed, gates)`` where each gate is
    ``{"gate", "budget", "actual", "pass"}`` — the full scorecard goes
    into the bench JSON so a failure says *which* objective broke."""
    gates: List[Dict] = []

    def gate(name, budget, actual, ok):
        gates.append({"gate": name, "budget": budget,
                      "actual": actual, "pass": bool(ok)})

    if slo.queue_delay_p50 is not None:
        a = metrics["queue_delay"]["p50"]
        gate("queue_delay_p50_s", slo.queue_delay_p50, a,
             a <= slo.queue_delay_p50)
    if slo.queue_delay_p99 is not None:
        a = metrics["queue_delay"]["p99"]
        gate("queue_delay_p99_s", slo.queue_delay_p99, a,
             a <= slo.queue_delay_p99)
    if slo.max_deadline_miss_rate is not None:
        a = metrics["deadline_miss_rate"]
        gate("deadline_miss_rate", slo.max_deadline_miss_rate, a,
             a <= slo.max_deadline_miss_rate)
    if slo.max_shed_rate is not None:
        a = metrics["shed_rate"]
        gate("shed_rate", slo.max_shed_rate, a, a <= slo.max_shed_rate)
    if slo.min_shed:
        a = metrics["shed"]
        gate("min_shed", slo.min_shed, a, a >= slo.min_shed)
    if slo.min_hit_rate is not None:
        a = metrics["hit_rate"]
        gate("min_hit_rate", slo.min_hit_rate, a, a >= slo.min_hit_rate)
    if slo.max_hit_rate is not None:
        a = metrics["hit_rate"]
        gate("max_hit_rate", slo.max_hit_rate, a, a <= slo.max_hit_rate)
    if slo.wall_ms_p99:
        for group, budget in sorted(slo.wall_ms_p99.items()):
            a = metrics["wall_ms_p99"].get(group)
            gate(f"wall_ms_p99[{group}]", budget, a,
                 a is None or a <= budget)  # no rows -> vacuous pass
    if slo.max_boundary_slice_ms is not None:
        a = metrics["boundary_slice_max_ms"]
        gate("boundary_slice_max_ms", slo.max_boundary_slice_ms, a,
             a <= slo.max_boundary_slice_ms)
    return all(g["pass"] for g in gates), gates


# ----------------------------------------------------------------------
# Scenario specs + trace generation
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Everything that determines a scenario run: the seeded traffic
    shape, the feature-store rollover placement, and the gateway
    configuration it plays against. Frozen so a spec can be hashed into
    the trace fingerprint's provenance."""
    name: str
    kind: str                 # "steady" | "diurnal" | "spike" | "cold"
    horizon: int              # trace length in simulated seconds
    n_users: int
    slo: SLOContract
    n_items: int = 300
    seed: int = 7
    start: int = 5 * DAY + 100   # sim-time origin (after seeded history)
    base_rate: float = 0.5       # mean arrivals per simulated second
    peak_mult: float = 1.0       # diurnal peak / spike multiplier
    spike_start: int = 0         # spike window offset from `start`
    spike_len: int = 0
    event_rate: float = 0.25     # mean feedback events per sim second
    event_burst_mult: float = 1.0  # event-rate multiplier inside the spike
    deadline_offset: int = 60    # per-request deadline = now + offset
    hot_frac: float = 0.1        # user locality: hottest fraction...
    hot_mass: float = 0.8        # ...receives this request mass
    seen_users: Optional[int] = None  # cold: ids below are the warm world
    churn_frac: float = 0.0      # events target the first frac of users
    prelude_events: int = 1200   # seeded history rows before the trace
    prelude_ts: Tuple[int, int] = (0, 5 * DAY)  # [lo, hi) prelude stamps
    snapshot_period: int = DAY
    snapshot_offset: int = 0
    feature_len: int = 24
    # gateway/engine knobs
    max_batch: int = 8
    prefill_len: int = 32
    inject_len: int = 8
    max_wait: Optional[int] = 2
    pane_service_time: Optional[int] = 1
    shed_policy: Optional[str] = "deadline"
    rewarm_budget: int = 0
    snapshot_build_budget: Optional[int] = None
    background_build: bool = False  # off-thread snapshot builds
    cache_entries: Optional[int] = None  # None -> n_users
    archs: Tuple[str, ...] = ()  # mixed_fleet: replay across these
    # tiered EventLog knobs (None = unbounded append-only log)
    log_window: Optional[int] = None       # hot-tail window (sim-s)
    log_retention_windows: int = 8         # warm windows before eviction
    log_compaction: Optional[str] = None   # None | "sync" | "background"
    # fraction of arrivals served on the model-free "decay" policy arm
    # (mixed-policy panes); 0 keeps existing traces byte-identical
    decay_frac: float = 0.0


@dataclasses.dataclass(frozen=True)
class Trace:
    """One deterministic op stream (see module docstring for the op
    grammar). ``fingerprint`` hashes the full stream — two runs that
    disagree on it did not replay the same traffic."""
    name: str
    seed: int
    start: int
    horizon: int
    ops: Tuple[Tuple, ...]
    arrivals: int
    events: int

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256()
        for op in self.ops:
            h.update(repr(op).encode())
        return h.hexdigest()[:16]


def _rate_at(spec: ScenarioSpec, t: int) -> float:
    """Arrival rate (mean arrivals/sim-s) at trace-relative second t."""
    if spec.kind == "diurnal":
        # peak at H/4, trough at 3H/4 — the rollovers land on both
        amp = spec.base_rate * (spec.peak_mult - 1.0)
        return spec.base_rate + amp * (
            1.0 + math.sin(2.0 * math.pi * t / spec.horizon)) / 2.0
    if spec.kind == "spike" and \
            spec.spike_start <= t < spec.spike_start + spec.spike_len:
        return spec.base_rate * spec.peak_mult
    return spec.base_rate


def _event_rate_at(spec: ScenarioSpec, t: int) -> float:
    r = spec.event_rate
    if spec.kind == "spike" and \
            spec.spike_start <= t < spec.spike_start + spec.spike_len:
        r *= spec.event_burst_mult
    return r


def _sample_users(rng: np.random.RandomState, spec: ScenarioSpec,
                  size: int, pool: int) -> np.ndarray:
    """Hot-user locality over the first ``pool`` ids: ``hot_mass`` of
    the draws land on the hottest ``hot_frac`` of users."""
    hot = max(int(pool * spec.hot_frac), 1)
    pick_hot = rng.rand(size) < spec.hot_mass
    return np.where(pick_hot, rng.randint(0, hot, size),
                    rng.randint(0, pool, size))


def make_trace(spec: ScenarioSpec) -> Trace:
    """Generate the scenario's deterministic op stream. Within each
    simulated second: one tick, then the second's feedback events, then
    its request arrivals — so a tick always sees the previous second's
    queue (max_wait/deadline drains) before new work lands."""
    rng = np.random.RandomState(spec.seed)
    pool = spec.seen_users if spec.seen_users is not None else spec.n_users
    next_cold = pool  # cold kind: sequential never-seen ids
    ops: List[Tuple] = []
    n_arrivals = n_events = 0
    for t in range(spec.horizon):
        now = spec.start + t
        ops.append(("t", now))
        for _ in range(int(rng.poisson(_event_rate_at(spec, t)))):
            if spec.churn_frac > 0:
                # churn regime: events sweep a broad slice of the
                # population so their snapshot rows change across the
                # mid-trace rollover
                u = int(rng.randint(0, max(int(pool * spec.churn_frac), 1)))
            else:
                u = int(_sample_users(rng, spec, 1, pool)[0])
            ops.append(("e", u, int(rng.randint(0, spec.n_items)), now))
            n_events += 1
        for _ in range(int(rng.poisson(_rate_at(spec, t)))):
            if spec.kind == "cold":
                if next_cold >= spec.n_users:
                    break  # id space exhausted — bound, never wrap
                u, next_cold = next_cold, next_cold + 1
                # a cold user acts before they request (signup flow):
                # their first events exist only in the realtime stream,
                # so the request prefills an empty batch history and
                # injects the fresh suffix
                ops.append(("e", u, int(rng.randint(0, spec.n_items)), now))
                n_events += 1
            else:
                u = int(_sample_users(rng, spec, 1, pool)[0])
            # decay_frac > 0 widens arrival ops to 5-tuples carrying an
            # explicit policy; the short-circuit keeps the rng stream —
            # and so every existing trace fingerprint — untouched when 0
            if spec.decay_frac > 0 and rng.rand() < spec.decay_frac:
                ops.append(("a", u, now, now + spec.deadline_offset,
                            "decay"))
            else:
                ops.append(("a", u, now, now + spec.deadline_offset))
            n_arrivals += 1
    return Trace(name=spec.name, seed=spec.seed, start=spec.start,
                 horizon=spec.horizon, ops=tuple(ops),
                 arrivals=n_arrivals, events=n_events)


# ----------------------------------------------------------------------
# Platform construction
# ----------------------------------------------------------------------

_ENGINE_CACHE: Dict[Tuple, object] = {}


def _engine_for(spec: ScenarioSpec, arch: Optional[str]):
    """Build (and memoize — jit caches are per engine) the serving
    engine a scenario runs against: the tiny dense ranker by default, or
    a reduced same-family variant of a registered arch for mixed_fleet."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig, get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    key = (arch, spec.n_items, spec.max_batch, spec.prefill_len,
           spec.inject_len)
    if key in _ENGINE_CACHE:
        return _ENGINE_CACHE[key]
    if arch is None:
        cfg = ModelConfig(
            name="loadgen-ranker", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=spec.n_items + 256, rope_theta=1e4,
            tie_embeddings=True)
    else:
        cfg = reduced(get_config(arch), n_layers=2, d_model=64,
                      vocab=spec.n_items + 256)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=spec.max_batch, prefill_len=spec.prefill_len,
        inject_len=spec.inject_len,
        cache_capacity=spec.prefill_len + spec.inject_len + 64))
    _ENGINE_CACHE[key] = eng
    return eng


def build_gateway(spec: ScenarioSpec, arch: Optional[str] = None,
                  engine=None):
    """The scenario's serving stack: seeded prelude history in both
    feature stores, an inject-policy injector, and a Gateway configured
    from the spec (continuous batching + the deadline shed policy by
    default). The prelude stream is seeded separately from the trace so
    trace generation and platform construction cannot entangle."""
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.serving.scheduler import Gateway, ServerConfig

    eng = engine if engine is not None else _engine_for(spec, arch)
    rng = np.random.RandomState(spec.seed + 1)
    pool = spec.seen_users if spec.seen_users is not None else spec.n_users
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=spec.n_users, feature_len=spec.feature_len,
        snapshot_period=spec.snapshot_period,
        snapshot_offset=spec.snapshot_offset,
        log_window=spec.log_window,
        log_retention_windows=spec.log_retention_windows))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=spec.n_users, buffer_len=8, ingest_latency=0))
    if spec.prelude_events:
        us = _sample_users(rng, spec, spec.prelude_events, pool)
        its = rng.randint(0, spec.n_items, spec.prelude_events)
        lo, hi = spec.prelude_ts
        tss = rng.randint(lo, hi, spec.prelude_events)
        store.extend(us, its, tss)
        rts.extend(us, its, tss)
    inj = FeatureInjector(InjectionConfig(
        policy="inject", feature_len=spec.feature_len), store, rts)
    cache_entries = spec.cache_entries or spec.n_users
    gw = Gateway(eng, inj, ServerConfig(
        slate_len=4, cache_entries=cache_entries,
        max_wait=spec.max_wait,
        pane_service_time=spec.pane_service_time,
        shed_policy=spec.shed_policy,
        rewarm_budget=spec.rewarm_budget,
        snapshot_build_budget=spec.snapshot_build_budget,
        background_build=spec.background_build,
        log_compaction=spec.log_compaction))
    return gw


def _compile_warmup(spec: ScenarioSpec, arch: Optional[str]) -> None:
    """Compile every jit on the request path (prefill/inject/decode at
    the scenario's pane shapes) through a throwaway gateway on the SAME
    engine, so the measured run's wall latencies never pay compile
    time. The scratch stack shares nothing else with the real run."""
    from repro.serving.api import Request

    gw = build_gateway(spec, arch)
    now = spec.start
    users = np.arange(min(spec.max_batch, spec.n_users))
    gw.warm(users, now)
    for u in users:
        gw.observe((int(u), 0, now))
    gw.submit_many([Request(user=int(u), now=now + 1) for u in users])
    gw.flush(now + 1)
    # the miss path (cold prefill inside a serve pane, incl. empty
    # histories) compiles against the same pane shapes as warm()


# ----------------------------------------------------------------------
# Scenario replay + metrics
# ----------------------------------------------------------------------

def slate_fingerprint(tickets: Sequence) -> str:
    """Hash every response in submission order: served slates/scores
    byte-for-byte, shed markers by id — the determinism witness."""
    h = hashlib.sha256()
    for t in tickets:
        if t.response.shed:
            h.update(f"shed:{t.request_id}".encode())
        else:
            h.update(np.ascontiguousarray(t.response.slate).tobytes())
            h.update(np.ascontiguousarray(t.response.scores).tobytes())
    return h.hexdigest()[:16]


def collect_metrics(tickets: Sequence, stats) -> Dict:
    """Aggregate per-ticket telemetry into the dict
    :func:`evaluate_slo` gates on."""
    served = [t for t in tickets if not t.response.shed]
    shed = len(tickets) - len(served)
    qd = np.asarray([t.response.telemetry.queue_delay for t in served],
                    np.int64)
    wall: Dict[str, List[float]] = {"hit": [], "fresh": [], "miss": []}
    for t in served:
        group = PATH_GROUPS[t.response.telemetry.path]
        wall[group].append((t.completed_wall - t.submitted_wall) * 1e3)
    hits = sum(t.response.telemetry.cache_hit for t in served)
    return {
        "requests": len(tickets), "served": len(served), "shed": shed,
        "shed_rate": shed / max(len(tickets), 1),
        "deadline_misses": int(stats.deadline_misses),
        "deadline_miss_rate": stats.deadline_misses / max(len(served), 1),
        "hit_rate": hits / max(len(served), 1),
        "queue_delay": {
            "p50": float(np.percentile(qd, 50)) if len(qd) else 0.0,
            "p99": float(np.percentile(qd, 99)) if len(qd) else 0.0,
            "max": int(qd.max()) if len(qd) else 0,
        },
        "wall_ms_p99": {
            g: (float(np.percentile(v, 99)) if v else None)
            for g, v in wall.items()},
        "boundary_slice_max_ms": float(
            stats.rollover["build_slice_max_s"] * 1e3),
        "paths": dict(stats.paths),
    }


@dataclasses.dataclass
class ScenarioResult:
    """One scenario x one architecture: fingerprints, SLO scorecard,
    and the gateway's own counters."""
    name: str
    arch: Optional[str]
    trace_fingerprint: str
    slate_fingerprint: str
    metrics: Dict
    gates: List[Dict]
    slo_pass: bool
    gateway_stats: Dict

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def replay(gw, trace: Trace, spec: ScenarioSpec) -> List:
    """Drive one op stream through a gateway; returns every ticket in
    submission order, all resolved (the tail is deadline-drained)."""
    from repro.serving.api import Request

    # the boundary-stall gate judges the TRACE, not the warmup: the
    # cold store's catch-up build during warm() is deploy-time work a
    # live boundary never pays, so the slice telemetry restarts here
    gw._rollover["build_slice_max_s"] = 0.0
    tickets: List = []
    for op in trace.ops:
        if op[0] == "t":
            gw.tick(op[1])
        elif op[0] == "e":
            gw.observe((op[1], op[2], op[3]))
        else:
            tickets.append(gw.submit(Request(
                user=op[1], now=op[2], deadline=op[3],
                policy=op[4] if len(op) > 4 else None)))
    # drain at end-of-trace (not later): flush serves the queued tail
    # regardless of deadlines, whereas jumping the clock further would
    # manufacture sheds the traffic never caused
    gw.drain(trace.start + trace.horizon)
    return tickets


def run_scenario(spec: ScenarioSpec, warmup: bool = True,
                 ) -> List[ScenarioResult]:
    """Run one scenario end to end: generate the trace, build the
    platform (per arch for mixed_fleet), warm the cache over the seen
    population, replay, and gate on the SLO contract. Returns one
    :class:`ScenarioResult` per architecture (a single ``None`` entry
    for single-arch scenarios)."""
    trace = make_trace(spec)
    archs: Tuple[Optional[str], ...] = spec.archs or (None,)
    results: List[ScenarioResult] = []
    for arch in archs:
        if warmup:
            _compile_warmup(spec, arch)
        gw = build_gateway(spec, arch)
        pool = spec.seen_users if spec.seen_users is not None \
            else spec.n_users
        gw.warm(np.arange(pool), spec.start)
        tickets = replay(gw, trace, spec)
        assert all(t.done for t in tickets), \
            "trace replay left unresolved tickets"
        st = gw.stats()
        metrics = collect_metrics(tickets, st)
        passed, gates = evaluate_slo(spec.slo, metrics)
        results.append(ScenarioResult(
            name=spec.name, arch=arch,
            trace_fingerprint=trace.fingerprint,
            slate_fingerprint=slate_fingerprint(tickets),
            metrics=metrics, gates=gates, slo_pass=passed,
            gateway_stats=st.as_dict()))
    return results


# ----------------------------------------------------------------------
# The named scenarios
# ----------------------------------------------------------------------

def get_scenario(name: str, smoke: bool = False) -> ScenarioSpec:
    """Build a named scenario spec (``SCENARIO_NAMES``). ``smoke``
    shrinks the horizon/population for CI while keeping every regime
    qualitatively intact (the diurnal rollovers still land at peak and
    trough, the flash crowd still overloads, cold users still never
    repeat)."""
    if name == "diurnal":
        h = 400 if smoke else 1600
        start = 5 * DAY + 100
        period = h // 2
        # boundaries at start + h/4 (peak) and start + 3h/4 (trough)
        return ScenarioSpec(
            name=name, kind="diurnal", horizon=h, n_users=192,
            seed=11, start=start, base_rate=0.4, peak_mult=4.0,
            event_rate=0.3,
            snapshot_period=period,
            snapshot_offset=(start + h // 4) % period,
            prelude_ts=(start - h, start - h // 4),
            slo=SLOContract(queue_delay_p50=4, queue_delay_p99=10,
                            max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                            min_hit_rate=0.5,
                            wall_ms_p99=_WALL_BUDGETS["diurnal"]))
    if name == "flash_crowd":
        h = 300 if smoke else 900
        return ScenarioSpec(
            name=name, kind="spike", horizon=h, n_users=192,
            seed=13, base_rate=0.4, peak_mult=50.0,
            spike_start=h // 3, spike_len=max(h // 10, 20),
            event_rate=0.3, event_burst_mult=10.0,
            deadline_offset=30,
            slo=SLOContract(queue_delay_p99=40,
                            max_deadline_miss_rate=0.05,
                            max_shed_rate=0.9, min_shed=1,
                            wall_ms_p99=_WALL_BUDGETS["flash_crowd"]))
    if name == "cold_start_storm":
        h = 300 if smoke else 900
        # every arrival is a brand-new id: reserve enough id space for
        # the whole storm (rate * horizon, with Poisson headroom)
        rate = 1.0
        reserve = int(rate * h * 2) + 64
        return ScenarioSpec(
            name=name, kind="cold", horizon=h, n_users=64 + reserve,
            seen_users=64, seed=17, base_rate=rate, event_rate=0.2,
            slo=SLOContract(queue_delay_p50=4, queue_delay_p99=10,
                            max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                            max_hit_rate=0.0,
                            wall_ms_p99=_WALL_BUDGETS["cold_start_storm"]))
    if name == "churn_heavy":
        h = 400 if smoke else 1200
        start = 5 * DAY + 100
        period = h  # exactly one boundary mid-trace, at start + h/2
        return ScenarioSpec(
            name=name, kind="steady", horizon=h, n_users=192,
            seed=19, start=start, base_rate=0.5,
            event_rate=1.5, churn_frac=0.8, rewarm_budget=4,
            snapshot_period=period,
            snapshot_offset=(start + h // 2) % period,
            prelude_ts=(start - h, start - h // 2),
            slo=SLOContract(queue_delay_p50=4, queue_delay_p99=10,
                            max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                            wall_ms_p99=_WALL_BUDGETS["churn_heavy"]))
    if name == "churn_compact":
        # churn_heavy's regime with the tiered EventLog live: a small
        # hot window compacted synchronously on gateway ticks (>= 3
        # rollovers per trace), plus a slice of arrivals pinned to the
        # model-free decay arm so panes mix engine and decay rows.
        # Not in SCENARIO_NAMES: it rides the ``ingest`` bench suite,
        # not the scenario suite, so committed scenario baselines keep
        # their fingerprints.
        h = 400 if smoke else 1200
        start = 5 * DAY + 100
        period = h
        return ScenarioSpec(
            name=name, kind="steady", horizon=h, n_users=192,
            seed=19, start=start, base_rate=0.5,
            event_rate=1.5, churn_frac=0.8, rewarm_budget=4,
            snapshot_period=period,
            snapshot_offset=(start + h // 2) % period,
            prelude_ts=(start - h, start - h // 2),
            log_window=h // 4, log_retention_windows=40,
            log_compaction="sync", decay_frac=0.25,
            slo=SLOContract(queue_delay_p50=4, queue_delay_p99=10,
                            max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                            wall_ms_p99=_WALL_BUDGETS["churn_compact"]))
    if name == "mixed_fleet":
        h = 200 if smoke else 600
        return ScenarioSpec(
            name=name, kind="steady", horizon=h, n_users=128,
            seed=23, base_rate=0.5, event_rate=0.3,
            archs=("llama3.2-1b", "mamba2-780m", "granite-moe-3b-a800m"),
            slo=SLOContract(queue_delay_p50=4, queue_delay_p99=10,
                            max_deadline_miss_rate=0.0, max_shed_rate=0.0,
                            wall_ms_p99=_WALL_BUDGETS["mixed_fleet"]))
    raise KeyError(f"unknown scenario {name!r}; known: {SCENARIO_NAMES}")


# Per-scenario serve-latency budgets (wall ms, p99 per path), calibrated
# from the committed BENCH_scenarios.json baselines: roughly 20-25x the
# measured p99 on the reference host, floored at ~250 ms. Wide enough
# that an arbitrarily slow CI host passes; tight enough that a path
# suddenly paying a re-compile or a full prefill where it used to hit
# the cache (baselines are ~10 ms) trips the gate instead of hiding in
# a 2-second catch-all. ``mixed_fleet`` takes the max over its three
# real-arch gateways (the MoE's hit path measures ~172 ms).
# Paths a scenario never exercises (flash_crowd sheds its misses;
# cold_start_storm never hits) keep a generous default — an unexercised
# budget gates nothing, but stays present in case a regression reroutes
# traffic onto that path.
_WALL_BUDGETS = {
    "diurnal": {"hit": 300.0, "fresh": 350.0, "miss": 350.0},
    "flash_crowd": {"hit": 250.0, "fresh": 250.0, "miss": 500.0},
    "cold_start_storm": {"hit": 250.0, "fresh": 250.0, "miss": 600.0},
    "churn_heavy": {"hit": 300.0, "fresh": 250.0, "miss": 400.0},
    "churn_compact": {"hit": 300.0, "fresh": 250.0, "miss": 400.0},
    "mixed_fleet": {"hit": 4500.0, "fresh": 450.0, "miss": 4500.0},
}
