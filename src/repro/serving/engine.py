"""TPU serving engine — the ITFI inference flow as cache operations.

The paper's injection maps onto TPU serving as **incremental prefill**
(DESIGN.md §2): the batch features correspond to a cached model state
(KV cache for attention layers, recurrent state for SSM layers) that the
daily job can materialize; injecting fresh events only runs the *suffix*
through the model — O(Δ) cost instead of O(full history):

    snapshot = engine.prefill(batch_history)        # daily job, cacheable
    state    = engine.inject(snapshot, fresh_events)  # per-request, cheap
    logits   = engine.decode(state, token, pos)       # unchanged serving

``prefill``/``inject`` return *sequence-form* caches (K/V grown along the
sequence dim; SSM conv tails + state); ``finalize`` converts to the
fixed-capacity ring cache that ``decode`` uses. All entry points are jit'd
once per shape; the engine pads requests to fixed shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import (cache_from_prefill, decode_step, extend,
                                init_cache, prefill)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    max_batch: int = 8
    prefill_len: int = 1024        # padded batch-history length
    inject_len: int = 32           # padded fresh-suffix length
    cache_capacity: int = 2048     # ring-cache slots for decode
    temperature: float = 0.0       # 0 = greedy
    q_chunk: int = 512


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServingConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(functools.partial(
            _prefill_impl, cfg=cfg, q_chunk=scfg.q_chunk))
        self._inject = jax.jit(functools.partial(
            _inject_impl, cfg=cfg, q_chunk=scfg.q_chunk))
        self._finalize = jax.jit(functools.partial(
            _finalize_impl, cfg=cfg, capacity=scfg.cache_capacity))
        self._decode = jax.jit(functools.partial(_decode_impl, cfg=cfg))

    # ------------------------------------------------------------------
    def pad_tokens(self, seqs, length: int, align: str = "right",
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Pad a list of variable-length token lists into (tokens, valid)
        of shape (max_batch, length).

        Prefill buffers are right-aligned (real tokens end at the last
        buffer position, so one uniform ``next_pos`` covers the batch);
        inject suffixes are LEFT-aligned (real tokens contiguous from the
        row's ``next_pos`` — RoPE distances stay exact per row).

        Raises ``ValueError`` when more than ``max_batch`` sequences are
        passed — silently dropping requests is a serving bug; callers with
        larger waves must pane-split (see serving/loop.py).
        """
        b = self.scfg.max_batch
        if len(seqs) > b:
            raise ValueError(
                f"{len(seqs)} sequences exceed max_batch={b}; split the "
                f"request wave into panes of at most {b} rows")
        toks = np.zeros((b, length), np.int32)
        valid = np.zeros((b, length), bool)
        for i, s in enumerate(seqs):
            s = list(s)[-length:]
            if not s:
                continue
            if align == "right":
                toks[i, length - len(s):] = s
                valid[i, length - len(s):] = True
            else:
                toks[i, :len(s)] = s
                valid[i, :len(s)] = True
        return toks, valid

    # ------------------------------------------------------------------
    def prefill(self, tokens, valid) -> Dict[str, Any]:
        """Materialize the batch-history state (the daily-job analogue).

        Positions index the padded buffer (real tokens right-aligned), so
        subsequent inject/decode positions continue at ``buf_len`` —
        relative distances between real tokens are exact under RoPE.
        """
        tokens = jnp.asarray(tokens)
        valid = jnp.asarray(valid)
        logits, caches = self._prefill(self.params, tokens, valid)
        b, s = tokens.shape
        return {"caches": caches, "valid": valid,
                # right-aligned prefill: every row's next position is S
                "next_pos": jnp.full((b,), s, jnp.int32),
                "logits": logits}

    def inject(self, state: Dict[str, Any], suffix_tokens, suffix_valid,
               ) -> Dict[str, Any]:
        """Incremental prefill of fresh events against a cached state —
        the paper's injection: O(suffix) compute, model untouched.
        Suffix must be LEFT-aligned (see pad_tokens)."""
        sv = jnp.asarray(suffix_valid)
        logits, caches = self._inject(
            self.params, state["caches"], jnp.asarray(suffix_tokens),
            sv, state["valid"], state["next_pos"])
        return {"caches": caches,
                "valid": jnp.concatenate([state["valid"], sv], axis=1),
                "next_pos": state["next_pos"] + sv.sum(-1).astype(jnp.int32),
                "logits": logits}

    def finalize(self, state: Dict[str, Any]) -> Dict[str, Any]:
        """Sequence-form state -> fixed-capacity ring cache for decode."""
        caches = self._finalize(state["caches"], state["valid"])
        return {"caches": caches, "pos": state["next_pos"]}

    def decode(self, dec: Dict[str, Any], tokens) -> Tuple[jnp.ndarray, Dict]:
        """One serve step: tokens (B,1) -> (logits (B,Vp), updated dec)."""
        logits, caches = self._decode(self.params, dec["caches"],
                                      jnp.asarray(tokens), dec["pos"])
        return logits[:, 0], {"caches": caches, "pos": dec["pos"] + 1}

    def sample(self, logits, rng=None) -> jnp.ndarray:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)


# ----------------------------------------------------------------------
# jit bodies (pure functions of pytrees + static cfg)
# ----------------------------------------------------------------------

def _prefill_impl(params, tokens, valid, *, cfg, q_chunk):
    return prefill(params, cfg, tokens, valid=valid, q_chunk=q_chunk)


def _inject_impl(params, caches, tokens, valid, prefix_valid, start, *,
                 cfg, q_chunk):
    return extend(params, cfg, caches, tokens, start,
                  valid=valid, prefix_valid=prefix_valid, q_chunk=q_chunk)


def _finalize_impl(caches, valid, *, cfg, capacity):
    return cache_from_prefill(cfg, caches, capacity, valid=valid)


def _decode_impl(params, caches, tokens, pos, *, cfg):
    return decode_step(params, cfg, caches, tokens, pos)


# ----------------------------------------------------------------------
# serve_step for the dry-run: ONE token against a seq_len cache
# ----------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig):
    """The function the decode-shape dry-runs lower: greedy one-token step.

    signature: (params, caches, tokens (B,1), pos (B,)) ->
               (next_token (B,), caches')
    """
    def serve_step(params, caches, tokens, pos):
        logits, caches = decode_step(params, cfg, caches, tokens, pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return nxt, caches
    return serve_step
