"""TPU serving engine — the ITFI inference flow as cache operations.

The paper's injection maps onto TPU serving as **incremental prefill**
(DESIGN.md §2): the batch features correspond to a cached model state
(KV cache for attention layers, recurrent state for SSM layers) that the
daily job can materialize; injecting fresh events only runs the *suffix*
through the model — O(Δ) cost instead of O(full history):

    snapshot = engine.prefill(batch_history)        # daily job, cacheable
    state    = engine.inject(snapshot, fresh_events)  # per-request, cheap
    logits   = engine.decode(state, token, pos)       # unchanged serving

``prefill``/``inject`` return *sequence-form* caches (K/V grown along the
sequence dim; SSM conv tails + state); ``finalize`` converts to the
fixed-capacity ring cache that ``decode`` uses. All entry points are jit'd
once per shape; the engine pads requests to fixed shapes.

**Sharded serving** (the multi-device path): pass a ``Mesh`` and the
engine resolves the full `sharding/rules.py` serving bundle once —
parameters land replicated over the data axes and TP-sharded over the
model axis (decode-mode layout, FSDP stripped — see
``rules.serving_pspecs``), request panes shard over the data axes
(``max_batch`` must divide the data-axis size; checked at construction,
never discovered as an uneven-sharding error inside jit), and every
entry point is jit'd with explicit ``in_shardings`` /
``out_shardings`` so prefill/inject/decode caches stay resident in their
sharded layout between calls. The ring KV/SSM cache is **donated** into
``decode`` — its input and output are shape- and sharding-identical, so
each serve step updates the cache in place instead of doubling its
footprint (inject/finalize change buffer shapes, seq-grow and seq→ring,
so their inputs cannot alias and are not donated — XLA frees them at the
end of the call anyway). On CPU test meshes donation is a no-op; on TPU
it is the difference between one decode-cache working set and two.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import (cache_from_prefill, decode_step, extend,
                                init_cache, prefill)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    max_batch: int = 8
    prefill_len: int = 1024        # padded batch-history length
    inject_len: int = 32           # padded fresh-suffix length
    cache_capacity: int = 2048     # ring-cache slots for decode
    temperature: float = 0.0       # 0 = greedy
    q_chunk: int = 512


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServingConfig,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.mesh = mesh
        self._slate_fns: Dict[int, Any] = {}
        pf = functools.partial(_prefill_impl, cfg=cfg, q_chunk=scfg.q_chunk)
        inj = functools.partial(_inject_impl, cfg=cfg, q_chunk=scfg.q_chunk)
        fin = functools.partial(_finalize_impl, cfg=cfg,
                                capacity=scfg.cache_capacity)
        dec = functools.partial(_decode_impl, cfg=cfg)
        if mesh is None:
            self.data_shards = 1
            self.params = params
            self._tok_ns = self._row_ns = self._seq_ns = self._ring_ns = None
            self._prefill = jax.jit(pf)
            self._inject = self._inject_fb = jax.jit(inj)
            self._finalize = jax.jit(fin)
            self._decode = jax.jit(dec)
            return

        from repro.sharding.rules import serving_pspecs
        sp = serving_pspecs(cfg, mesh, scfg.max_batch)
        self.data_shards = sp.data_shards
        ns = lambda spec: jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda x: isinstance(x, P))
        p_ns, tok_ns, row_ns = ns(sp.params), ns(sp.tokens), ns(sp.rows)
        seq_ns, ring_ns, lg_ns = (ns(sp.seq_caches), ns(sp.ring_caches),
                                  ns(sp.logits))
        # Entry points re-place operands with device_put (below): jit
        # in_shardings only *check* committed arrays, they don't reshard
        # them — and the serving scheduler legitimately hands us host-assembled
        # states (per-user LRU rows concatenated into a pane).
        self._tok_ns, self._row_ns = tok_ns, row_ns
        self._seq_ns, self._ring_ns = seq_ns, ring_ns
        self._param_ns = p_ns
        # Parameters move to their sharded layout ONCE, here — every jit
        # below then sees them already placed (no per-call transfer).
        self.params = jax.device_put(params, p_ns)
        # in_shardings double as device_put: numpy panes from pad_tokens
        # and host-assembled cache states get scattered to the mesh at the
        # call boundary; out_shardings pin the returned caches to the same
        # layout the next entry point consumes, so nothing round-trips.
        self._prefill = jax.jit(
            pf, in_shardings=(p_ns, tok_ns, tok_ns),
            out_shardings=(lg_ns, seq_ns))
        inj_out = {"caches": seq_ns, "logits": lg_ns, "valid": tok_ns,
                   "next_pos": row_ns, "n_valid": row_ns,
                   "last_valid_logits": tok_ns}
        self._inject = jax.jit(
            inj,
            in_shardings=(p_ns, seq_ns, tok_ns, tok_ns, tok_ns, row_ns),
            out_shardings=inj_out)
        self._inject_fb = jax.jit(
            inj,
            in_shardings=(p_ns, seq_ns, tok_ns, tok_ns, tok_ns, row_ns,
                          tok_ns),
            out_shardings={**inj_out, "first_logits": tok_ns})
        self._finalize = jax.jit(
            fin, in_shardings=(seq_ns, tok_ns), out_shardings=ring_ns)
        self._decode = jax.jit(
            dec, in_shardings=(p_ns, ring_ns, tok_ns, row_ns),
            out_shardings=(lg_ns, ring_ns), donate_argnums=(1,))

    # ------------------------------------------------------------------
    def prefill_state_shapes(self) -> Tuple[Any, Any]:
        """Abstract ``(logits, caches)`` of one prefill pane — the shapes
        and dtypes ``prefill`` would return for a ``(max_batch,
        prefill_len)`` call — derived via ``jax.eval_shape`` without
        running (or even compiling) the model. The paged state pool
        (serving/pool.py) sizes its slot buffers from this, so pool
        preallocation can never drift from what prefill actually
        produces."""
        b, p = self.scfg.max_batch, self.scfg.prefill_len
        pf = functools.partial(_prefill_impl, cfg=self.cfg,
                               q_chunk=self.scfg.q_chunk)
        pshapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        return jax.eval_shape(pf, pshapes,
                              jax.ShapeDtypeStruct((b, p), jnp.int32),
                              jax.ShapeDtypeStruct((b, p), jnp.bool_))

    # ------------------------------------------------------------------
    def apply_patch(self, leaves: Dict[str, Any]) -> int:
        """Install new values for a subset of parameter leaves.

        ``leaves`` maps ``jax.tree_util.keystr`` paths (the convention
        ``training/online.py`` emits) to full replacement arrays. O(patch):
        only the named leaves are validated, transferred (re-placed to
        their sharded layout on a mesh) and rebound; every other leaf
        object is reused as-is, and ``self.params`` swaps in one tree
        rebind — the caller (``Gateway.install_patch``) decides *when*
        that rebind is safe (between panes). Shapes and dtypes must match
        the current tree exactly: the jitted entry points were traced
        against them, and a silent mismatch would mean recompilation (or
        wrong math) mid-serving. Returns the number of leaves patched.
        """
        if not leaves:
            return 0
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.params)
        by_path = {jax.tree_util.keystr(p): i for i, (p, _) in
                   enumerate(flat)}
        ns_leaves = (jax.tree.leaves(self._param_ns)
                     if self.mesh is not None else None)
        new_leaves = [leaf for _, leaf in flat]
        for key, val in leaves.items():
            i = by_path.get(key)
            if i is None:
                raise KeyError(
                    f"patch leaf {key!r} is not in the parameter tree")
            old = new_leaves[i]
            arr = jnp.asarray(val)
            if tuple(arr.shape) != tuple(old.shape):
                raise ValueError(
                    f"patch leaf {key!r}: shape {tuple(arr.shape)} != "
                    f"{tuple(old.shape)}")
            if arr.dtype != old.dtype:
                raise ValueError(
                    f"patch leaf {key!r}: dtype {arr.dtype} != "
                    f"{old.dtype}")
            new_leaves[i] = (jax.device_put(arr, ns_leaves[i])
                            if ns_leaves is not None else arr)
        self.params = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return len(leaves)

    # ------------------------------------------------------------------
    def pad_tokens(self, seqs, length: int, align: str = "right",
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Pad a list of variable-length token lists into (tokens, valid)
        of shape (max_batch, length).

        Prefill buffers are right-aligned (real tokens end at the last
        buffer position, so one uniform ``next_pos`` covers the batch);
        inject suffixes are LEFT-aligned (real tokens contiguous from the
        row's ``next_pos`` — RoPE distances stay exact per row).

        Raises ``ValueError`` when more than ``max_batch`` sequences are
        passed — silently dropping requests is a serving bug; callers with
        larger waves must pane-split (see serving/scheduler.py).
        """
        b = self.scfg.max_batch
        if len(seqs) > b:
            raise ValueError(
                f"{len(seqs)} sequences exceed max_batch={b}; split the "
                f"request wave into panes of at most {b} rows")
        toks = np.zeros((b, length), np.int32)
        valid = np.zeros((b, length), bool)
        for i, s in enumerate(seqs):
            s = list(s)[-length:]
            if not s:
                continue
            if align == "right":
                toks[i, length - len(s):] = s
                valid[i, length - len(s):] = True
            else:
                toks[i, :len(s)] = s
                valid[i, :len(s)] = True
        return toks, valid

    # ------------------------------------------------------------------
    def _place(self, x, ns):
        """Reshard ``x`` to its serving layout (no-op off-mesh / already
        placed). device_put, not in_shardings: committed arrays — LRU rows
        concatenated host-side into a pane — need an actual transfer."""
        if self.mesh is None or x is None:
            return x
        return jax.device_put(x, ns)

    # ------------------------------------------------------------------
    def prefill(self, tokens, valid) -> Dict[str, Any]:
        """Materialize the batch-history state (the daily-job analogue).

        Positions index the padded buffer (real tokens right-aligned), so
        subsequent inject/decode positions continue at ``buf_len`` —
        relative distances between real tokens are exact under RoPE.
        """
        tokens = self._place(jnp.asarray(tokens), self._tok_ns)
        valid = self._place(jnp.asarray(valid), self._tok_ns)
        logits, caches = self._prefill(self.params, tokens, valid)
        b, s = tokens.shape
        return {"caches": caches, "valid": valid,
                # right-aligned prefill: every row's next position is S
                "next_pos": jnp.full((b,), s, jnp.int32),
                "logits": logits}

    def inject(self, state: Dict[str, Any], suffix_tokens, suffix_valid,
               fallback_logits=None) -> Dict[str, Any]:
        """Incremental prefill of fresh events against a cached state —
        the paper's injection: O(suffix) compute, model untouched.
        Suffix must be LEFT-aligned (see pad_tokens).

        All state bookkeeping (valid concat, next_pos advance, per-row
        last-*valid*-position logit extraction) happens inside the jit —
        eager follow-up ops on the sharded outputs were a measurable
        serve-path cost. Extra keys vs prefill state: ``n_valid`` (real
        suffix length per row) and ``last_valid_logits`` (the next-item
        scores after the row's final real event). When
        ``fallback_logits`` (B, Vp) is given — the pre-inject scores —
        the result also carries ``first_logits``: last-valid scores for
        rows with a real suffix, the fallback for empty rows."""
        args = [self.params,
                self._place(state["caches"], self._seq_ns),
                self._place(jnp.asarray(suffix_tokens), self._tok_ns),
                self._place(jnp.asarray(suffix_valid), self._tok_ns),
                self._place(state["valid"], self._tok_ns),
                self._place(state["next_pos"], self._row_ns)]
        if fallback_logits is None:
            return self._inject(*args)
        return self._inject_fb(
            *args, self._place(jnp.asarray(fallback_logits), self._tok_ns))

    def finalize(self, state: Dict[str, Any]) -> Dict[str, Any]:
        """Sequence-form state -> fixed-capacity ring cache for decode."""
        caches = self._finalize(self._place(state["caches"], self._seq_ns),
                                self._place(state["valid"], self._tok_ns))
        return {"caches": caches,
                "pos": self._place(state["next_pos"], self._row_ns)}

    def decode(self, dec: Dict[str, Any], tokens) -> Tuple[jnp.ndarray, Dict]:
        """One serve step: tokens (B,1) -> (logits (B,Vp), updated dec)."""
        logits, caches = self._decode(
            self.params,
            self._place(dec["caches"], self._ring_ns),
            self._place(jnp.asarray(tokens), self._tok_ns),
            self._place(dec["pos"], self._row_ns))
        return logits[:, 0], {"caches": caches, "pos": dec["pos"] + 1}

    def decode_slate(self, state: Dict[str, Any], first_logits,
                     slate_len: int, row_lens=None) -> np.ndarray:
        """finalize + a greedy distinct-item slate in ONE jit call.

        The per-token python loop (mask → argmax → decode → sync) used to
        dominate the serve hot path with eager-op dispatch; here the whole
        slate runs as a ``lax.scan`` over ``slate_len - 1`` decode steps
        with the already-chosen mask kept on device. Greedy only: a
        ``temperature > 0`` engine raises rather than silently serving
        greedy slates (sampled slate decode is not implemented).
        Returns int32 (B, slate_len); each row's items are distinct.

        ``row_lens`` (B,) enables **per-request slate lengths** inside a
        fixed-shape pane: the pane still decodes ``slate_len`` (the pane
        max) steps as one traced program, but every row's slots at
        ``>= row_lens[row]`` are masked to -1 inside the jit. The first
        ``row_lens[row]`` items of a row are bitwise identical to what a
        ``slate_len=row_lens[row]`` decode of that row would have chosen
        (greedy decode is a prefix-stable sequence), so callers just
        slice. ``row_lens`` is a traced operand — one compiled program
        serves every mix of lengths at a given pane max.
        """
        if self.scfg.temperature > 0:
            raise NotImplementedError(
                "decode_slate is greedy-only; sampled slate decode "
                f"(temperature={self.scfg.temperature}) is not implemented "
                "— drive decode()/sample() directly for sampled serving")
        dec = self.finalize(state)
        key = slate_len if row_lens is None else ("masked", slate_len)
        fn = self._slate_fns.get(key)
        if fn is None:
            body = _slate_impl if row_lens is None else _slate_masked_impl
            impl = functools.partial(body, cfg=self.cfg,
                                     slate_len=slate_len)
            if self.mesh is None:
                fn = jax.jit(impl)
            elif row_lens is None:
                fn = jax.jit(impl, in_shardings=(
                    self._param_ns, self._ring_ns, self._row_ns,
                    self._tok_ns), out_shardings=self._tok_ns)
            else:
                fn = jax.jit(impl, in_shardings=(
                    self._param_ns, self._ring_ns, self._row_ns,
                    self._tok_ns, self._row_ns), out_shardings=self._tok_ns)
            self._slate_fns[key] = fn
        first = self._place(jnp.asarray(first_logits), self._tok_ns)
        if row_lens is None:
            return np.asarray(fn(self.params, dec["caches"], dec["pos"],
                                 first))
        lens = self._place(jnp.asarray(row_lens, jnp.int32), self._row_ns)
        return np.asarray(fn(self.params, dec["caches"], dec["pos"], first,
                             lens))

    def sample(self, logits, rng=None) -> jnp.ndarray:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)


# ----------------------------------------------------------------------
# jit bodies (pure functions of pytrees + static cfg)
# ----------------------------------------------------------------------

def _prefill_impl(params, tokens, valid, *, cfg, q_chunk):
    return prefill(params, cfg, tokens, valid=valid, q_chunk=q_chunk)


def _inject_impl(params, caches, tokens, valid, prefix_valid, start,
                 fallback_logits=None, *, cfg, q_chunk):
    logits, caches = extend(params, cfg, caches, tokens, start,
                            valid=valid, prefix_valid=prefix_valid,
                            q_chunk=q_chunk)
    n_valid = valid.sum(-1).astype(jnp.int32)
    # logits at each row's last REAL suffix position (left-aligned
    # suffixes: position n_valid - 1; clamped for empty rows, whose value
    # is meaningless — callers gate on n_valid > 0). Selected by one-hot
    # contraction, not logits[rows, idx]: a batch-dependent gather makes
    # GSPMD all-gather the whole (B,Ss,V) logits across the data axis.
    sel = (jnp.arange(logits.shape[1], dtype=jnp.int32)[None, :]
           == jnp.maximum(n_valid - 1, 0)[:, None])
    last_valid = jnp.einsum("bs,bsv->bv", sel.astype(logits.dtype), logits)
    out = {
        "caches": caches, "logits": logits,
        "valid": jnp.concatenate([prefix_valid, valid], axis=1),
        "next_pos": start + n_valid,
        "n_valid": n_valid,
        "last_valid_logits": last_valid,
    }
    if fallback_logits is not None:
        # next-item scores per row: after the last real fresh event, or
        # the caller-supplied pre-inject scores when the row's suffix is
        # empty — computed here so the serve loop never syncs logits
        out["first_logits"] = jnp.where(
            (n_valid > 0)[:, None], last_valid, fallback_logits)
    return out


def _finalize_impl(caches, valid, *, cfg, capacity):
    return cache_from_prefill(cfg, caches, capacity, valid=valid)


def _decode_impl(params, caches, tokens, pos, *, cfg):
    return decode_step(params, cfg, caches, tokens, pos)


def _slate_impl(params, caches, pos, first, *, cfg, slate_len):
    """Greedy slate of ``slate_len`` distinct items as one traced loop.

    Matches the retired host loop operation-for-operation: pick from the
    current logits with already-chosen items masked, then advance decode —
    ``slate_len - 1`` decode steps total (the last pick needs no advance).
    """
    vocab_iota = jnp.arange(first.shape[-1], dtype=jnp.int32)

    def pick(logits, mask):
        tok = jnp.argmax(jnp.where(mask, -1e30, logits),
                         axis=-1).astype(jnp.int32)
        # mark via one-hot compare, NOT a scatter: a scatter's indices
        # force GSPMD to all-gather inside the decode loop (a cross-device
        # sync per step); the compare partitions cleanly over the batch
        return tok, mask | (vocab_iota[None, :] == tok[:, None])

    def step(carry, _):
        caches, pos, logits, mask = carry
        tok, mask = pick(logits, mask)
        nxt, caches = decode_step(params, cfg, caches, tok[:, None], pos)
        return (caches, pos + 1, nxt[:, 0], mask), tok

    mask0 = jnp.zeros(first.shape, bool)
    (_, _, logits, mask), toks = jax.lax.scan(
        step, (caches, pos, first, mask0), None, length=slate_len - 1)
    last, _ = pick(logits, mask)
    return jnp.concatenate(
        [jnp.moveaxis(toks, 0, 1), last[:, None]], axis=1)


def _slate_masked_impl(params, caches, pos, first, row_lens, *, cfg,
                       slate_len):
    """Per-request slate lengths on a fixed-shape pane: decode the pane
    max, then mask each row's tail (slots >= row_lens[row]) to -1. The
    mask is a compare against an iota — no batch-dependent scatter, so
    the partitioned program stays collective-free like the uniform one.
    Greedy decode picks each item from state that only depends on the
    items already chosen, so a row's first k items are exactly the
    k-slate it would have been served alone."""
    slate = _slate_impl(params, caches, pos, first, cfg=cfg,
                        slate_len=slate_len)
    keep = (jnp.arange(slate_len, dtype=jnp.int32)[None, :]
            < row_lens[:, None])
    return jnp.where(keep, slate, -1)


# ----------------------------------------------------------------------
# serve_step for the dry-run: ONE token against a seq_len cache
# ----------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig):
    """The function the decode-shape dry-runs lower: greedy one-token step.

    signature: (params, caches, tokens (B,1), pos (B,)) ->
               (next_token (B,), caches')
    """
    def serve_step(params, caches, tokens, pos):
        logits, caches = decode_step(params, cfg, caches, tokens, pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return nxt, caches
    return serve_step
