"""Typed per-request serving API — the unit of the paper's deployment.

The paper's surface is per-request: a user arrives, their fresh suffix
is injected, a slate is served. This module is the request-level
contract the :class:`~repro.serving.scheduler.Gateway` serves:

    Request   — one arrival: (user, now) plus optional per-request
                policy (the A/B arm), slate_len, deadline, and tag.
                Frozen; validated at construction so a malformed request
                fails at the call site, not as a shape error inside jit.
    Response  — the served slate + next-item scores + structured
                telemetry for that request.
    Ticket    — the handle ``submit`` returns; ``.response`` fills in
                when the scheduler flushes the pane the request rode in.
    Event     — one feedback event (user watched item at ts); the
                ingestion type ``Gateway.observe`` and the platform
                observe hooks share.

Per-request **policy** is what makes the A/B split expressible at
request granularity (the wave API baked one policy into the server):
rows with different arms coexist in one fixed-shape pane and are
resolved at feature-assembly time. ``hash_arm`` is the deterministic
user->arm assignment an experiment uses to label requests.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

POLICIES = ("batch", "inject", "fresh", "decay")


# ----------------------------------------------------------------------
# Events (ingestion side of the facade)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """One feedback event: ``user`` watched ``item`` at ``ts``."""
    user: int
    item: int
    ts: int


def as_event(ev) -> Event:
    """Coerce an event-like value — an :class:`Event`, a ``(user, item,
    ts)`` tuple, or any object with ``.user/.item/.ts`` attributes (the
    simulator's event records) — into an :class:`Event`."""
    if isinstance(ev, Event):
        return ev
    if isinstance(ev, (tuple, list)) and len(ev) == 3:
        return Event(int(ev[0]), int(ev[1]), int(ev[2]))
    try:
        return Event(int(ev.user), int(ev.item), int(ev.ts))
    except AttributeError:
        raise TypeError(
            f"cannot interpret {ev!r} as an event; pass an Event, a "
            f"(user, item, ts) tuple, or an object with .user/.item/.ts")


# ----------------------------------------------------------------------
# Request / Response
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request, validated at construction.

    ``policy``/``slate_len`` default to ``None`` = "use the gateway's
    configured default" — a request only carries what it overrides.
    ``deadline`` is an absolute time: the scheduler must flush the
    request's pane (padding it if short) once its clock reaches it.
    ``tag`` is free-form caller context (experiment arm label, trace
    id); it rides through to the telemetry untouched.
    """
    user: int
    now: int
    policy: Optional[str] = None
    slate_len: Optional[int] = None
    deadline: Optional[int] = None
    tag: Optional[str] = None

    def __post_init__(self):
        if self.user < 0:
            raise ValueError(f"user must be >= 0, got {self.user}")
        if self.policy is not None and self.policy not in POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; expected one of "
                f"{POLICIES} (or None for the gateway default)")
        if self.slate_len is not None and self.slate_len < 1:
            raise ValueError(
                f"slate_len must be >= 1, got {self.slate_len}")
        if self.deadline is not None and self.deadline < self.now:
            raise ValueError(
                f"deadline ({self.deadline}) must be >= the request's "
                f"arrival time now ({self.now})")


@dataclasses.dataclass(frozen=True)
class RequestTelemetry:
    """Structured per-request observability, attached to every Response.

    ``queue_delay`` is in request-clock units (``served_at - now``,
    clamped at 0): how long the request waited for its pane to fill or
    its deadline to fire. The clamp matters only under the deprecated
    legacy shim, whose non-monotonic replay rewinds the gateway clock —
    a request pending from a later wave would otherwise record a
    negative delay and pollute the ``stats()`` percentiles. ``path``
    says what the request actually paid:

      * ``"prefill"`` — the row paid a batch-history prefill this
        request (cache miss, uncacheable policy, or caching disabled);
      * ``"inject"``  — served from a cached prefill state with a
        non-empty fresh suffix injected (the paper's hot path);
      * ``"cached"``  — served from a cached prefill state with no
        fresh events pending (pure cache read + decode);
      * ``"decay"``   — served model-free: the slate was ranked by
        exponentially time-decayed event scores computed from the
        user's cutoff-exact features (policy ``"decay"``); no engine
        call, no cache entry;
      * ``"shed"``    — never served: the deadline-aware load-shedder
        rejected the request because its projected completion time
        exceeded its deadline (``Response.shed`` is True, the slate is
        empty, ``pane_id`` is -1). Shed rows are counted in
        ``GatewayStats.shed``, not in ``paths``.
    """
    request_id: int
    user: int
    policy: str
    slate_len: int
    pane_id: int
    queue_delay: int
    cache_hit: bool
    path: str
    generation: int
    submitted_at: int
    served_at: int
    tag: Optional[str] = None
    model_version: int = 0    # weight version the pane was scored with


@dataclasses.dataclass
class Response:
    """What one request gets back: the slate, the scores it was ranked
    from, and the request's telemetry record.

    ``shed=True`` is the typed rejection marker of deadline-aware load
    shedding (``ServerConfig.shed_policy``): the scheduler projected the
    request would complete past its deadline and refused to serve it
    late. A shed response carries an **empty** slate/scores and a
    telemetry record with ``path="shed"`` — callers must check ``shed``
    before reading the slate."""
    slate: np.ndarray          # (slate_len,) int32 greedy distinct items
    scores: np.ndarray         # (vocab_padded,) float32 next-item logits
    telemetry: RequestTelemetry
    shed: bool = False         # True -> rejected by the load-shedder


class Ticket:
    """Handle for a submitted request; ``response`` fills at flush (or
    immediately with a shed marker when the load-shedder rejects).
    ``completed_wall`` is the ``time.perf_counter()`` stamp taken when
    the response filled — ``completed_wall - submitted_wall`` is the
    request's wall-clock residence time, the number the load generator's
    per-path serve-latency SLOs gate on."""

    __slots__ = ("request", "request_id", "response", "submitted_wall",
                 "completed_wall")

    def __init__(self, request: Request, request_id: int,
                 submitted_wall: float = 0.0):
        self.request = request
        self.request_id = request_id
        self.response: Optional[Response] = None
        self.submitted_wall = submitted_wall
        self.completed_wall: float = 0.0

    @property
    def done(self) -> bool:
        return self.response is not None

    def __repr__(self) -> str:
        state = "done" if self.done else "pending"
        return (f"Ticket(id={self.request_id}, user={self.request.user}, "
                f"{state})")


# ----------------------------------------------------------------------
# Typed gateway telemetry aggregates
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RolloverStats:
    """Generation-rollover telemetry: warm-handoff and incremental-build
    counters (see scheduler docstring, "Generation rollover")."""
    rollovers: int            # generation rolls the gateway handed across
    rekeyed: int              # entries renamed to the new generation
    invalidated: int          # entries purged (changed users/stale gens)
    retained: int             # changed-user old-gen entries kept at handoff
    rebuilt: int              # users re-prefilled by warm_step
    delta_rewarms: int        # entries rebuilt via O(delta) deferred inject
    build_steps: int          # incremental snapshot-build slices run
    build_time_s: float       # wall time spent in completed builds
    pending_build_users: int  # users left in the in-flight build
    pending_rewarm: int       # invalidated users still queued for re-warm
    # worst single clock-call slice spent advancing the snapshot job
    # (wall time, so excluded from == — the sharded-equivalence check
    # compares stats across gateways whose wall clocks differ)
    build_slice_max_s: float = dataclasses.field(compare=False,
                                                 default=0.0)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def __getitem__(self, key: str) -> Any:
        # migration shim for dict-era callers (stats()["rollover"]["rekeyed"])
        return getattr(self, key)


@dataclasses.dataclass(frozen=True)
class GatewayStats:
    """The typed ``Gateway.stats()`` snapshot.

    Frozen and directly comparable (the sharded-equivalence check
    asserts single-device == mesh stats by ``==``). ``paths`` and
    ``queue_delay`` stay plain dicts — they are aggregate views the
    bench suites serialize as-is. ``__getitem__`` keeps dict-era
    ``stats()["key"]`` callers working; new code should use attributes,
    and anything that needs JSON should call :meth:`as_dict`.
    """
    requests: int
    panes: int
    pending: int              # queued, not yet served
    completed: int            # served, not yet claimed by poll()/drain()
    prefill_calls: int
    inject_calls: int
    decode_steps: int
    deadline_flushes: int
    shed: int                 # requests rejected by the load-shedder
    deadline_misses: int      # requests SERVED past their deadline
    paths: Dict[str, int]     # "prefill"/"inject"/"cached"/"decay" rows
    queue_delay: Dict[str, float]  # window/p50/p99/max over recent requests
    rollover: RolloverStats
    cache: Dict[str, int]     # PrefillStateCache / PagedStateCache counters
    # tiered EventLog ingest counters (EventLog.ingest_stats()):
    # appended/events_hot/events_warm/bytes_hot/bytes_warm/demoted/
    # dropped_late/trimmed/evicted/compactions/segments/hot_overflow
    ingest: Dict[str, int] = dataclasses.field(default_factory=dict)
    model_version: int = 0    # current hot-swapped weight version
    patches_applied: int = 0  # delta weight patches installed so far
    # worst single install_patch() stall observed on the serving thread
    # (wall-clock ms, so excluded from == like build_slice_max_s)
    patch_install_max_ms: float = dataclasses.field(compare=False,
                                                    default=0.0)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)  # recurses into RolloverStats

    def __getitem__(self, key: str) -> Any:
        return getattr(self, key)


# ----------------------------------------------------------------------
# Per-request A/B arm assignment
# ----------------------------------------------------------------------

def hash_arm(user: int, arms: Sequence[str] = ("control", "treatment"),
             salt: int = 0) -> str:
    """Deterministic user -> arm assignment for request-level A/B.

    Stable across processes (md5, not ``hash()``), uniform over arms,
    and re-randomizable per experiment via ``salt``. The same user is
    always in the same arm within one salt — the unit of randomization
    is the user, as in the paper's experiment — but assignment happens
    per *request*, which is what lets arms share one serving fleet
    (mixed-policy panes) instead of one server per arm.
    """
    if not arms:
        raise ValueError("arms must be non-empty")
    h = hashlib.md5(f"{salt}:{int(user)}".encode()).hexdigest()
    return arms[int(h, 16) % len(arms)]


def assign_arms(users, arms: Sequence[str] = ("control", "treatment"),
                salt: int = 0) -> Tuple[str, ...]:
    """Vector form of :func:`hash_arm` over a user array."""
    return tuple(hash_arm(int(u), arms, salt) for u in np.asarray(users).ravel())
