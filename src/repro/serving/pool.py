"""Paged device-resident prefill-state pool (vLLM-style paging for ITFI).

The host LRU (`scheduler.PrefillStateCache`) keeps per-user prefill
states as numpy rows and re-assembles every pane with host concats — one
host->device transfer per pane, per admission AND per hit. This module
is the device-resident successor: one **preallocated pool** of
``n_slots`` prefill-state slots lives on the devices for the engine's
lifetime, and pane assembly/writeback are slot-indexed **one-hot
contractions** inside jit:

    gather:  pane_row[b]  = Σ_n onehot[b, n] · pool[n]     (assembly)
    scatter: pool'[n]     = (1 - covered[n]) · pool[n]
                            + Σ_b onehot[b, n] · pane_row[b]  (writeback)

On a mesh: one-hot einsums, never batch-dependent ``take``/scatter ops
— the same zero-collective discipline the engine's inject/decode paths
use: a dynamic gather on a partitioned operand makes GSPMD all-gather
the whole pool, while the einsum partitions by output rows. The pool's
slot axis is REPLICATED over the data axes (`rules.slot_pool_pspecs`),
the gathered pane comes out data-sharded, and the compiled programs
carry **zero collectives** — asserted from HLO by
``tools/slot_pool_check.py``. On a single device there is nothing to
partition, so the gather drops to a direct ``take`` (an O(pane)
indexed copy instead of the einsum's O(n_slots x pane) contraction —
bitwise identical, both are exact copies); the scatter keeps the
one-hot form everywhere (fixed shapes for any writeback width, and it
only runs on admissions).

Bitwise exactness: multiplying by 0/1 and adding 0 is exact in every
float dtype, and integer/bool leaves contract in int32 — a gathered row
is bit-identical to the slot contents, and a scattered slot is
bit-identical to the pane row. The pooled serving path therefore serves
slates bitwise equal to the host-LRU path (property-tested in
tests/test_state_pool.py).

Only **prefill** states are pooled (sequence length fixed at
``prefill_len``): post-inject states grow the sequence axis and are
never written back, which is exactly the cache-key invariant — an entry
keyed ``(user, generation)`` is a pure function of the user's
snapshot-row history and the params; fresh suffixes never enter a slot.

:class:`PagedStateCache` is the slot table on top: an LRU mapping
``(user, generation) -> slot`` with a free-slot allocator,
slot-pressure eviction (a full pool IS the byte budget: fixed slots =
fixed bytes), and the same counter/rekey surface as the host
``PrefillStateCache`` — so the PR 5 warm handoff composes unchanged:
``rekey_generation`` renames slot-table keys and **never touches the
device arrays**.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Any, Dict, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.serving.engine import ServingEngine


# ----------------------------------------------------------------------
# One-hot gather/scatter jit bodies
# ----------------------------------------------------------------------

def _sel(leaf, onehot, slot_axis: int):
    """Gather pane rows from a pool leaf: one-hot einsum over the slot
    axis (axis 1 for cache leaves, axis 0 for the flat planes). Bool and
    integer leaves contract in int32 so every dtype round-trips exactly."""
    dt = leaf.dtype
    cast = dt == jnp.bool_
    work = leaf.astype(jnp.int32) if cast else leaf
    w = onehot.astype(work.dtype)
    if slot_axis == 1:
        out = jnp.einsum("bn,rn...->rb...", w, work)
    else:
        out = jnp.einsum("bn,n...->b...", w, work)
    return out.astype(dt) if cast else out


def _upd(pool_leaf, rows_leaf, onehot, covered, slot_axis: int):
    """Scatter pane rows into pool slots: slots covered by the one-hot
    are overwritten, the rest pass through untouched (0/1 arithmetic —
    exact in every dtype, int32 for bool/int leaves)."""
    dt = pool_leaf.dtype
    cast = dt == jnp.bool_
    pl = pool_leaf.astype(jnp.int32) if cast else pool_leaf
    rl = rows_leaf.astype(jnp.int32) if cast else rows_leaf
    w = onehot.astype(pl.dtype)
    keep = (1 - covered).astype(pl.dtype)
    if slot_axis == 1:
        contrib = jnp.einsum("bn,rb...->rn...", w, rl)
        keep = keep.reshape((1, -1) + (1,) * (pl.ndim - 2))
    else:
        contrib = jnp.einsum("bn,b...->n...", w, rl)
        keep = keep.reshape((-1,) + (1,) * (pl.ndim - 1))
    out = pl * keep + contrib
    return out.astype(dt) if cast else out


def _gather_impl(caches, valid, next_pos, last, onehot):
    return ({"caches": jax.tree.map(lambda x: _sel(x, onehot, 1), caches),
             "valid": _sel(valid, onehot, 0),
             "next_pos": _sel(next_pos, onehot, 0),
             "logits": None},
            _sel(last, onehot, 0))


def _gather_take_impl(caches, valid, next_pos, last, idx):
    """Single-device gather: a direct indexed copy. The one-hot einsum
    exists to keep GSPMD from all-gathering a partitioned pool — on one
    device there is nothing to partition, and the einsum's
    O(n_slots x pane) contraction is pure waste next to this O(pane)
    take. Bitwise identical (both are exact copies of slot contents)."""
    return ({"caches": jax.tree.map(lambda x: jnp.take(x, idx, axis=1),
                                    caches),
             "valid": jnp.take(valid, idx, axis=0),
             "next_pos": jnp.take(next_pos, idx, axis=0),
             "logits": None},
            jnp.take(last, idx, axis=0))


def _scatter_impl(caches, valid, next_pos, last,
                  st_caches, st_valid, st_next_pos, st_logits, onehot):
    covered = onehot.sum(axis=0)  # (n_slots,) 0/1: slots written this call
    return (jax.tree.map(lambda p_, r_: _upd(p_, r_, onehot, covered, 1),
                         caches, st_caches),
            _upd(valid, st_valid, onehot, covered, 0),
            _upd(next_pos, st_next_pos, onehot, covered, 0),
            # the slot keeps the prefill's LAST-position logits — the
            # next-item scores when a request carries no fresh suffix —
            # sliced here so callers never sync the full (B,S,Vp) plane
            _upd(last, st_logits[:, -1, :], onehot, covered, 0))


# ----------------------------------------------------------------------
# The pool
# ----------------------------------------------------------------------

class DeviceStatePool:
    """Preallocated device buffers for ``n_slots`` prefill-state rows.

    Shapes/dtypes come from ``engine.prefill_state_shapes()`` (an
    ``eval_shape`` of the real prefill body, so the pool can never drift
    from what prefill produces). On a mesh the pool is allocated in its
    ``slot_pool_pspecs`` layout — slot axis replicated over data,
    model dims TP-sharded — and the gather's ``out_shardings`` are the
    engine's pane layouts, so gathered state feeds ``inject``/
    ``finalize`` with no resharding. The pool is **donated** through
    ``scatter``: writeback updates the buffers in place, one pool-sized
    working set, not two.

    ``scatter`` inputs are re-placed to replicated-over-data at the call
    boundary (`device_put`): the writeback einsum contracts over the
    pane's batch axis, and a batch-sharded operand would force an
    all-reduce *inside* the compiled program. The explicit transfer
    keeps the compiled scatter collective-free — the same pattern as the
    engine's own call-boundary placement.
    """

    def __init__(self, engine: ServingEngine, n_slots: int):
        b = engine.scfg.max_batch
        if n_slots < b:
            raise ValueError(
                f"pool_slots={n_slots} must be >= max_batch={b}: a single "
                f"pane can pin one slot per row during assembly")
        self.engine = engine
        self.n_slots = int(n_slots)
        self.data_shards = engine.data_shards
        logits_s, caches_s = engine.prefill_state_shapes()
        p = engine.scfg.prefill_len
        vp = logits_s.shape[-1]

        mesh = engine.mesh
        if mesh is None:
            alloc = lambda shape, dtype, spec: jnp.zeros(shape, dtype)
            oh_ns = pane_out = None
        else:
            from repro.sharding.rules import slot_pool_pspecs
            sp = slot_pool_pspecs(engine.cfg, mesh)
            ns = lambda spec: jax.tree.map(
                lambda s: NamedSharding(mesh, s), spec,
                is_leaf=lambda x: isinstance(x, P))
            self._cache_ns, self._valid_ns = ns(sp.caches), ns(sp.valid)
            self._rows_ns, self._logits_ns = ns(sp.rows), ns(sp.logits)
            self._st_logits_ns = NamedSharding(mesh, P(None, None, None))
            alloc = lambda shape, dtype, spec: jax.device_put(
                jnp.zeros(shape, dtype), spec)
            oh_ns = NamedSharding(mesh, P(None, None))
            pane_out = ({"caches": engine._seq_ns, "valid": engine._tok_ns,
                         "next_pos": engine._row_ns, "logits": None},
                        engine._tok_ns)

        slotted = lambda s: (s.shape[0], self.n_slots) + s.shape[2:]
        self.caches = (jax.tree.map(
            lambda s: alloc(slotted(s), s.dtype, None), caches_s)
            if mesh is None else jax.tree.map(
                lambda s, nsh: alloc(slotted(s), s.dtype, nsh),
                caches_s, self._cache_ns))
        self.valid = alloc((self.n_slots, p), jnp.bool_,
                           None if mesh is None else self._valid_ns)
        self.next_pos = alloc((self.n_slots,), jnp.int32,
                              None if mesh is None else self._rows_ns)
        self.last_logits = alloc((self.n_slots, vp), logits_s.dtype,
                                 None if mesh is None else self._logits_ns)
        self.slot_nbytes = sum(
            x.nbytes for x in jax.tree.leaves(
                (self.caches, self.valid, self.next_pos, self.last_logits))
        ) // self.n_slots

        if mesh is None:
            # no mesh -> no partitioning constraint: gather by direct
            # take (O(pane), not O(n_slots x pane)); scatter keeps the
            # one-hot update (fixed shapes regardless of how many rows
            # a pane writes back, and it only runs on admissions)
            self._gather = jax.jit(_gather_take_impl)
            self._scatter = jax.jit(_scatter_impl,
                                    donate_argnums=(0, 1, 2, 3))
        else:
            pool_in = (self._cache_ns, self._valid_ns, self._rows_ns,
                       self._logits_ns)
            self._gather = jax.jit(
                _gather_impl, in_shardings=pool_in + (oh_ns,),
                out_shardings=pane_out)
            self._scatter = jax.jit(
                _scatter_impl,
                in_shardings=pool_in + (self._cache_ns, self._valid_ns,
                                        self._rows_ns, self._st_logits_ns,
                                        oh_ns),
                out_shardings=pool_in, donate_argnums=(0, 1, 2, 3))
        self.gathers = 0
        self.scatters = 0

    # ------------------------------------------------------------------
    def _onehot(self, slots: Sequence[int]) -> np.ndarray:
        b = self.engine.scfg.max_batch
        if len(slots) > b:
            raise ValueError(
                f"{len(slots)} rows exceed max_batch={b}")
        oh = np.zeros((b, self.n_slots), np.float32)
        for row, s in enumerate(slots):
            oh[row, s] = 1.0
        return oh

    def gather(self, slots: Sequence[int]) -> Tuple[Dict[str, Any], Any]:
        """Assemble a pane from slot ids (row ``i`` reads ``slots[i]``;
        short panes pad by repeating ``slots[0]``). Returns
        ``(state, last)``: a sequence-form engine state (sharded to the
        pane layout on a mesh) plus the per-row pre-inject next-item
        logits."""
        b = self.engine.scfg.max_batch
        if not slots:
            raise ValueError("gather of an empty pane")
        if len(slots) > b:
            raise ValueError(f"{len(slots)} rows exceed max_batch={b}")
        slots = list(slots) + [slots[0]] * (b - len(slots))
        if self.engine.mesh is None:
            state, last = self._gather(
                self.caches, self.valid, self.next_pos, self.last_logits,
                jnp.asarray(slots, jnp.int32))
        else:
            state, last = self._gather(self.caches, self.valid,
                                       self.next_pos, self.last_logits,
                                       self._onehot(slots))
        self.gathers += 1
        return state, last

    def scatter(self, state: Dict[str, Any], slots: Sequence[int]) -> None:
        """Write prefill-pane rows into slots (row ``i`` -> ``slots[i]``;
        trailing pad rows of the pane are simply not listed). In-place:
        the pool buffers are donated into the update."""
        oh = self._onehot(slots)
        caches, valid = state["caches"], state["valid"]
        next_pos, logits = state["next_pos"], state["logits"]
        if self.engine.mesh is not None:
            # replicate the pane over the data axes OUTSIDE the compiled
            # program (see class docstring)
            caches = jax.device_put(caches, self._cache_ns)
            valid = jax.device_put(valid, self._valid_ns)
            next_pos = jax.device_put(next_pos, self._rows_ns)
            logits = jax.device_put(logits, self._st_logits_ns)
        (self.caches, self.valid, self.next_pos,
         self.last_logits) = self._scatter(
            self.caches, self.valid, self.next_pos, self.last_logits,
            caches, valid, next_pos, logits, oh)
        self.scatters += 1


# ----------------------------------------------------------------------
# The slot table
# ----------------------------------------------------------------------

class PagedStateCache:
    """LRU slot table over a :class:`DeviceStatePool` — the pooled
    counterpart of ``scheduler.PrefillStateCache``.

    Same key discipline (``(user, generation)``), same counter surface
    (hits/misses/evictions/invalidations/rekeys), same warm-handoff
    entry points (``rekey_generation`` / ``invalidate_except``) — but
    the values are **slot indices**, not host arrays, so every table
    operation is O(metadata): rekeying a generation renames dict keys
    and never moves a byte of device state, and invalidation just
    returns slots to the free list (the buffers are overwritten on next
    admission, not zeroed).

    Eviction is **slot-pressure**: the pool is the byte budget (fixed
    slots × fixed slot size). When the free list is empty, allocation
    evicts the least-recently-used entry whose slot is not ``pinned`` —
    the pin set (slots referenced by the pane being assembled) makes
    mid-assembly eviction safe: a slot this pane reads or just wrote can
    never be reallocated out from under it. With ``n_slots >=
    max_batch`` (enforced by the pool) an allocation can always succeed.
    """

    def __init__(self, pool: DeviceStatePool):
        self.pool = pool
        self.budget = pool.n_slots      # warm() clamps to this, like the LRU
        self.byte_budget = pool.n_slots * pool.slot_nbytes
        self.shards = pool.data_shards
        self._entries: "OrderedDict[Tuple[int, int], int]" = OrderedDict()
        self._free: deque = deque(range(pool.n_slots))
        # host-side pending-inject sidecar for the O(delta) re-warm: the
        # deferred snapshot-delta tokens ride NEXT TO the slot table
        # (token lists are host metadata; the device slot itself is the
        # untouched old-generation prefill state). Keys mirror _entries
        # and are pruned wherever an entry dies, so a recycled slot can
        # never inherit a previous tenant's pending tokens.
        self._pending: Dict[Tuple[int, int], list] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.rekeys = 0
        # handoff window: retained old-generation keys of changed users
        # (retain_changed rekey) — first victims under slot pressure
        self._handoff_stale: set = set()
        self.stale_evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return key in self._entries

    @property
    def bytes_per_shard(self) -> int:
        """Resident entry bytes. The slot axis is replicated over the
        data axes, so per-shard == per-slot total (unlike the host LRU,
        whose pane rows shard over ``data``) — the price of the
        zero-collective gather, paid in HBM."""
        return len(self._entries) * self.pool.slot_nbytes

    # ------------------------------------------------------------------
    def lookup(self, user: int, gen: int) -> Optional[int]:
        slot = self._entries.get((user, gen))
        if slot is None:
            self.misses += 1
            return None
        self._entries.move_to_end((user, gen))
        self.hits += 1
        return slot

    def _alloc(self, pinned: Set[int]) -> int:
        if self._free:
            return self._free.popleft()
        # rollover-aware victim order: a retained dual-generation entry
        # (changed user, old generation) evicts before any live entry —
        # LRU order among the stale, pin-aware like every eviction here.
        # _handoff_stale is empty outside the handoff window, so the
        # steady-state scan is the same single pass as before.
        victim = None
        if self._handoff_stale:
            victim = next((k for k, s in self._entries.items()
                           if k in self._handoff_stale and s not in pinned),
                          None)
        if victim is not None:
            self._handoff_stale.discard(victim)
            self.stale_evictions += 1
        else:
            victim = next((k for k, s in self._entries.items()
                           if s not in pinned), None)
        if victim is None:
            raise RuntimeError(
                f"no allocatable slot: all {self.pool.n_slots} slots are "
                f"pinned by the pane under assembly")
        slot = self._entries.pop(victim)
        self._pending.pop(victim, None)
        self.evictions += 1
        return slot

    def admit(self, user: int, gen: int, pinned: Set[int]) -> int:
        """Allocate a slot for ``(user, gen)`` (evicting an unpinned LRU
        entry under slot pressure) and insert it most-recently-used.
        The caller scatters the state into the returned slot."""
        old = self._entries.pop((user, gen), None)
        # a fresh admission overwrites the slot contents: any deferred
        # delta attached to the previous entry is superseded
        self._pending.pop((user, gen), None)
        slot = old if old is not None else self._alloc(pinned)
        self._entries[(user, gen)] = slot
        return slot

    def alloc_scratch(self, pinned: Set[int]) -> int:
        """A table-less slot for an ephemeral (uncacheable) pane row;
        must be returned via :meth:`free_scratch` when the pane retires."""
        return self._alloc(pinned)

    def free_scratch(self, slot: int) -> None:
        self._free.append(slot)

    # ------------------------------------------------------------------
    def invalidate_except(self, gen: int) -> int:
        """Purge every entry from a generation other than ``gen`` —
        table keys only; the slots go back on the free list untouched."""
        stale = [k for k in self._entries if k[1] != gen]
        for k in stale:
            self._free.append(self._entries.pop(k))
            self._pending.pop(k, None)
        self.invalidations += len(stale)
        self._handoff_stale = {k for k in self._handoff_stale
                               if k in self._entries}
        return len(stale)

    def rekey_generation(self, old_gen: int, new_gen: int, changed,
                         retain_changed: bool = False) -> Tuple[int, int]:
        """Warm handoff, slot-table edition: identical contract to
        ``PrefillStateCache.rekey_generation`` (same caller, same
        certification requirements, same ``retain_changed`` handoff-
        window semantics — see its docstring), but a rekey is a
        dict-key rename and an invalidation a free-list push. The
        device arrays are never read, moved, or zeroed; a retained
        entry keeps its slot out of the free list until evicted."""
        changed_set = {int(u) for u in np.asarray(changed).ravel()}
        live_new = {u for (u, g) in self._entries if g == new_gen}
        out: "OrderedDict[Tuple[int, int], int]" = OrderedDict()
        pend: Dict[Tuple[int, int], list] = {}
        stale: set = set()
        rekeyed = invalidated = 0
        for (u, g), slot in self._entries.items():
            p = self._pending.get((u, g))
            if g == new_gen:
                out[(u, g)] = slot
                if p is not None:
                    pend[(u, g)] = p
            elif g == old_gen and u not in live_new:
                if u not in changed_set:
                    out[(u, new_gen)] = slot
                    if p is not None:
                        pend[(u, new_gen)] = p
                    rekeyed += 1
                elif retain_changed:
                    out[(u, g)] = slot
                    if p is not None:
                        pend[(u, g)] = p
                    stale.add((u, g))
                else:
                    self._free.append(slot)
                    invalidated += 1
            else:
                self._free.append(slot)
                invalidated += 1
        self._entries = out
        self._pending = pend
        self._handoff_stale = stale
        self.rekeys += rekeyed
        self.invalidations += invalidated
        return rekeyed, invalidated

    def rekey_entry(self, user: int, old_gen, new_gen) -> bool:
        """Rename ONE entry ``(user, old_gen)`` -> ``(user, new_gen)``
        in place — the slot-table twin of
        ``PrefillStateCache.rekey_entry`` (same O(delta) re-warm caller,
        same certification contract). A dict-key rename: the device
        arrays never move. An existing ``new_gen`` entry for the user is
        replaced (its slot returns to the free list). Pending-inject
        tokens follow the renamed key. Returns False when no
        ``(user, old_gen)`` entry exists."""
        slot = self._entries.pop((user, old_gen), None)
        if slot is None:
            return False
        prev = self._entries.pop((user, new_gen), None)
        if prev is not None:
            self._free.append(prev)
            self._pending.pop((user, new_gen), None)
        self._entries[(user, new_gen)] = slot
        self._entries.move_to_end((user, new_gen))
        p = self._pending.pop((user, old_gen), None)
        if p is not None:
            self._pending[(user, new_gen)] = p
        self._handoff_stale.discard((user, old_gen))
        self.rekeys += 1
        return True

    def drop(self, user: int, gen) -> bool:
        """Invalidate one entry (serve-time fallback when a deferred
        delta no longer fits the inject budget). The slot returns to the
        free list untouched. Returns False when absent."""
        slot = self._entries.pop((user, gen), None)
        if slot is None:
            return False
        self._free.append(slot)
        self._pending.pop((user, gen), None)
        self._handoff_stale.discard((user, gen))
        self.invalidations += 1
        return True

    # ------------------------------------------------------------------
    # Backend-neutral delta-rewarm surface (mirrored by PrefillStateCache)
    # ------------------------------------------------------------------

    def has_entry(self, user: int, gen) -> bool:
        """Membership probe with NO side effects — no LRU bump, no
        hit/miss counters (``lookup`` counts; this peeks)."""
        return (user, gen) in self._entries

    def get_pending(self, user: int, gen) -> Optional[list]:
        """The entry's deferred-inject token list, or None."""
        return self._pending.get((user, gen))

    def set_pending(self, user: int, gen, tokens) -> None:
        """Attach (or, with an empty list, clear) the entry's deferred
        snapshot-delta tokens. Raises KeyError when the entry is absent
        — pending tokens without a state to defer onto are a bug."""
        if (user, gen) not in self._entries:
            raise KeyError(f"no entry ({user}, {gen}) to attach pending "
                           f"inject tokens to")
        if tokens:
            self._pending[(user, gen)] = list(tokens)
        else:
            self._pending.pop((user, gen), None)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "rekeys": self.rekeys,
                "handoff_stale": len(self._handoff_stale),
                "stale_evictions": self.stale_evictions,
                "bytes_per_shard": self.bytes_per_shard,
                "shards": self.shards,
                "slots": self.pool.n_slots,
                "free_slots": len(self._free),
                "slot_bytes": self.pool.slot_nbytes}
