"""Training-data builder: event logs -> next-item training examples.

Encodes the paper's two training regimes (§IV):

* ``cutoff="midnight"`` — the **batch-trained** model: for a label watch at
  time t, the input history is everything the daily job had materialized by
  then, i.e. events before the last midnight prior to t. This is the model
  the paper keeps untouched and injects into (control + treatment arms).

* ``cutoff="fresh"`` — the **consistent variant**: auxiliary features
  "explicitly representing recent watch behavior (e.g., items watched in the
  past few hours)" are present at training AND inference. The example input
  is ``[batch_history…, SEP, recent_items…]`` where recent = same-day events
  before t, exactly what the serving path constructs for this arm. Because
  the logs were collected under the previously-deployed model, the recent
  segment is feedback-loop-correlated with the label — the mechanism the
  paper blames for this variant's null result.

Tokenization: item i ↦ token i+1; 0 = pad; SEP = n_items+1.
Loss is applied on the LAST position only (sequence → next item).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

DAY = 86400


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    n_items: int
    feature_len: int = 64          # input sequence length (incl. SEP segment)
    recent_len: int = 16           # max recent-segment length ("fresh" mode)
    min_history: int = 2
    seed: int = 0


def sep_token(n_items: int) -> int:
    return n_items + 1


def build_examples(events: Dict[str, np.ndarray], lcfg: LoaderConfig,
                   cutoff: str) -> Dict[str, np.ndarray]:
    """events: arrays from ``events_to_arrays`` (the platform's offline log).

    Returns {"tokens" (N,K), "labels" (N,), "valid" (N,K)} — next-item
    examples, one per watch event with enough history.
    """
    k, rl = lcfg.feature_len, lcfg.recent_len
    sep = sep_token(lcfg.n_items)
    # columnar grouping: one lexsort by (user, ts, item) replaces the
    # per-event dict build; each user's slice arrives already sorted.
    u_col = np.asarray(events["user"], np.int64)
    it_col = np.asarray(events["item"], np.int64)
    ts_col = np.asarray(events["ts"], np.int64)
    order = np.lexsort((it_col, ts_col, u_col))
    uniq, starts = np.unique(u_col[order], return_index=True)
    bounds = np.append(starts, len(order))

    toks_out, labels_out = [], []
    for g in range(len(uniq)):
        idx = order[bounds[g]:bounds[g + 1]]
        evs = list(zip(ts_col[idx].tolist(), it_col[idx].tolist()))
        for j in range(len(evs)):
            ts_label, item_label = evs[j]
            midnight = (ts_label // DAY) * DAY
            hist_batch = [e for e in evs[:j] if e[0] < midnight]
            if cutoff == "midnight":
                if len(hist_batch) < lcfg.min_history:
                    continue
                seq = [it + 1 for _, it in hist_batch[-k:]]
            elif cutoff == "fresh":
                recent = [e for e in evs[:j] if e[0] >= midnight][-rl:]
                if len(hist_batch) + len(recent) < lcfg.min_history:
                    continue
                head = [it + 1 for _, it in
                        hist_batch[-(k - 1 - len(recent)):]]
                seq = head + [sep] + [it + 1 for _, it in recent]
            else:
                raise ValueError(f"unknown cutoff {cutoff!r}")
            toks_out.append(seq)
            labels_out.append(item_label + 1)

    n = len(toks_out)
    tokens = np.zeros((n, k), np.int32)
    valid = np.zeros((n, k), bool)
    for i, seq in enumerate(toks_out):
        m = min(len(seq), k)
        tokens[i, k - m:] = seq[-m:]
        valid[i, k - m:] = True
    return {"tokens": tokens, "labels": np.asarray(labels_out, np.int32),
            "valid": valid}


def batches(examples: Dict[str, np.ndarray], batch_size: int, epochs: int,
            seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Shuffled fixed-shape batches; loss mask = last position only."""
    n, k = examples["tokens"].shape
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            idx = order[s:s + batch_size]
            toks = examples["tokens"][idx]
            lab = np.zeros((batch_size, k), np.int32)
            lab[:, -1] = examples["labels"][idx]
            lmask = np.zeros((batch_size, k), bool)
            lmask[:, -1] = True
            # ``valid`` = token validity (attention/SSM mask);
            # ``loss_mask`` = predict-next-item on the last position only.
            yield {"tokens": toks, "labels": lab,
                   "valid": examples["valid"][idx], "loss_mask": lmask}


def serve_tokens_consistent(batch_feats, recent_feats, n_items: int,
                            feature_len: int):
    """Serving-path input construction for the consistent variant:
    ``[batch…, SEP, recent…]`` — mirrors build_examples(cutoff="fresh").

    batch_feats / recent_feats: (items, ts, valid) padded arrays.
    Returns (tokens (B,K), valid (B,K)) right-aligned.
    """
    bi, _, bv = batch_feats
    ri, _, rv = recent_feats
    b = bi.shape[0]
    k = feature_len
    sep = sep_token(n_items)
    tokens = np.zeros((b, k), np.int32)
    vout = np.zeros((b, k), bool)
    for r in range(b):
        rec = [int(i) + 1 for i, v in zip(ri[r], rv[r]) if v]
        head_budget = k - 1 - len(rec)
        head = [int(i) + 1 for i, v in zip(bi[r], bv[r]) if v][-head_budget:]
        seq = head + [sep] + rec
        m = min(len(seq), k)
        tokens[r, k - m:] = seq[-m:]
        vout[r, k - m:] = True
    return tokens, vout
