"""Mechanistic long-form streaming user simulator (DESIGN.md §7.1).

No public Tubi logs exist, so the paper's A/B result is reproduced against a
generative user model built to contain exactly the mechanisms the paper's
claims depend on:

* **Intra-day intent drift** — each user has a stable long-term genre
  preference (dirichlet) plus a *session intent* (single active genre) that
  switches between sessions with probability ``p_switch``. A batch feature
  snapshot from last midnight cannot see today's intent switches; the
  real-time buffer can — this is the freshness gap the paper closes.
* **Organic discovery** — some watches happen off-slate (search / browse);
  they reveal intent to the real-time service even when slates are bad,
  which is what makes injection informative *within* a session.
* **Feedback loop** — training logs are generated under the then-deployed
  recommender, so a next-generation model partially fits the previous
  model's slate distribution. This is the mechanism the paper invokes to
  explain the consistent-features variant's null result (§IV).
* **Series binge chains** — long-form catalogs are dominated by episodic
  series: after watching episode e the user auto-continues to e+1 with
  probability ``p_binge`` via the Continue-Watching row (an ORGANIC,
  unattributed watch), and never picks continuations or mid-series entry
  points from the generic discovery slates. Intra-day logs are therefore
  saturated with mechanical e→e+1 transitions; a model trained WITH fresh
  recent-watch features (the paper's consistent variant) learns mostly to
  predict continuations — watches that happen anyway and earn a discovery
  slate nothing. This is the concrete form of the paper's hypothesis that
  such training "fits previous model recommendation / what the user would
  watch anyway instead of learning what the user really likes".

Engagement metric = slate CTR (attributed watches / impressions), the
closest observable analogue of the paper's "key user engagement metrics".

Everything is seeded numpy on the host; model scoring is batched into jit'd
calls by the pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

DAY = 86400


@dataclasses.dataclass(frozen=True)
class WorldConfig:
    n_items: int = 5000
    n_genres: int = 8
    n_users: int = 1500
    seed: int = 0
    # session structure
    sessions_per_day: float = 2.5       # poisson mean
    rounds_per_session: int = 3         # slate impressions per session
    slate_size: int = 10
    # behaviour
    p_switch: float = 0.55              # intent switch prob between sessions
    p_organic: float = 0.25             # off-slate (search) watch per round
    affinity_long: float = 1.0          # weight of long-term preference
    affinity_intent: float = 3.0        # weight of session intent (drift!)
    affinity_pop: float = 0.3
    # long-form is watch-once: a large utility penalty for items the user
    # has already watched (without it, arms that exclude just-watched items
    # from slates — any fresh-feature arm — are unfairly punished, since
    # re-watches are free CTR for the stale arm).
    rewatch_penalty: float = 6.0
    choice_temp: float = 1.0
    # positional trust bias (regime B of §Paper-claims): conditional on
    # engaging with a slate, users satisfice from the top slots rather than
    # optimizing affinity. This makes the *deployed policy's ranking* a
    # strong label signal in intra-day logs — the paper's hypothesized
    # mechanism for the consistent variant's null ("training fits previous
    # model recommendation instead of learning what user really like").
    # 0.0 = pure affinity choice (regime A).
    trust_bias: float = 0.0
    # slate skipped if nothing beats this. Calibrated so a popularity policy
    # lands at CTR≈0.28 and a true-affinity oracle at ≈0.49 — the headroom
    # in which slate quality (and hence freshness) is measurable.
    skip_utility: float = 5.0
    # item space
    zipf_a: float = 1.1
    genre_concentration: float = 0.2    # dirichlet alpha for item genre mix
    # episodic structure (long-form): fraction of the catalog arranged in
    # series of ``series_len`` consecutive item ids; the rest are movies.
    series_frac: float = 0.6
    series_len: int = 6
    p_binge: float = 0.55               # continue-to-next-episode prob
    # users don't start a series mid-season from a discovery slate, and
    # they take continuations from the Continue-Watching row, not slates —
    # recommending either wastes the slate slot.
    midseries_penalty: float = 6.0


@dataclasses.dataclass
class Event:
    user: int
    item: int
    ts: int
    attributed: bool  # True if the watch came from a served slate


class World:
    """Static item/user space + per-user latent intent state."""

    def __init__(self, cfg: WorldConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        g = cfg.n_genres
        # episodic series layout: items [0, n_series*series_len) are
        # episodes (consecutive ids within a series share its genre)
        self.series_len = cfg.series_len
        self.n_series = int(cfg.n_items * cfg.series_frac / cfg.series_len)
        self.n_episode_items = self.n_series * cfg.series_len
        # items: sparse genre mixtures with one dominant genre
        primary = rng.randint(0, g, cfg.n_items)
        series_genre = rng.randint(0, g, self.n_series)
        for s_id in range(self.n_series):
            lo = s_id * cfg.series_len
            primary[lo:lo + cfg.series_len] = series_genre[s_id]
        mix = rng.dirichlet([cfg.genre_concentration] * g, cfg.n_items)
        boost = np.zeros((cfg.n_items, g))
        boost[np.arange(cfg.n_items), primary] = 1.0
        self.item_genre = 0.35 * mix + 0.65 * boost  # (V, G)
        self.item_primary = primary
        ranks = rng.permutation(cfg.n_items) + 1
        pop = 1.0 / ranks ** cfg.zipf_a
        self.popularity = pop / pop.sum()  # (V,)
        # users: long-term genre preference
        self.user_long = rng.dirichlet([0.5] * g, cfg.n_users)  # (U, G)
        # mutable per-user session intent (genre index)
        self.intent = np.array([
            rng.choice(g, p=self.user_long[u]) for u in range(cfg.n_users)])
        # watch-once memory (long-form): items already seen per user
        self.watched = [set() for _ in range(cfg.n_users)]
        # pending next-episode per user (the Continue-Watching row)
        self.continuations = [set() for _ in range(cfg.n_users)]

    # ------------------------------------------------------------------
    def affinity(self, user: int, items: np.ndarray) -> np.ndarray:
        """Current true affinity of `user` for `items` (higher = better)."""
        c = self.cfg
        ig = self.item_genre[items]  # (n, G)
        long_term = ig @ self.user_long[user]
        intent = ig[:, self.intent[user]]
        pop = np.log(self.popularity[items] * c.n_items + 1e-9)
        aff = (c.affinity_long * long_term + c.affinity_intent * intent +
               c.affinity_pop * pop)
        if c.rewatch_penalty and self.watched[user]:
            seen = np.fromiter((i in self.watched[user] for i in items),
                               bool, len(items))
            aff = aff - c.rewatch_penalty * seen
        if c.midseries_penalty:
            # continuations are taken from the CW row, never from discovery
            # slates; mid-season episodes are not entry points. Both waste
            # a discovery-slate slot.
            dead = np.fromiter(
                (self.is_midseries_entry(int(i), user)
                 or int(i) in self.continuations[user] for i in items),
                bool, len(items))
            aff = aff - c.midseries_penalty * dead
        return aff

    def record_watch(self, user: int, item: int) -> None:
        item = int(item)
        self.watched[user].add(item)
        self.continuations[user].discard(item)
        nxt = self.next_episode(item)
        if nxt is not None and nxt not in self.watched[user]:
            self.continuations[user].add(nxt)

    def next_episode(self, item: int):
        if item >= self.n_episode_items:
            return None  # a movie
        if (item + 1) % self.series_len == 0:
            return None  # season finale
        return item + 1

    def is_midseries_entry(self, item: int, user: int) -> bool:
        """Episode >1 that is NOT this user's pending continuation."""
        if item >= self.n_episode_items or item % self.series_len == 0:
            return False
        return item not in self.continuations[user]

    def maybe_switch_intent(self, user: int, rng: np.random.RandomState):
        if rng.rand() < self.cfg.p_switch:
            self.intent[user] = rng.choice(
                self.cfg.n_genres, p=self.user_long[user])

    def organic_item(self, user: int, rng: np.random.RandomState) -> int:
        """A search/browse watch aligned with the user's current intent."""
        genre_w = (self.item_genre[:, self.intent[user]] * self.popularity
                   ).copy()
        if self.watched[user]:
            genre_w[list(self.watched[user])] *= 1e-6  # watch-once
        # search lands on entry points (ep 1 / movies), not mid-season
        if self.n_episode_items:
            ep_idx = np.arange(self.n_episode_items) % self.series_len
            genre_w[:self.n_episode_items][ep_idx > 0] *= 1e-6
        genre_w = genre_w / genre_w.sum()
        return int(rng.choice(self.cfg.n_items, p=genre_w))

    def binge_chain(self, user: int, item: int, ts: int,
                    rng: np.random.RandomState):
        """Continue-Watching auto-continuation after a watch: a chain of
        organic next-episode events (the platform's CW row, not a slate)."""
        out = []
        cur = item
        while True:
            nxt = self.next_episode(int(cur))
            if nxt is None or nxt in self.watched[user]:
                break
            if rng.rand() >= self.cfg.p_binge:
                break
            ts += 600
            out.append((nxt, ts))
            self.record_watch(user, nxt)
            cur = nxt
        return out

    def choose_from_slate(self, user: int, slate: np.ndarray,
                          rng: np.random.RandomState) -> Optional[int]:
        """Multinomial choice over slate ∪ {skip}; returns item or None.

        The skip-vs-engage margin is always affinity-driven (users bail on
        rows that miss their mood — slate QUALITY moves CTR); with
        ``trust_bias`` > 0 the conditional WHICH-item choice is tilted
        toward the top positions (satisficing), transferring the deployed
        ranker's ordering into the logs.
        """
        c = self.cfg
        aff = self.affinity(user, slate)
        util = aff / c.choice_temp
        if c.trust_bias:
            n = len(slate)
            pos_bonus = c.trust_bias * (n - 1 - np.arange(n)) / max(n - 1, 1)
            util = util + pos_bonus
        util = np.concatenate([util, [c.skip_utility / c.choice_temp]])
        util -= util.max()
        p = np.exp(util)
        p /= p.sum()
        pick = rng.choice(len(slate) + 1, p=p)
        return None if pick == len(slate) else int(slate[pick])


# ----------------------------------------------------------------------
# Session schedule + day simulation
# ----------------------------------------------------------------------

def session_schedule(cfg: WorldConfig, day: int, rng: np.random.RandomState,
                     ) -> List[Tuple[int, int]]:
    """[(ts, user), ...] sorted by ts, for one day. Daytime-weighted.

    Columnar: one poisson draw for all users, one normal/randint draw for
    all sessions, one lexsort — no per-user Python loop.
    """
    base = day * DAY
    counts = rng.poisson(cfg.sessions_per_day, cfg.n_users)
    users = np.repeat(np.arange(cfg.n_users), counts)
    n = len(users)
    hours = np.clip(rng.normal(15, 5, n), 0.0, 23.9)  # afternoon peak
    tss = base + (hours * 3600).astype(np.int64) + rng.randint(0, 3600, n)
    order = np.lexsort((users, tss))
    return list(zip(tss[order].tolist(), users[order].tolist()))


def simulate_day(world: World, day: int, serve_fn: Callable,
                 observe_fn: Callable, *, seed: int,
                 serve_batch: int = 256) -> Tuple[List[Event], Dict[str, float]]:
    """Run one day of traffic.

    serve_fn(users (n,), ts (n,)) -> slates (n, slate_size) — the platform
    under test (an arm of the A/B). observe_fn(event) — feeds the platform's
    real-time service. Sessions at the same timestep are micro-batched into
    one serve call (realistic request batching, and fast under jit).

    Choice RNG is keyed by (user, session, round) so paired arms face
    identical user randomness — common-random-numbers variance reduction.
    """
    cfg = world.cfg
    sched_rng = np.random.RandomState(seed * 7919 + day)
    schedule = session_schedule(cfg, day, sched_rng)
    events: List[Event] = []
    impressions = 0
    slate_watches = 0
    sessions_with_click = 0
    user_impressions = np.zeros(cfg.n_users, np.int64)
    user_watches = np.zeros(cfg.n_users, np.int64)

    # group sessions into serving batches while preserving time order
    for i in range(0, len(schedule), serve_batch):
        group = schedule[i:i + serve_batch]
        for r in range(cfg.rounds_per_session):
            users = np.array([u for _, u in group])
            tss = np.array([ts + 60 * r for ts, _ in group])
            slates = serve_fn(users, tss)  # (n, slate)
            for (ts0, u), ts, slate in zip(group, tss, slates):
                if r == 0:
                    # keyed by session start: independent draw per session,
                    # identical across paired A/B arms (common random nums).
                    world.maybe_switch_intent(
                        u, np.random.RandomState((seed, day, u, ts0 % DAY, 17)))
                crng = np.random.RandomState((seed, day, u, ts0 % DAY, r))
                impressions += 1
                user_impressions[u] += 1
                pick = world.choose_from_slate(u, np.asarray(slate), crng)
                if pick is not None:
                    ev = Event(u, pick, int(ts), True)
                    events.append(ev)
                    observe_fn(ev)
                    world.record_watch(u, pick)
                    slate_watches += 1
                    user_watches[u] += 1
                    for it2, ts2 in world.binge_chain(u, pick, int(ts), crng):
                        ev2 = Event(u, it2, ts2, False)  # CW row, organic
                        events.append(ev2)
                        observe_fn(ev2)
                if crng.rand() < cfg.p_organic:
                    item = world.organic_item(u, crng)
                    ev = Event(u, item, int(ts) + 30, False)
                    events.append(ev)
                    observe_fn(ev)
                    world.record_watch(u, item)
                    for it2, ts2 in world.binge_chain(u, item, int(ts) + 30,
                                                      crng):
                        ev2 = Event(u, it2, ts2, False)
                        events.append(ev2)
                        observe_fn(ev2)
        # sessions with >=1 attributed watch
    # recompute session success from events
    by_session = {}
    for ev in events:
        if ev.attributed:
            by_session.setdefault((ev.user, ev.ts // 3600), 0)
            by_session[(ev.user, ev.ts // 3600)] += 1
    sessions_with_click = len(by_session)

    metrics = {
        "impressions": impressions,
        "slate_watches": slate_watches,
        "ctr": slate_watches / max(impressions, 1),
        "organic_watches": sum(1 for e in events if not e.attributed),
        "sessions_with_click": sessions_with_click,
        "user_impressions": user_impressions,
        "user_watches": user_watches,
    }
    return events, metrics


# ----------------------------------------------------------------------
# Bootstrap (pre-model) logging policy
# ----------------------------------------------------------------------

def bootstrap_serve_fn(world: World, seed: int) -> Callable:
    """Popularity-proportional slates with exploration — generation-0 policy
    that produces the initial training logs."""
    cfg = world.cfg
    rng = np.random.RandomState(seed)

    def serve(users, tss):
        n = len(users)
        slates = np.empty((n, cfg.slate_size), np.int64)
        for j in range(n):
            slates[j] = rng.choice(
                cfg.n_items, cfg.slate_size, replace=False, p=world.popularity)
        return slates

    return serve


def events_to_arrays(events: List[Event]) -> Dict[str, np.ndarray]:
    """Event list -> columnar arrays, the feature plane's native format
    (directly consumable by ``EventLog.extend`` / the store ``extend``s)."""
    n = len(events)
    return {
        "user": np.fromiter((e.user for e in events), np.int32, n),
        "item": np.fromiter((e.item for e in events), np.int32, n),
        "ts": np.fromiter((e.ts for e in events), np.int64, n),
        "attributed": np.fromiter((e.attributed for e in events), bool, n),
    }
