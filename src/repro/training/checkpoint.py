"""Msgpack-based checkpointing (no orbax in this environment).

Stores the pytree structure as a nested msgpack document with ndarray leaves
encoded as (dtype, shape, raw bytes). Atomic via write-to-temp + rename.
bfloat16 round-trips through a uint16 view (numpy has no native bf16).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _encode_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if str(arr.dtype) == _BF16:
        u16 = arr.view(np.uint16)
        return {"__nd__": True, "dtype": _BF16, "shape": list(u16.shape),
                "data": u16.tobytes()}
    return {"__nd__": True, "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_leaf(d: dict):
    if d["dtype"] == _BF16:
        u16 = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(u16).view(jnp.bfloat16)
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def _to_doc(tree):
    if isinstance(tree, dict):
        return {"__map__": {k: _to_doc(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_to_doc(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    if tree is None:
        return {"__none__": True}
    if isinstance(tree, (int, float, str, bool)):
        return {"__py__": tree}
    return _encode_leaf(tree)


def _from_doc(doc):
    if "__map__" in doc:
        return {k: _from_doc(v) for k, v in doc["__map__"].items()}
    if "__seq__" in doc:
        seq = [_from_doc(v) for v in doc["__seq__"]]
        return tuple(seq) if doc.get("__tuple__") else seq
    if "__none__" in doc:
        return None
    if "__py__" in doc:
        return doc["__py__"]
    return _decode_leaf(doc)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: dict | None = None) -> None:
    doc = {"version": 1, "step": step, "metadata": metadata or {},
           "tree": _to_doc(jax.device_get(tree))}
    payload = msgpack.packb(doc, use_bin_type=True)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str):
    with open(path, "rb") as f:
        doc = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    assert doc["version"] == 1
    return _from_doc(doc["tree"]), doc["step"], doc["metadata"]
