"""Online incremental trainer + hot-swappable delta weight patches.

Closes the model-freshness half of the paper's comparison: the repro's
serving stack (PRs 1-8) keeps *features* fresh via inference-time
injection, but the weights themselves still came from a hypothetical
daily retrain. This module is the "Near-Zero-Overhead Freshness via
Inference-Side Model Updates" alternative — a continuous trainer that

* consumes appended events from a lock-free frozen ``EventLog.view()``
  (``LogView.events_since``: the trainer remembers the log position it
  has trained through and each capture hands it just the new suffix);
* builds next-item-prediction mini-batches from the recent window of
  the users those events touched (``LogView.materialize`` — the same
  right-aligned read the feature plane uses) and steps the existing
  ``make_train_step``/``adamw_update`` machinery;
* emits versioned :class:`WeightPatch` objects — *sparse per-leaf*
  updates (full new values for the trainable leaf subset, keyed by
  ``jax.tree_util.keystr`` path) with a ``base_version`` guard so a
  patch can never be applied out of order, msgpack-serializable via the
  checkpoint codec.

The serving side (``Gateway.install_patch`` / ``ServingEngine.
apply_patch``) installs a patch atomically between panes in O(patch)
time; cached prefill states keyed to the old model version are never
served again (the cache generation grows a model-version axis that
composes with the snapshot-rekey machinery).

Patches carry **full new leaf values**, not arithmetic diffs: adding a
float delta on the serving side would round differently than the
trainer's own accumulate, and the hot-swap contract is *bitwise*
equivalence with a cold start from the patched weights. Sparsity comes
from the trainable-leaf filter (``OnlineTrainerConfig.trainable``), the
knob that makes a patch a delta rather than a checkpoint.

Threading mirrors ``BackgroundSnapshotBuilder``: an optional daemon
worker steps the trainer off-thread and enqueues patches; the serving
thread drains them via ``poll_patch()`` (O(1), sticky worker errors
re-raised there). The synchronous ``step()``/``make_patch()`` pair is
the deterministic path tests and benchmarks drive directly.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.event_log import EventLog
from repro.core.pipeline import items_to_tokens
from repro.training.checkpoint import _from_doc, _to_doc
from repro.training.optimizer import init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step

DAY = 86400


def flatten_with_keystr(tree) -> List[Tuple[str, Any]]:
    """``(keystr path, leaf)`` pairs — the shared leaf-naming convention
    between patch emission (here) and patch application
    (``ServingEngine.apply_patch``)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


# ----------------------------------------------------------------------
# WeightPatch — the wire format
# ----------------------------------------------------------------------

_PATCH_CODEC_VERSION = 1


@dataclasses.dataclass(frozen=True)
class WeightPatch:
    """A versioned sparse weight update.

    ``leaves`` maps ``keystr`` leaf paths to full replacement values.
    ``base_version`` is the model version the patch applies on top of;
    installing onto any other version must be rejected (the guard that
    keeps a reordered/dropped patch stream from silently corrupting the
    served weights). ``version`` (== base_version + 1 in the stream the
    trainer emits) is the model version the install produces.
    """
    version: int
    base_version: int
    step: int                       # trainer step count at emission
    leaves: Dict[str, Any]          # keystr path -> ndarray
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    @property
    def n_params(self) -> int:
        return int(sum(np.asarray(v).size for v in self.leaves.values()))

    def to_bytes(self) -> bytes:
        doc = {"codec": _PATCH_CODEC_VERSION, "version": self.version,
               "base_version": self.base_version, "step": self.step,
               "metadata": self.metadata,
               "leaves": _to_doc(jax.device_get(dict(self.leaves)))}
        return msgpack.packb(doc, use_bin_type=True)

    @staticmethod
    def from_bytes(data: bytes) -> "WeightPatch":
        doc = msgpack.unpackb(data, raw=False, strict_map_key=False)
        if doc.get("codec") != _PATCH_CODEC_VERSION:
            raise ValueError(
                f"unsupported patch codec {doc.get('codec')!r}")
        return WeightPatch(
            version=int(doc["version"]),
            base_version=int(doc["base_version"]),
            step=int(doc["step"]),
            leaves=_from_doc(doc["leaves"]),
            metadata=doc.get("metadata", {}))


# ----------------------------------------------------------------------
# OnlineTrainer
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnlineTrainerConfig:
    batch_size: int = 8
    seq_len: int = 32               # tokens per example (window read k-1)
    window: int = 30 * DAY          # event-time lookback per example
    min_new_events: int = 1         # suffix size required to run a step
    steps_per_patch: int = 1        # train steps bundled into one patch
    # keystr substrings selecting the trainable (and therefore patched)
    # leaf subset; None trains and ships every leaf. This is the knob
    # that makes a patch a *delta* — e.g. ("head", "embed") ships the
    # item-embedding/head slice that online signals actually move.
    trainable: Optional[Tuple[str, ...]] = None
    # background worker cadence (seconds between step attempts)
    interval_s: float = 0.05


class OnlineTrainer:
    """Incremental trainer over a live :class:`EventLog`.

    Synchronous API (deterministic; tests/benchmarks):
        ``step()`` consumes the appended-event suffix and runs one train
        step (returns metrics, or ``None`` if too little new data);
        ``make_patch()`` emits the next :class:`WeightPatch`.

    Background API (production shape): ``start()`` spawns a daemon
    worker that steps continuously and enqueues a patch every
    ``steps_per_patch`` successful steps; the serving thread drains via
    ``poll_patch()``. Worker exceptions are sticky and re-raised from
    ``poll_patch()``/``stop()``.
    """

    def __init__(self, model_cfg: ModelConfig, params, log: EventLog, *,
                 cfg: OnlineTrainerConfig = OnlineTrainerConfig(),
                 train_cfg: Optional[TrainConfig] = None,
                 base_version: int = 0,
                 step_hook: Optional[Callable[[], None]] = None):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.log = log
        if train_cfg is None:
            # the emitted weights must be dtype-identical to what the
            # serving engine holds — bitwise swap equivalence starts here
            leaf_dtype = jax.tree.leaves(params)[0].dtype
            train_cfg = TrainConfig(param_dtype=leaf_dtype)
        self.train_cfg = train_cfg
        self._step_fn = jax.jit(make_train_step(model_cfg, train_cfg))
        self._params = params
        self._opt = init_opt_state(params)
        self._version = int(base_version)
        self._cursor = 0            # log position trained through
        self.missed_events = 0      # consumed-range events the tiered
        #                             log no longer held (see step())
        self._rr = 0                # round-robin user cursor
        self._steps = 0
        self._steps_at_patch = 0
        self.history: List[Dict[str, float]] = []
        self.step_time_s = 0.0
        if cfg.trainable is None:
            self._trainset = None
        else:
            self._trainset = {
                k for k, _ in flatten_with_keystr(params)
                if any(sub in k for sub in cfg.trainable)}
            if not self._trainset:
                raise ValueError(
                    f"trainable filter {cfg.trainable!r} matches no "
                    f"param leaf")
        # background worker plumbing
        self._step_hook = step_hook
        self._patch_q: "collections.deque[WeightPatch]" = \
            collections.deque()
        self._qlock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    @property
    def params(self):
        """Current full weights (what a cold engine started 'from the
        patched weights' must be constructed with)."""
        return self._params

    @property
    def version(self) -> int:
        """Model version of the *last emitted* patch (== base_version
        until the first ``make_patch``)."""
        return self._version

    @property
    def steps(self) -> int:
        return self._steps

    @property
    def cursor(self) -> int:
        return self._cursor

    # ------------------------------------------------------------------
    # synchronous path
    # ------------------------------------------------------------------
    def step(self) -> Optional[Dict[str, float]]:
        """Consume the appended-event suffix and run one train step.

        Returns the step metrics (floats), or ``None`` when fewer than
        ``min_new_events`` events arrived since the last consumed
        position (the cursor does not move) or the batch had no
        scorable transition (cursor moves — the data was consumed, it
        was just untrainable, e.g. all touched users have single-event
        histories)."""
        t0 = time.perf_counter()
        view = self.log.view()
        # gate on appended POSITIONS, not retained rows: identical
        # untiered, but a tiered log may have lost part of the suffix
        # (see missed_events below) and the hole must still be consumed
        if view.n_events - self._cursor < max(self.cfg.min_new_events, 1):
            return None
        users, _items, ts = view.events_since(self._cursor)
        # Tiered-log accounting: positions in [cursor, n_events) the
        # composite view no longer holds were dropped late, trimmed by
        # window compaction, or evicted past retention before this step
        # consumed them. A gateway-driven compaction pins positions >=
        # the trainer cursor (keep_from), so this stays 0 there; it
        # counts real losses when compaction runs uncoordinated.
        self.missed_events += \
            int(view.n_events - self._cursor) - len(users)
        if len(users) == 0:
            self._cursor = view.n_events
            return None
        batch = self._build_batch(view, users, ts)
        self._cursor = view.n_events
        if batch is None:
            return None
        params, opt, metrics = self._step_fn(self._params, self._opt,
                                             batch)
        if self._trainset is not None:
            params = self._merge_frozen(params, self._params)
            opt = opt._replace(
                master=self._merge_frozen(opt.master, self._opt.master),
                m=self._merge_frozen(opt.m, self._opt.m),
                v=self._merge_frozen(opt.v, self._opt.v))
        self._params, self._opt = params, opt
        self._steps += 1
        out = {k: float(v) for k, v in metrics.items()}
        self.history.append(out)
        self.step_time_s += time.perf_counter() - t0
        return out

    def _build_batch(self, view, users: np.ndarray, ts: np.ndarray,
                     ) -> Optional[Dict[str, np.ndarray]]:
        """Next-item-prediction batch from the recent window of the
        users the new events touched. Deterministic: unique users in
        sorted order, rotated by a round-robin cursor so repeated steps
        over a hot set cycle through it."""
        c = self.cfg
        uniq = np.unique(users)
        if len(uniq) > c.batch_size:
            r = self._rr % len(uniq)
            uniq = np.concatenate([uniq[r:], uniq[:r]])[:c.batch_size]
            self._rr += c.batch_size
        hi = int(ts.max()) + 1
        items, _t, valid = view.materialize(
            uniq, hi - c.window, hi, c.seq_len + 1)
        # train in the SERVING token space (item+1, pad->0): the weights
        # this trainer ships are scored against injected histories that
        # went through the same items_to_tokens mapping
        toks = items_to_tokens(items, valid)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        # position j scores label j+1 given the prefix through j; rows
        # are right-aligned so both slots valid <=> token slot valid
        mask = (valid[:, :-1] * valid[:, 1:]).astype(np.int32)
        if int(mask.sum()) == 0:
            return None
        return {"tokens": tokens, "labels": labels,
                "valid": valid[:, :-1].astype(np.int32),
                "loss_mask": mask}

    def _merge_frozen(self, new_tree, old_tree):
        """Restore the old leaf objects at every non-trainable path.

        Grad-masking alone is not enough — AdamW's decoupled weight
        decay moves matrix leaves even at zero gradient — so frozen
        leaves are frozen by construction: the post-step tree simply
        keeps the pre-step objects."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(new_tree)
        old = jax.tree.leaves(old_tree)
        leaves = [n if jax.tree_util.keystr(p) in self._trainset else o
                  for (p, n), o in zip(flat, old)]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def make_patch(self, metadata: Optional[dict] = None) -> WeightPatch:
        """Emit the next versioned patch: full current values of every
        trainable leaf, guarded by the current base version."""
        leaves = {k: np.asarray(jax.device_get(v))
                  for k, v in flatten_with_keystr(self._params)
                  if self._trainset is None or k in self._trainset}
        patch = WeightPatch(
            version=self._version + 1, base_version=self._version,
            step=self._steps, leaves=leaves,
            metadata=dict(metadata or {}, steps=self._steps))
        self._version += 1
        self._steps_at_patch = self._steps
        return patch

    # ------------------------------------------------------------------
    # background worker
    # ------------------------------------------------------------------
    def start(self) -> "OnlineTrainer":
        """Spawn the daemon worker. Idempotent while running."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._work, name="online-trainer", daemon=True)
        self._thread.start()
        return self

    def _work(self) -> None:
        try:
            while not self._stop.is_set():
                stepped = self.step() is not None
                if stepped and (self._steps - self._steps_at_patch
                                >= self.cfg.steps_per_patch):
                    patch = self.make_patch()
                    with self._qlock:
                        self._patch_q.append(patch)
                if self._step_hook is not None:
                    self._step_hook()
                if not stepped:
                    self._stop.wait(self.cfg.interval_s)
        except BaseException as e:    # sticky: re-raised from poll_patch
            self._error = e

    def poll_patch(self) -> Optional[WeightPatch]:
        """Next pending patch from the worker, or ``None``. O(1); never
        blocks. Re-raises a worker exception, stickily."""
        if self._error is not None:
            raise RuntimeError("online trainer worker failed") \
                from self._error
        with self._qlock:
            return self._patch_q.popleft() if self._patch_q else None

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Signal the worker to exit and join it."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
        if self._error is not None:
            raise RuntimeError("online trainer worker failed") \
                from self._error
