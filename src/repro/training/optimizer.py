"""Pure-JAX AdamW with fp32 master weights, grad clipping and schedules.

Optimizer state is a pytree shaped like the params (sharded identically by
the dry-run's sharding rules — fully-sharded optimizer à la ZeRO comes free
from GSPMD since master/m/v inherit the param PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any  # fp32 copy of params
    m: Any
    v: Any


def lr_schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _is_matrix(path) -> bool:
    """Weight decay applies to matrices only (not norms/biases/A_log/D)."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("scale", "norm_scale", "A_log", "D", "dt_bias",
                        "bq", "bk", "bv", "conv_bias_x", "conv_bias_B",
                        "conv_bias_C")


def adamw_update(cfg: AdamWConfig, grads, opt: OptState, param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, g, p32, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(path):
            delta = delta + cfg.weight_decay * p32
        return p32 - lr * delta, m, v

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    paths = [p for p, _ in flat]
    treedef = jax.tree.structure(grads)
    g_l = [g for _, g in flat]
    p_l = jax.tree.leaves(opt.master)
    m_l = jax.tree.leaves(opt.m)
    v_l = jax.tree.leaves(opt.v)
    new = [upd(path, g, p, m, v) for path, g, p, m, v
           in zip(paths, g_l, p_l, m_l, v_l)]
    master = jax.tree.unflatten(treedef, [n[0] for n in new])
    m_t = jax.tree.unflatten(treedef, [n[1] for n in new])
    v_t = jax.tree.unflatten(treedef, [n[2] for n in new])
    params = jax.tree.map(lambda p: p.astype(param_dtype), master)
    return params, OptState(step, master, m_t, v_t), {
        "grad_norm": gnorm, "lr": lr}
