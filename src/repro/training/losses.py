"""Next-token / next-item cross-entropy with padded-vocab + validity masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, valid=None):
    """logits (B,S,Vp) fp32 (padded vocab already masked to -inf);
    labels (B,S) int32; valid (B,S) bool. Returns (mean loss, accuracy)."""
    # one-hot contraction instead of take_along_axis: under GSPMD it
    # partitions cleanly over vocab-sharded logits (a gather on the sharded
    # dim would force an all-gather of the full logits tensor).
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    lab = jnp.sum(logits * onehot, axis=-1)
    nll = logz - lab
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if valid is None:
        return nll.mean(), hit.mean()
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)
    return (nll * w).sum() / denom, (hit * w).sum() / denom
