from repro.training.checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
from repro.training.losses import cross_entropy  # noqa: F401
from repro.training.online import (  # noqa: F401
    OnlineTrainer, OnlineTrainerConfig, WeightPatch)
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig, OptState, adamw_update, init_opt_state, lr_schedule)
from repro.training.train_loop import (  # noqa: F401
    TrainConfig, make_loss_fn, make_train_step, train)
