"""Train-step factory: remat'd forward, microbatch gradient accumulation,
AdamW — the function the multi-pod dry-run lowers for ``train_4k``."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.training.losses import cross_entropy
from repro.training.optimizer import AdamWConfig, OptState, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    # global_batch is split into `microbatches` sequential accumulation steps
    microbatches: int = 1
    remat: bool = True
    q_chunk: int = 512
    param_dtype: Any = jnp.bfloat16
    # distribution: NamedShardings applied inside the step (layer-boundary
    # activations and the logits tensor) — None on a single device.
    act_sharding: Any = None
    logits_sharding: Any = None
    head_sharding: Any = None
    embed_mesh: Any = None
    head_pad_to: int = 0
    attn_sharding: Any = None
    moe_sharding: Any = None


def make_loss_fn(model_cfg: ModelConfig, train_cfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        logits, aux = forward(
            params, model_cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            valid=batch.get("valid"), remat=train_cfg.remat,
            q_chunk=train_cfg.q_chunk,
            act_sharding=train_cfg.act_sharding,
            logits_sharding=train_cfg.logits_sharding,
            head_sharding=train_cfg.head_sharding,
            embed_mesh=train_cfg.embed_mesh,
            head_pad_to=train_cfg.head_pad_to,
            attn_sharding=train_cfg.attn_sharding,
            moe_sharding=train_cfg.moe_sharding)
        loss, acc = cross_entropy(logits, batch["labels"],
                                  batch.get("loss_mask", batch.get("valid")))
        return loss + aux, {"loss": loss, "acc": acc, "moe_aux": aux}
    return loss_fn


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig) -> Callable:
    loss_fn = make_loss_fn(model_cfg, train_cfg)
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def train_step(params, opt: OptState, batch):
        nm = train_cfg.microbatches
        if nm == 1:
            grads, metrics = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def split(x):
                return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])
            micro = jax.tree.map(split, batch)
            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mz = {"loss": jnp.zeros((), jnp.float32),
                  "acc": jnp.zeros((), jnp.float32),
                  "moe_aux": jnp.zeros((), jnp.float32)}

            def body(carry, mb):
                gacc, macc = carry
                g, m = grad_fn(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                macc = jax.tree.map(lambda a, b: a + b, macc, m)
                return (gacc, macc), None

            (grads, msum), _ = jax.lax.scan(body, (gz, mz), micro)
            grads = jax.tree.map(lambda g: g / nm, grads)
            metrics = jax.tree.map(lambda m: m / nm, msum)

        params, opt, stats = adamw_update(
            train_cfg.adamw, grads, opt, train_cfg.param_dtype)
        return params, opt, {**metrics, **stats}

    return train_step


def train(model_cfg: ModelConfig, train_cfg: TrainConfig, params,
          opt: OptState, batches, *, log_every: int = 20,
          log: Optional[Callable[[str], None]] = print) -> Dict[str, Any]:
    """Simple host loop over an iterable of batches. Returns final state."""
    step_fn = jax.jit(make_train_step(model_cfg, train_cfg))
    history = []
    for i, batch in enumerate(batches):
        params, opt, metrics = step_fn(params, opt, batch)
        if log and (i % log_every == 0):
            m = {k: float(v) for k, v in metrics.items()}
            log(f"step {i:5d} loss={m['loss']:.4f} acc={m['acc']:.4f} "
                f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}")
        history.append({k: float(v) for k, v in metrics.items()})
    return {"params": params, "opt": opt, "history": history}
