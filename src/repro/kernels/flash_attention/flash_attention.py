"""Pallas TPU flash-attention (prefill) kernel.

Blockwise online-softmax attention with explicit VMEM tiling:

* grid = (batch, q_heads, S/block_q, S/block_k); the K-block axis is the
  fastest (sequential) grid dimension, so the (m, l, acc) online-softmax
  state lives in VMEM scratch and persists across K steps.
* Q block (block_q, head_dim) stays resident; K/V blocks stream through.
* GQA is handled in the K/V index_map (query head h reads kv head
  h * n_kv // n_q) — repeated KV heads are never materialized.
* Causal and sliding-window masks are applied with block-level early-out:
  fully-masked K blocks skip the matmul entirely (``pl.when``).

Layouts are (batch, heads, seq, head_dim); block_q/block_k default to 128,
MXU-aligned, and head_dim (64/128 across assigned archs) is the minor dim.
VMEM working set per step ≈ (block_q + 2·block_k)·head_dim·2B +
block_q·block_k·4B + acc (block_q·head_dim·4B) ≈ 0.3 MB at 128/128/128 —
comfortably under the ~16 MB/core VMEM budget, leaving room for the
compiler's double buffering of the K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: causal => K block strictly after Q block is dead;
    # sliding window => K block entirely left of the window is dead.
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window > 0:
        live &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]  # (bq, hd)
        k = k_ref[0, 0]  # (bk, hd)
        v = v_ref[0, 0]  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale: float | None = None, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q (B, nq, S, hd); k/v (B, nkv, S, hd); returns (B, nq, S, hd).

    S must be divisible by block sizes (ops.py pads).
    """
    b, nq, s, hd = q.shape
    nkv = k.shape[1]
    assert nq % nkv == 0
    g = nq // nkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    if scale is None:
        scale = hd ** -0.5

    grid = (b, nq, s // block_q, s // block_k)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, h, qi, ki, g=g: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, h, qi, ki, g=g: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
