"""Jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, S, heads, hd) layout, transposes to the kernel's
(B, heads, S, hd), pads S up to the block size, and slices the pad off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.models.common import round_up


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,S,nq,hd); k/v (B,S,nkv,hd) -> (B,S,nq,hd)."""
    b, s, nq, hd = q.shape
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    sp = round_up(s, max(min(block_q, s), min(block_k, s)))
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        qt, kt, vt = jnp.pad(qt, pad), jnp.pad(kt, pad), jnp.pad(vt, pad)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return jnp.moveaxis(out[:, :, :s], 2, 1)
