"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q (B,nq,S,hd); k/v (B,nkv,S,hd) -> (B,nq,S,hd). Materializes (S,S)."""
    b, nq, s, hd = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    if scale is None:
        scale = hd ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(vv.dtype), vv)
