"""Pallas TPU decode-attention kernel (single query vs. a long KV cache).

serve_step attention is memory-bound: one query token must stream the whole
ring-buffer cache (32 k – 512 k entries) from HBM. The kernel is a
flash-decode: grid = (batch, q_heads, W/block_k) with the K-block axis
sequential, online-softmax state in VMEM scratch, one (1, hd) output write
at the last block. Slot validity (ring buffers that are not yet full) is
an additive f32 bias streamed alongside K.

Arithmetic intensity is O(1) FLOP/byte, so the roofline term this kernel
moves is HBM bytes: K/V blocks are read exactly once, in bf16, with no
(B, H, W) score materialization in HBM (the XLA path materializes scores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # (1, hd)
    k = k_ref[0, 0]  # (bk, hd)
    v = v_ref[0, 0]  # (bk, hd)
    bias = bias_ref[0]  # (bk,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (1, bk)
    s = s + bias[None, :]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_bhd(q, k, v, bias, *, scale: float | None = None,
                         block_k: int = 512, interpret: bool = False):
    """q (B, nq, 1, hd); k/v (B, nkv, W, hd); bias (B, W) f32 additive
    (0 = attendable, NEG_INF = masked). Returns (B, nq, 1, hd)."""
    b, nq, one, hd = q.shape
    nkv, w = k.shape[1], k.shape[2]
    g = nq // nkv
    block_k = min(block_k, w)
    assert w % block_k == 0
    if scale is None:
        scale = hd ** -0.5

    grid = (b, nq, w // block_k)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda bb, h, ki: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, h, ki, g=g: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bb, h, ki, g=g: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bb, h, ki: (bb, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda bb, h, ki: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
