"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, bias, *, scale: float | None = None):
    """q (B,nq,1,hd); k/v (B,nkv,W,hd); bias (B,W) -> (B,nq,1,hd)."""
    b, nq, _, hd = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    if scale is None:
        scale = hd ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
