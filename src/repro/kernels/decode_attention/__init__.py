from repro.kernels.decode_attention.ops import decode_attention, ring_bias  # noqa: F401
from repro.kernels.decode_attention.ref import decode_attention_ref  # noqa: F401
