"""Jit'd wrapper for decode attention: model layout + ring-validity bias."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    NEG_INF, decode_attention_bhd)


def ring_bias(pos: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Additive mask for a ring cache: slot i valid iff i <= pos or the ring
    has wrapped (pos >= capacity). pos (B,) int32 -> (B, capacity) f32."""
    idx = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    valid = (idx <= pos[:, None]) | (pos[:, None] >= capacity)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, block_k: int = 512,
                     interpret: bool = False):
    """q (B,1,nq,hd); k/v cache (B,W,nkv,hd); pos (B,) -> (B,1,nq,hd)."""
    b, one, nq, hd = q.shape
    w = k_cache.shape[1]
    qt = jnp.moveaxis(q, 1, 2)  # (B,nq,1,hd)
    kt = jnp.moveaxis(k_cache, 1, 2)  # (B,nkv,W,hd)
    vt = jnp.moveaxis(v_cache, 1, 2)
    bias = ring_bias(pos, w)
    out = decode_attention_bhd(qt, kt, vt, bias, block_k=block_k,
                               interpret=interpret)
    return jnp.moveaxis(out, 2, 1)
