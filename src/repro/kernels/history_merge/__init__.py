from repro.kernels.history_merge.ops import history_merge  # noqa: F401
from repro.kernels.history_merge.ref import (  # noqa: F401
    history_merge_python, history_merge_ref)
