"""Oracles for history_merge: a pure-jnp version (argsort-based) and a
plain-python version used as ground truth in hypothesis property tests."""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np


def history_merge_ref(batch_items, batch_ts, batch_valid,
                      rt_items, rt_ts, rt_valid, *, out_len: int):
    """jnp oracle, same contract as the kernel (vectorized via argsort)."""
    b, lb = batch_items.shape
    lr = rt_items.shape[1]
    n, k = lb + lr, out_len

    items = jnp.concatenate([batch_items, rt_items], axis=1)
    ts = jnp.concatenate([batch_ts, rt_ts], axis=1)
    valid = jnp.concatenate([batch_valid, rt_valid], axis=1) > 0
    is_rt = (jnp.arange(n) >= lb)[None, :].astype(jnp.int32)
    idx = jnp.arange(n)[None, :]

    ts_j, ts_i = ts[:, :, None], ts[:, None, :]
    rt_j, rt_i = is_rt[:, :, None], is_rt[:, None, :]
    ix_j, ix_i = idx[:, :, None], idx[:, None, :]
    fresher = (ts_j > ts_i) | ((ts_j == ts_i) & (
        ((rt_j > rt_i)) | ((rt_j == rt_i) & (ix_j > ix_i))))

    dup = jnp.any(valid[:, :, None] & (items[:, :, None] == items[:, None, :])
                  & fresher, axis=1) | ~valid
    alive = valid & ~dup
    rank = jnp.sum((alive[:, :, None] & fresher).astype(jnp.int32), axis=1)
    keep = alive & (rank < k)
    slot = k - 1 - rank

    out_i = jnp.zeros((b, k), jnp.int32)
    out_t = jnp.zeros((b, k), jnp.int32)
    out_v = jnp.zeros((b, k), jnp.int32)
    brow = jnp.arange(b)[:, None]
    tgt = jnp.where(keep, slot, k)  # k = discard bin
    out_i = jnp.concatenate([out_i, jnp.zeros((b, 1), jnp.int32)], 1
                            ).at[brow, tgt].set(items).at[:, k].set(0)[:, :k]
    out_t = jnp.concatenate([out_t, jnp.zeros((b, 1), jnp.int32)], 1
                            ).at[brow, tgt].set(ts).at[:, k].set(0)[:, :k]
    out_v = jnp.concatenate([out_v, jnp.zeros((b, 1), jnp.int32)], 1
                            ).at[brow, tgt].set(1).at[:, k].set(0)[:, :k]
    return out_i, out_t, out_v


def history_merge_python_padded(batch_items, batch_ts, batch_valid,
                                rt_items, rt_ts, rt_valid, *, out_len: int,
                                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pure-python reference with the *kernel's* padded-array contract.

    Same inputs/outputs as ``history_merge`` (all (B, L) int arrays in,
    three (B, out_len) int32 arrays out, right-aligned ascending time) but
    computed row-by-row through ``history_merge_python`` — no jnp, no
    vectorization tricks, so it is an independent ground truth for the
    differential sweep (pallas vs xla vs this)."""
    arrs = [np.asarray(a) for a in (batch_items, batch_ts, batch_valid,
                                    rt_items, rt_ts, rt_valid)]
    b = arrs[0].shape[0]
    k = out_len
    out_i = np.zeros((b, k), np.int32)
    out_t = np.zeros((b, k), np.int32)
    out_v = np.zeros((b, k), np.int32)
    for row in range(b):
        batch = [(int(i), int(t)) for i, t, v in
                 zip(arrs[0][row], arrs[1][row], arrs[2][row]) if v]
        rt = [(int(i), int(t)) for i, t, v in
              zip(arrs[3][row], arrs[4][row], arrs[5][row]) if v]
        merged = history_merge_python(batch, rt, k)
        for slot, (item, ts) in zip(range(k - len(merged), k), merged):
            out_i[row, slot] = item
            out_t[row, slot] = ts
            out_v[row, slot] = 1
    return out_i, out_t, out_v


def history_merge_python(batch: List[Tuple[int, int]], rt: List[Tuple[int, int]],
                         out_len: int) -> List[Tuple[int, int]]:
    """Plain-python ground truth over (item, ts) event lists.

    Returns up to out_len (item, ts) pairs, ascending freshness order
    (the right-aligned valid suffix of the kernel output).
    """
    events = [(ts, 0, i, item) for i, (item, ts) in enumerate(batch)]
    events += [(ts, 1, i, item) for i, (item, ts) in enumerate(rt)]
    # freshest first: sort by (ts, is_rt, idx) descending
    events.sort(key=lambda e: (e[0], e[1], e[2]), reverse=True)
    seen, out = set(), []
    for ts, _, _, item in events:
        if item in seen:
            continue
        seen.add(item)
        out.append((item, ts))
        if len(out) == out_len:
            break
    return list(reversed(out))  # ascending time
