"""Pallas TPU kernel for inference-time watch-history injection (paper §III-B).

This is the per-request hot spot of the paper's technique: merge the user's
*batch* watch history (daily snapshot, long window) with the *real-time*
event buffer (seconds-fresh, short window) into one model-ready history —
time-ordered, deduplicated by item (freshest occurrence wins, real-time
beats batch on ties), truncated to the feature length K.

TPU adaptation (DESIGN.md §2): no data-dependent shapes and no sort
primitive. Both inputs arrive as fixed-size padded buffers with validity
flags; the merge is reformulated as **pairwise rank computation**:

  rank(i)  = #{ j valid, non-duplicate : j strictly fresher than i }
  slot(i)  = K - 1 - rank(i)            (right-aligned, ascending time)
  keep(i)  = valid(i) ∧ ¬dup(i) ∧ rank(i) < K

over the concatenated N = L_batch + L_rt events — O(N²) boolean work on
(N, N) tiles, fully vectorized (VPU), followed by a one-hot (N, K) scatter
expressed as a masked integer reduction. N ≈ a few hundred, so N² ≈ 10⁵
lane-ops per request — microseconds, vs. a host round-trip for a dynamic
merge. Grid = (batch,); each step's working set is O(N² + N·K) int32/bool
in VMEM (≈ 0.6 MB at N=320, K=256).

Freshness total order: (ts, is_rt, buffer index) lexicographic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(bi_ref, bt_ref, bv_ref, ri_ref, rt_ref, rv_ref,
                  oi_ref, ot_ref, ov_ref, *, lb: int, lr: int, k: int):
    n = lb + lr
    items = jnp.concatenate([bi_ref[0], ri_ref[0]])  # (N,)
    ts = jnp.concatenate([bt_ref[0], rt_ref[0]])
    valid = jnp.concatenate([bv_ref[0], rv_ref[0]]) > 0
    is_rt = jax.lax.iota(jnp.int32, n) >= lb
    idx = jax.lax.iota(jnp.int32, n)

    # fresher(j, i): event j strictly fresher than event i (lexicographic)
    ts_j, ts_i = ts[:, None], ts[None, :]
    rt_j, rt_i = is_rt[:, None], is_rt[None, :]
    ix_j, ix_i = idx[:, None], idx[None, :]
    fresher = (ts_j > ts_i) | (
        (ts_j == ts_i) & ((rt_j & ~rt_i) | ((rt_j == rt_i) & (ix_j > ix_i))))

    valid_j = valid[:, None]
    same_item = items[:, None] == items[None, :]
    dup = jnp.any(valid_j & same_item & fresher, axis=0) | ~valid  # (N,)

    alive_j = (valid & ~dup)[:, None]
    rank = jnp.sum((alive_j & fresher).astype(jnp.int32), axis=0)  # (N,)
    keep = valid & ~dup & (rank < k)
    slot = k - 1 - rank  # right-aligned: rank 0 (freshest) -> slot K-1

    # one-hot scatter as a masked reduction over N (no dynamic indexing)
    slots = jax.lax.iota(jnp.int32, k)[None, :]  # (1, K)
    onehot = keep[:, None] & (slot[:, None] == slots)  # (N, K)
    oi_ref[0] = jnp.sum(jnp.where(onehot, items[:, None], 0), axis=0)
    ot_ref[0] = jnp.sum(jnp.where(onehot, ts[:, None], 0), axis=0)
    ov_ref[0] = jnp.sum(onehot.astype(jnp.int32), axis=0)


def history_merge_pallas(batch_items, batch_ts, batch_valid,
                         rt_items, rt_ts, rt_valid, *, out_len: int,
                         interpret: bool = False):
    """All inputs (B, L_batch) / (B, L_rt) int32. Returns
    (items, ts, valid) each (B, out_len) int32, right-aligned ascending-time,
    deduplicated by item id (freshest kept, real-time wins ties)."""
    b, lb = batch_items.shape
    lr = rt_items.shape[1]
    k = out_len

    # A zero-length side (empty realtime buffer / empty batch window) would
    # give a zero-width BlockSpec, which pallas rejects; widen it to one
    # all-invalid column — the validity flags make the extra event inert.
    if lb == 0:
        z = jnp.zeros((b, 1), jnp.int32)
        batch_items, batch_ts, batch_valid, lb = z, z, z, 1
    if lr == 0:
        z = jnp.zeros((b, 1), jnp.int32)
        rt_items, rt_ts, rt_valid, lr = z, z, z, 1

    row = lambda L: pl.BlockSpec((1, L), lambda bb: (bb, 0))
    return pl.pallas_call(
        functools.partial(_merge_kernel, lb=lb, lr=lr, k=k),
        grid=(b,),
        in_specs=[row(lb), row(lb), row(lb), row(lr), row(lr), row(lr)],
        out_specs=[row(k), row(k), row(k)],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.int32)] * 3,
        interpret=interpret,
    )(batch_items, batch_ts, batch_valid, rt_items, rt_ts, rt_valid)
