"""Jit'd wrapper for history_merge with an impl switch.

``impl``:
  * "pallas"            — the TPU kernel (target)
  * "pallas_interpret"  — kernel body interpreted on CPU (tests / this host)
  * "xla"               — the jnp oracle (CPU-fast default for the A/B sim)
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.history_merge.history_merge import history_merge_pallas
from repro.kernels.history_merge.ref import history_merge_ref


@functools.partial(jax.jit, static_argnames=("out_len", "impl"))
def history_merge(batch_items, batch_ts, batch_valid, rt_items, rt_ts,
                  rt_valid, *, out_len: int, impl: str = "xla"):
    args = (batch_items, batch_ts, batch_valid, rt_items, rt_ts, rt_valid)
    if impl == "xla":
        return history_merge_ref(*args, out_len=out_len)
    return history_merge_pallas(*args, out_len=out_len,
                                interpret=(impl == "pallas_interpret"))
