"""Oracles for the SSD scan kernel.

``ssd_ref_sequential`` is the direct (non-chunked) recurrence — the ground
truth both the chunked jnp path (models/ssm.py) and the Pallas kernel are
validated against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked  # chunked jnp path doubles as oracle


def ssd_ref_sequential(x, dt, A, B, C, D, h0=None):
    """Token-by-token recurrence. Same signature/shapes as the kernel."""
    b, s, nh, hp = x.shape
    ds = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    h = jnp.zeros((b, nh, hp, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (b,nh,hp),(b,nh),(b,ds),(b,ds)
        a = jnp.exp(dtt * A[None, :])
        h = a[:, :, None, None] * h + jnp.einsum("bh,bhp,bs->bhps", dtt, xt, Bt)
        y = jnp.einsum("bs,bhps->bhp", Ct, h) + D[None, :, None] * xt
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
