"""Pallas TPU kernel for the Mamba2 SSD (state-space dual) chunked scan.

Grid = (batch, n_head_blocks, n_chunks) with the chunk axis sequential; the
per-(batch, head-block) recurrent state (bh, hp, ds) f32 lives in VMEM
scratch and is carried across chunk steps. Each step computes:

  intra-chunk:  y_ij = Σ_{j<=i} exp(Acum_i - Acum_j) (C_i·B_j) dt_j x_j
  inter-chunk:  y_i += C_i · (exp(Acum_i) * state_in)
  state update: state = exp(Acum_last) * state + Σ_j B_j ⊗ (dt_j decay_j x_j)

which is exactly the discrete SSD form of [arXiv:2405.21060] — the
quadratic intra-chunk term maps onto the MXU (chunk×chunk matmuls) while
the O(S) state pass stays in VMEM, never round-tripping HBM.

VMEM at (Q=256, bh=8, hp=64, ds=128): x block 256·8·64·4 ≈ 0.5 MB, the
L/segsum tensor 8·256·256·4 ≈ 2 MB, state 8·64·128·4 ≈ 0.25 MB — ~4 MB
total with B/C blocks, inside the VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                y_ref, hout_ref, state_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)     # (Q, bh, hp)
    dt = dt_ref[0].astype(jnp.float32)   # (Q, bh)
    A = a_ref[...].astype(jnp.float32)   # (bh,)
    B = b_ref[0].astype(jnp.float32)     # (Q, ds)
    C = c_ref[0].astype(jnp.float32)     # (Q, ds)
    D = d_ref[...].astype(jnp.float32)   # (bh,)

    dA = dt * A[None, :]                 # (Q, bh)
    cum = jnp.cumsum(dA, axis=0)         # (Q, bh)

    # intra-chunk quadratic term
    seg = cum[:, None, :] - cum[None, :, :]          # (Q, Q, bh)
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril[:, :, None], jnp.exp(seg), 0.0)  # (Q, Q, bh)
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (Q, Q)
    M = scores[:, :, None] * L                          # (Q, Q, bh)
    xdt = x * dt[:, :, None]                            # (Q, bh, hp)
    y = jnp.einsum("ijh,jhp->ihp", M, xdt)

    # inter-chunk: contribution of the state entering this chunk
    state_in = state_scr[...]                           # (bh, hp, ds)
    y += jnp.einsum("is,ih,hps->ihp", C, jnp.exp(cum), state_in)

    y += D[None, :, None] * x
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(cum[-1:, :] - cum)           # (Q, bh)
    upd = jnp.einsum("js,jhp->hps", B, xdt * decay_to_end[:, :, None])
    state_scr[...] = jnp.exp(cum[-1])[:, None, None] * state_in + upd

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0] = state_scr[...]


def ssd_scan_pallas(x, dt, A, B, C, D, h0, *, chunk: int = 256,
                    block_heads: int = 8, interpret: bool = False):
    """x (b,s,nh,hp); dt (b,s,nh) f32; A (nh,); B/C (b,s,ds); D (nh,);
    h0 (b,nh,hp,ds) f32. Returns (y (b,s,nh,hp), h_final (b,nh,hp,ds))."""
    b, s, nh, hp = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    bh = min(block_heads, nh)
    assert nh % bh == 0

    grid = (b, nh // bh, s // chunk)
    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh, hp), lambda bb, hi, ci: (bb, ci, hi, 0)),
            pl.BlockSpec((1, chunk, bh), lambda bb, hi, ci: (bb, ci, hi)),
            pl.BlockSpec((bh,), lambda bb, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, ds), lambda bb, hi, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bb, hi, ci: (bb, ci, 0)),
            pl.BlockSpec((bh,), lambda bb, hi, ci: (hi,)),
            pl.BlockSpec((1, bh, hp, ds), lambda bb, hi, ci: (bb, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bh, hp), lambda bb, hi, ci: (bb, ci, hi, 0)),
            pl.BlockSpec((1, bh, hp, ds), lambda bb, hi, ci: (bb, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, nh, hp), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hp, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, hp, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, h0)
    return y, hout
