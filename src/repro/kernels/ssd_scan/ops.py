"""Jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "block_heads", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256, block_heads: int = 8,
             init_state=None, interpret: bool = False):
    """Public SSD scan, matching models.ssm.ssd_chunked's contract."""
    b, s, nh, hp = x.shape
    ds = B.shape[-1]
    h0 = (jnp.zeros((b, nh, hp, ds), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    bh = block_heads
    while nh % bh != 0:
        bh //= 2
    return ssd_scan_pallas(x, dt.astype(jnp.float32), A, B, C, D, h0,
                           chunk=chunk, block_heads=bh, interpret=interpret)
