from repro.kernels.ssd_scan.ops import ssd_scan  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_ref_sequential  # noqa: F401
