"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduced --steps 50 --batch 8 --seq 128 [--ckpt out.msgpack]

On this CPU host use ``--reduced`` (the 2-layer smoke variant); on a real
TPU pod the full config + production mesh apply (sharding rules from
``repro.sharding``). Data: the synthetic next-token stream from
``repro.data`` (the ITFI ranker trains on real simulator logs via
examples/train_ranker.py instead).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer CPU-scale variant of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    from repro.training.checkpoint import save_checkpoint
    from repro.training.optimizer import AdamWConfig, init_opt_state
    from repro.training.train_loop import TrainConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed),
                         dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    opt = init_opt_state(params)
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        microbatches=args.microbatches, remat=not args.reduced,
        param_dtype=jnp.float32 if args.reduced else jnp.bfloat16)

    rng = np.random.RandomState(args.seed)

    def gen():
        # zipf-ish synthetic next-token stream with local structure
        for _ in range(args.steps):
            toks = rng.randint(1, cfg.vocab_size, (args.batch, args.seq))
            labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
            yield {"tokens": jnp.asarray(toks, jnp.int32),
                   "labels": jnp.asarray(labels, jnp.int32)}

    out = train(cfg, tcfg, params, opt, gen(), log_every=10)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": out["params"]},
                        step=args.steps, metadata={"arch": cfg.name})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
