"""Step functions + abstract input specs for every (arch × input shape).

``input_specs`` returns ShapeDtypeStructs (with shardings attached) for
every model input — the dry-run lowers against these with zero allocation.

Step kinds per input shape (configs/shapes.py):
  train_4k     → train_step   (forward+backward+AdamW, remat, microbatched)
  prefill_32k  → prefill_step (history → decode cache + last-token logits)
  decode_32k   → serve_step   (ONE token against a seq_len KV cache)
  long_500k    → serve_step   (512k context; sub-quadratic policy: SSM /
                 hybrid native, dense via the sliding-window ring cache)

[vlm]/[audio] archs: ``prefix_embeds`` stand in for the stubbed frontend —
patch/frame embeddings of the right shape occupy the leading positions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.frontend import frontend_prefix_len
from repro.models.model import cache_shapes, decode_step, param_shapes, prefill
from repro.sharding.rules import (batch_pspec, cache_pspecs, data_axes,
                                  param_pspecs)
from repro.training.optimizer import AdamWConfig, OptState
from repro.training.train_loop import TrainConfig, make_train_step

# Sliding window substituted for pure full-attention archs at long_500k
# (DESIGN.md §4: full attention at 512k is excluded by the assignment).
LONG_CONTEXT_WINDOW = 4096


def microbatches_for(cfg: ModelConfig, shape: InputShape,
                     act_budget: float = 1.5 * 2**30,
                     dp: int = 16, tp: int = 16) -> int:
    """Gradient-accumulation factor from an activation-memory budget.

    Perf iteration (§Perf, mixtral train): FSDP weight all-gathers repeat
    per microbatch, so mb should be the SMALLEST value whose remat-saved
    layer-boundary activations (B/(dp·mb) rows × L × S × d × 2B / tp) fit
    the budget — the original param-count heuristic (mb=16 for >20B) cost
    8× needless weight traffic on mixtral.
    """
    bytes_row = cfg.n_layers * shape.seq_len * cfg.d_model * 2 / tp
    rows = shape.global_batch / dp
    mb = 1
    while mb < rows and rows / mb * bytes_row > act_budget:
        mb *= 2
    return mb


def arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments (the long_500k SWA substitution)."""
    if (shape.name == "long_500k" and cfg.ssm is None
            and not cfg.sliding_window):
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


# ----------------------------------------------------------------------
# Sharded abstract values
# ----------------------------------------------------------------------

def _sharded(tree, spec_tree, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    def one(x, spec):
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: x is None)


def abstract_params(cfg: ModelConfig, mesh: Mesh, decode: bool = False):
    shapes = param_shapes(cfg)
    specs = param_pspecs(cfg, mesh, decode=decode)
    return _sharded(shapes, specs, mesh), specs


def abstract_opt(cfg: ModelConfig, mesh: Mesh):
    pshapes = param_shapes(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    shapes = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      master=jax.tree.map(f32, pshapes),
                      m=jax.tree.map(f32, pshapes),
                      v=jax.tree.map(f32, pshapes))
    pspecs = param_pspecs(cfg, mesh)
    specs = OptState(step=P(), master=pspecs, m=pspecs, v=pspecs)
    return _sharded(shapes, specs, mesh), specs


def _activation_shardings(cfg: ModelConfig, mesh: Mesh):
    from repro.sharding.rules import head_pspec
    dp = data_axes(mesh)
    act = NamedSharding(mesh, P(dp, None, "model"))
    logits = NamedSharding(mesh, P(dp, None, "model"))
    head = NamedSharding(mesh, head_pspec(cfg, mesh))
    return act, logits, head


def _moe_use_shardings(cfg: ModelConfig, mesh: Mesh):
    """Expert-weight shardings at USE time (gather-over-dp FSDP idiom)."""
    if cfg.moe is None:
        return None
    from repro.sharding.rules import ShardingRules
    r = ShardingRules.make(cfg, mesh)
    if r.moe_experts_on_tp:
        up = NamedSharding(mesh, P("model", None, None))
        down = NamedSharding(mesh, P("model", None, None))
        return up, down
    e, tp = cfg.moe.n_experts, r.tp_size
    if tp % e == 0 and cfg.d_ff % (tp // e) == 0 and tp // e > 1:
        # all-to-all EP with f-splitting: e experts × (tp/e) f-shards
        m = tp // e
        dp = data_axes(mesh)
        return ("ep", NamedSharding(mesh, P(dp, "model", None, None)), m)
    # granite (40e on tp=16): neither divides. Constraining the weights to
    # gathered form made GSPMD REPLICATE the expert compute (compute term
    # 8.5→34.8 s — hypothesis refuted, see §Perf); XLA's own partial-sum
    # strategy is the better one. Leave it alone.
    return None


def _attn_pad_policy(cfg: ModelConfig, mesh: Mesh):
    """Pad the attention head axis to a tp multiple when it doesn't divide
    (llava 56H, granite 24H) so scores shard instead of psum-replicating."""
    tp = mesh.shape.get("model", 1)
    if cfg.n_heads and cfg.n_heads % tp:
        dp = data_axes(mesh)
        return tp, NamedSharding(mesh, P(dp, None, "model", None))
    return 0, None


# ----------------------------------------------------------------------
# input_specs + step factories, per shape kind
# ----------------------------------------------------------------------

def make_step_and_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                        ) -> Tuple[Callable, Tuple, Any, Any]:
    """Returns (step_fn, example_args, in_shardings, out_shardings)."""
    cfg = arch_for_shape(cfg, shape)
    if shape.kind == "train":
        return _train_setup(cfg, shape, mesh)
    if shape.kind == "prefill":
        return _prefill_setup(cfg, shape, mesh)
    return _decode_setup(cfg, shape, mesh)


def _batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    bspec = batch_pspec(mesh, b)
    pfx = frontend_prefix_len(cfg, s)
    toks = jax.ShapeDtypeStruct((b, s - pfx), jnp.int32)
    batch = {"tokens": toks}
    specs = {"tokens": bspec}
    if pfx:
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, pfx, cfg.d_model), jnp.bfloat16)
        specs["prefix_embeds"] = P(bspec[0], None, None)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = bspec
        if pfx:  # no loss on the frontend-embedding positions
            batch["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
            specs["loss_mask"] = bspec
    return batch, specs


def _train_setup(cfg, shape, mesh):
    act, logits, head = _activation_shardings(cfg, mesh)
    head_pad, attn_sh = _attn_pad_policy(cfg, mesh)
    tcfg = TrainConfig(
        adamw=AdamWConfig(),
        microbatches=microbatches_for(cfg, shape),
        remat=True, q_chunk=512,
        act_sharding=act, logits_sharding=logits, head_sharding=head,
        embed_mesh=mesh, head_pad_to=head_pad, attn_sharding=attn_sh,
        moe_sharding=_moe_use_shardings(cfg, mesh))
    step = make_train_step(cfg, tcfg)

    params, pspecs = abstract_params(cfg, mesh)
    opt, ospecs = abstract_opt(cfg, mesh)
    batch, bspecs = _batch_specs(cfg, shape, mesh, with_labels=True)
    batch_sharded = _sharded(batch, bspecs, mesh)

    ns = lambda spec: jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(pspecs), ns(ospecs), ns(bspecs))
    metric_sh = {k: NamedSharding(mesh, P()) for k in
                 ("loss", "acc", "moe_aux", "grad_norm", "lr")}
    out_sh = (ns(pspecs), ns(ospecs), metric_sh)
    return step, (params, opt, batch_sharded), in_sh, out_sh


def _prefill_setup(cfg, shape, mesh):
    act, _, head = _activation_shardings(cfg, mesh)
    dp = data_axes(mesh)

    head_pad, attn_sh = _attn_pad_policy(cfg, mesh)

    moe_sh = _moe_use_shardings(cfg, mesh)

    def prefill_step(params, batch):
        logits, caches = prefill(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            q_chunk=512, act_sharding=act, head_sharding=head,
            logits_last_only=True, embed_mesh=mesh,
            head_pad_to=head_pad, attn_sharding=attn_sh,
            moe_sharding=moe_sh)
        return logits[:, 0], caches

    params, pspecs = abstract_params(cfg, mesh)
    batch, bspecs = _batch_specs(cfg, shape, mesh, with_labels=False)
    batch_sharded = _sharded(batch, bspecs, mesh)

    ns = lambda spec: jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(pspecs), ns(bspecs))
    # prefill cache = per-layer K/V (B,S,kv,hd) / ssm states — batch over dp
    kv_like = _prefill_cache_pspecs(cfg, mesh, shape.global_batch)
    out_sh = (NamedSharding(mesh, P(dp, "model")), ns(kv_like))
    return prefill_step, (params, batch_sharded), in_sh, out_sh


def _prefill_cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int):
    """PartitionSpecs for the sequence-form prefill cache output."""
    from repro.models.model import pattern_sig
    from repro.sharding.rules import ShardingRules
    r = ShardingRules.make(cfg, mesh)
    b_ok = batch % r.dp_size == 0
    bspec = r.dp if b_ok else None
    hd_tp = r.tpa(cfg.head_dim_)
    out = {}
    for p, (kind, _) in enumerate(pattern_sig(cfg)):
        if kind == "attn":
            kv = P(None, bspec, None, None, hd_tp)
            out[f"pos{p}"] = {"k": kv, "v": kv}
        else:
            out[f"pos{p}"] = {
                "conv_x": P(None, bspec, None, r.tpa(cfg.d_inner)),
                "conv_B": P(None, bspec, None, None),
                "conv_C": P(None, bspec, None, None),
                "state": P(None, bspec, r.tpa(cfg.n_ssm_heads), None, None),
            }
    return out


def _decode_setup(cfg, shape, mesh):
    b, s = shape.global_batch, shape.seq_len

    def serve_step(params, caches, tokens, pos):
        logits, caches = decode_step(params, cfg, caches, tokens, pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return nxt, caches

    params, pspecs = abstract_params(cfg, mesh, decode=True)
    cshapes = cache_shapes(cfg, b, s)
    cspecs = cache_pspecs(cfg, mesh, b)
    caches = _sharded(cshapes, cspecs, mesh)

    bspec = batch_pspec(mesh, b)
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, bspec))
    pos = jax.ShapeDtypeStruct(
        (b,), jnp.int32, sharding=NamedSharding(mesh, P(bspec[0])))

    ns = lambda spec: jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(pspecs), ns(cspecs), NamedSharding(mesh, bspec),
             NamedSharding(mesh, P(bspec[0])))
    out_sh = (NamedSharding(mesh, P(bspec[0])), ns(cspecs))
    return serve_step, (params, caches, tokens, pos), in_sh, out_sh
