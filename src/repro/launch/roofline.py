"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_global   / (chips × 197 TF/s bf16)
  memory     = HLO_bytes_global   / (chips × 819 GB/s HBM)
  collective = wire_bytes_per_dev / (links × 50 GB/s ICI)

Sources: ``compiled.cost_analysis()`` gives per-device FLOPs / bytes
accessed of the partitioned module (multiplied back to global for the
formula). Collective bytes are NOT in cost_analysis — we parse the
optimized per-device HLO text and sum wire bytes per collective with the
standard ring factors:

  all-gather        out × (g-1)/g
  reduce-scatter    out × (g-1)          (= in × (g-1)/g)
  all-reduce        in  × 2(g-1)/g
  all-to-all        in  × (g-1)/g
  collective-permute  in × 1

where g = replica-group size parsed from the op's ``replica_groups``.

MODEL_FLOPS (the "useful FLOPs" yardstick) = 6·N_active·tokens for train,
2·N_active·tokens for inference — the ratio against HLO_FLOPs exposes
remat recompute and padding waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, ICI_LINKS,
                               PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction: "%name = TYPE[shape]{layout} op-name(...)".
# The opcode is the token immediately before the '(' argument list; the
# result type(s) sit between '=' and the opcode.
_OP_RE = re.compile(r"\s((?:all-gather|all-reduce|reduce-scatter|all-to-all|"
                    r"collective-permute)(?:-start)?)\(")

_SHAPE_RE = re.compile(r"([a-z]+\d*|pred|token|opaque)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [n_groups, group_size]
        return int(m.group(2))
    return 2  # conservative fallback


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: float          # per-device bytes on the wire (ring model)
    raw_bytes: Dict[str, float]
    details: List[Tuple[str, int, float]]  # (op, group, wire_bytes)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    raw: Dict[str, float] = {}
    details = []
    wire = 0.0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group(1)
        base = op[:-6] if op.endswith("-start") else op
        # result type(s) are between '=' and the opcode token
        eq = line.index("=")
        ty = line[eq + 1: m.start()]
        out_bytes = _shape_bytes(ty)
        g = _group_size(line)
        if base == "all-gather":
            w = out_bytes * (g - 1) / g
        elif base == "reduce-scatter":
            w = out_bytes * (g - 1)
        elif base == "all-reduce":
            w = out_bytes * 2 * (g - 1) / g
        elif base == "all-to-all":
            w = out_bytes * (g - 1) / g
        else:  # collective-permute
            w = out_bytes
        counts[base] = counts.get(base, 0) + 1
        raw[base] = raw.get(base, 0.0) + out_bytes
        wire += w
        details.append((base, g, w))
    return CollectiveStats(counts, wire, raw, details)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: ONE token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(cost: Dict, hlo_text: str, chips: int,
                   cfg: ModelConfig, shape: InputShape) -> Dict:
    """Three-term roofline from the loop-aware HLO analysis.

    ``cost`` = compiled.cost_analysis() — kept for reference, but its
    while-loop bodies are counted ONCE, so the terms use
    ``hlo_analysis.analyze`` (trip-count-weighted) instead.

    The compute term takes max(dot FLOPs, MODEL_FLOPS/chips): XLA lowers
    degenerate contractions (e.g. decode's hd/16-wide attention dots) to
    multiply+reduce fusions that dot-counting misses, while MODEL_FLOPS is
    a guaranteed floor.
    """
    from repro.launch.hlo_analysis import analyze
    h = analyze(hlo_text)
    mf = model_flops(cfg, shape)
    dev_flops = max(h.flops, mf / chips)
    t_compute = dev_flops / PEAK_FLOPS_BF16
    t_memory = h.bytes_accessed / HBM_BW
    t_coll = h.wire_bytes / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_global = h.flops * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_dot_flops_per_device": h.flops,
        "hlo_bytes_per_device": h.bytes_accessed,
        "collective_wire_bytes_per_device": h.wire_bytes,
        "collective_counts": h.collective_counts,
        "collective_bytes_by_kind": h.collective_bytes,
        "loop_trips": sorted(set(h.loop_trips), key=lambda t: -t[1])[:12],
        "unknown_trip_loops": h.unknown_trip_loops,
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "chips": chips,
    }
