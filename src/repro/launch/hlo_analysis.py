"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop body ONCE — useless for
a scanned layer stack (layers × microbatches × attention-chunk loops all
live in whiles). This module parses the optimized per-device HLO text,
recovers the computation call graph with **while trip counts**, and
accumulates:

  * dot FLOPs            (matmuls dominate; elementwise ignored)
  * bytes accessed       (operand + result bytes of top-level/fusion ops —
                          approximately XLA's own traffic model)
  * collective wire bytes (ring-model factors per op kind)

each weighted by the product of enclosing loop trip counts.

Trip counts come from the canonical counted-loop form: the while condition
compares the induction variable against a constant — we take the largest
integer constant in the condition computation. Dynamic-trip loops fall back
to 1 and are flagged in ``unknown_trip_loops``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9a-z]*)\[([\d,]*)\]")
# computation header: "%name (params...) -> result {" — params may contain
# nested parens (tuple-typed), so match loosely
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_AFTER_TYPE_RE = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


def _split_type_op(rhs: str):
    """Split an instruction RHS into (result type string, opcode).

    Tuple result types contain parens and spaces — scan the balanced group;
    scalar/array types are a single token.
    """
    s = rhs.strip()
    if s.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ty, rest = s[:end + 1], s[end + 1:]
    else:
        sp = s.find(" ")
        if sp < 0:
            return s, ""
        ty, rest = s[:sp], s[sp:]
    m = _OP_AFTER_TYPE_RE.match(rest)
    return ty, (m.group(1) if m else "")


@dataclasses.dataclass
class Instr:
    name: str
    ty: str          # result type string
    op: str          # opcode
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr name -> result type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        ty, op = _split_type_op(rhs)
        cur.instrs.append(Instr(name, ty, op, line))
        cur.shapes[name] = ty
    return comps


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    """2 × result_elems × contracted_elems (per batch already in result)."""
    ops = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
    lhs_ty = shapes.get(ops[0], "") if ops else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if m and lhs_ty:
        sm = _SHAPE_RE.search(lhs_ty)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * shape_elems(ins.ty) * contract


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def _wire_bytes(base: str, out_bytes: float, g: int) -> float:
    if base == "all-gather":
        return out_bytes * (g - 1) / g
    if base == "reduce-scatter":
        return out_bytes * (g - 1)
    if base == "all-reduce":
        return out_bytes * 2 * (g - 1) / g
    if base == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0
    loop_trips: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    # top individual traffic contributors: (bytes×mult, op, result type,
    # metadata op_name) — the profile the perf loop reads
    top_bytes: List[Tuple[float, str, str, str]] = dataclasses.field(
        default_factory=list)


def _trip_count(cond: Computation) -> Optional[int]:
    consts = [int(c) for i in cond.instrs for c in _CONST_RE.findall(i.line)]
    return max(consts) if consts else None


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation named *.main or the last one
        cands = [n for n in comps if "main" in n]
        entry = cands[0] if cands else (next(iter(comps)) if comps else None)

    cost = HloCost()
    if entry is None:
        return cost

    # computations reachable as fusion bodies should NOT be double counted
    fused_targets = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m:
                    fused_targets.add(m.group(1))

    def visit(name: str, mult: float, stack=()):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                tm = _TRIP_RE.search(ins.line)
                trips = int(tm.group(1)) if tm else None
                if trips is None and mc and mc.group(1) in comps:
                    trips = _trip_count(comps[mc.group(1)])
                if trips is None:
                    trips = 1
                    cost.unknown_trip_loops += 1
                cost.loop_trips.append((ins.name, trips))
                if mb:
                    visit(mb.group(1), mult * trips, stack + (name,))
                continue
            if ins.op in ("call", "async-start"):
                m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.line)
                if m:
                    visit(m.group(1), mult, stack + (name,))
                continue
            if ins.op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+))",
                                     ins.line):
                    tgt = m.group(1) or m.group(2)
                    for t in (tgt or "").split(","):
                        visit(t.strip().lstrip("%"), mult, stack + (name,))
                continue

            base = None
            for c in COLLECTIVES:
                if ins.op == c or ins.op == c + "-start":
                    base = c
                    break
            if base is not None:
                out_b = shape_bytes(ins.ty)
                g = _group_size(ins.line)
                w = _wire_bytes(base, out_b, g) * mult
                cost.wire_bytes += w
                cost.collective_counts[base] = (
                    cost.collective_counts.get(base, 0) + int(mult))
                cost.collective_bytes[base] = (
                    cost.collective_bytes.get(base, 0.0) + w)

            if ins.op in ("dot",):
                cost.flops += _dot_flops(ins, comp.shapes) * mult
            if ins.op == "fusion":
                # fusion internals may contain dots — count them once per
                # fusion execution
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    fc = comps[m.group(1)]
                    for fi in fc.instrs:
                        if fi.op == "dot":
                            cost.flops += _dot_flops(fi, fc.shapes) * mult

            # bytes accessed: operands + result of top-level ops (fusions
            # are XLA's memory-traffic units; whiles/calls handled above)
            if ins.op not in ("while", "call", "parameter", "constant",
                              "get-tuple-element", "tuple", "bitcast"):
                b = shape_bytes(ins.ty)
                args = ins.line.split("(", 1)
                if len(args) > 1:
                    for opnd in _OPERAND_RE.findall(args[1].split(")")[0]):
                        b += shape_bytes(comp.shapes.get(opnd, ""))
                cost.bytes_accessed += b * mult
                meta = ""
                mm = re.search(r'op_name="([^"]*)"', ins.line)
                if mm:
                    meta = mm.group(1)
                cost.top_bytes.append((b * mult, ins.op,
                                       ins.ty.strip()[:48], meta[-80:]))

    visit(entry, 1.0)
    cost.top_bytes = sorted(cost.top_bytes, key=lambda t: -t[0])[:20]
    return cost
