"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS for 512 host devices
*before* any jax initialization; everything else sees the real devices).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                # 256 chips (one v5e-256 pod)
MULTI_POD = (2, 16, 16)              # 2 pods = 512 chips

# TPU v5e-class hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12             # FLOP/s
HBM_BW = 819e9                       # B/s
ICI_BW_PER_LINK = 50e9               # B/s per link (~4 links usable/chip)
ICI_LINKS = 4


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def n_chips(multi_pod: bool = False) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
