"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS for 512 host devices
*before* any jax initialization; everything else sees the real devices).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                # 256 chips (one v5e-256 pod)
MULTI_POD = (2, 16, 16)              # 2 pods = 512 chips

# TPU v5e-class hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12             # FLOP/s
HBM_BW = 819e9                       # B/s
ICI_BW_PER_LINK = 50e9               # B/s per link (~4 links usable/chip)
ICI_LINKS = 4


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(data: int = 1, model: int = 1,
                      devices=None) -> jax.sharding.Mesh:
    """A ("data", "model") mesh over the first ``data*model`` devices.

    Unlike :func:`make_production_mesh` this does not assume the full pod —
    serving replicas are sized to traffic, and CI builds e.g. an 8×1 mesh
    out of ``--xla_force_host_platform_device_count`` CPU devices (the
    dry-run trick; see :func:`host_device_flags`). Degenerate meshes
    (1×1) are valid and run the sharded code path on one device.
    """
    import numpy as np
    n = data * model
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n:
        raise ValueError(
            f"mesh {data}x{model} needs {n} devices, have {len(devices)}; "
            f"on CPU, set XLA_FLAGS={host_device_flags(n)!r} before the "
            f"first jax use (launch/serve.py --mesh does this for you)")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(data, model), ("data", "model"))


def host_device_flags(n: int) -> str:
    """The XLA flag that simulates ``n`` host devices on one CPU — the
    dry-run's 512-device trick, reused by the sharded serving tests and
    benchmarks. Must be in ``XLA_FLAGS`` *before* jax first initializes."""
    return f"--xla_force_host_platform_device_count={n}"


def n_chips(multi_pod: bool = False) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
